"""AdamW with global-norm clipping, configurable moment dtype, and optional
int8 error-feedback gradient compression (distributed-optimization trick).

No optax dependency: the update is ~40 lines and owning it lets us (a) keep
moments in bf16 for the 671B dry-run memory budget, (b) interpose the
compression stage exactly where a real fleet would compress the cross-pod
all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" for the biggest configs
    compress_grads: bool = False      # int8 + error feedback (see compress())


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, dt), p)
    state = {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}
    if cfg.compress_grads:
        state["ef"] = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def compress_int8(g: jnp.ndarray, ef: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 quantization of one gradient tensor.

    Returns (dequantized int8 gradient, new error buffer).  On real hardware
    the int8 payload is what crosses the wire (8x less cross-pod traffic);
    under XLA SPMD we model the value semantics (quantize -> reduce -> requant
    error) so convergence behaviour is faithful.
    """
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1

    if cfg.compress_grads:
        pairs = jax.tree.map(compress_int8, grads, state["ef"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda pr: pr[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_ef = None

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    triples = jax.tree.map(upd, params, grads, state["m"], state["v"])
    unzip = lambda i: jax.tree.map(lambda t: t[i], triples,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_params = unzip(0)
    new_state = {"m": unzip(1), "v": unzip(2), "step": step}
    if new_ef is not None:
        new_state["ef"] = new_ef
    return new_params, new_state, gnorm


def make_train_step(loss_fn, cfg: AdamWConfig):
    """loss_fn(params, batch) -> scalar.  Returns step(params, state, batch)."""

    def step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, gnorm = adamw_update(grads, state, params, cfg)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return step

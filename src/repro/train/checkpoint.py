"""Fault-tolerant checkpointing: atomic, keep-k, mesh-reshardable.

Layout per step:  <dir>/step_<n>.tmp/  ->  (atomic rename)  ->  <dir>/step_<n>/
    manifest.json          {step, config_hash, leaf paths, shapes, dtypes}
    <leaf-path>.npy        one file per pytree leaf (numpy, little-endian)

Design points for 1000+ node fleets (DESIGN.md §5):
  * WRITE atomicity: a crash mid-write leaves only a .tmp dir, never a
    corrupt checkpoint; restore always picks the newest COMPLETE step.
  * RESHARDABLE restore: leaves are stored unsharded (gathered); restore
    device_puts onto whatever mesh/sharding the new job uses — an elastic
    restart onto a different topology is the same code path.
  * Counter-based data pipeline (repro.data.synthetic) + the step in the
    manifest => bitwise-identical training continuation.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list:
    paths = []
    def rec(path, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(path + (str(k),), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(path + (str(i),), v)
        else:
            paths.append((path, node))
    rec((), tree)
    return paths


def _set_path(tree, path, value):
    node = tree
    for p in path[:-1]:
        node = node[int(p)] if isinstance(node, (list, tuple)) else node[p]
    last = path[-1]
    if isinstance(node, (list, tuple)):
        node[int(last)] = value
    else:
        node[last] = value


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        Path(self.directory).mkdir(parents=True, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        final = Path(self.directory) / f"step_{step:08d}"
        tmp = Path(str(final) + ".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for path, leaf in _leaf_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            name = "__".join(path) or "root"
            np.save(tmp / f"{name}.npy", arr)
            manifest["leaves"].append({
                "path": list(path), "file": f"{name}.npy",
                "shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic commit
        self._gc()
        return str(final)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(Path(self.directory) / f"step_{s:08d}",
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list:
        out = []
        for p in Path(self.directory).iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / "manifest.json").exists():    # complete checkpoints only
                    out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``template``.  ``shardings`` (same
        pytree structure, or None) re-shards onto the CURRENT mesh — restoring
        onto a different topology than the one that saved is supported."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = Path(self.directory) / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())

        # Deep-copy the container skeleton so we can fill it in.
        skeleton = jax.tree.map(lambda x: None, template,
                                is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
        import copy
        out = copy.deepcopy(skeleton)
        sh_leaves = None
        if shardings is not None:
            sh_leaves = {tuple(str(p) for p in path): s
                         for path, s in _leaf_paths(shardings)}
        for entry in manifest["leaves"]:
            arr = np.load(d / entry["file"])
            path = tuple(entry["path"])
            if sh_leaves is not None and path in sh_leaves and sh_leaves[path] is not None:
                val = jax.device_put(arr, sh_leaves[path])
            else:
                val = jax.numpy.asarray(arr)
            _set_path(out, path, val)
        return out, manifest

"""Training loop with checkpoint/restart, failure injection, and straggler
accounting — the host-side fault-tolerance harness (DESIGN.md §5).

Within a step, TPU SPMD is synchronous — there is no partial failure; fault
tolerance is across steps:
  * checkpoint every ``ckpt_every`` steps (atomic, keep-k);
  * on (re)start, resume from the newest complete checkpoint — the counter
    based data pipeline replays the exact batch sequence;
  * `simulate_failure_at` kills the loop mid-run (tests use it to prove
    crash -> restore -> bitwise-identical continuation);
  * a step-time watchdog records stragglers (steps slower than
    ``straggler_factor`` x the running median); on a real fleet this signal
    feeds the scheduler that re-slices the data axis (elastic restore is
    exercised in tests by restoring onto a different mesh).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax
import numpy as np

from .checkpoint import CheckpointManager
from .optimizer import AdamWConfig, init_opt_state, make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    losses: List[float]
    start_step: int
    end_step: int
    straggler_steps: List[int]


def train(
    *,
    loss_fn: Callable[[Any, Any], Any],
    init_params_fn: Callable[[], Any],
    batch_fn: Callable[[int], Any],          # step -> batch (counter-based)
    n_steps: int,
    opt_cfg: AdamWConfig = AdamWConfig(),
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 50,
    simulate_failure_at: Optional[int] = None,
    straggler_factor: float = 3.0,
    donate: bool = True,
) -> TrainResult:
    step_fn = make_train_step(loss_fn, opt_cfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())

    start_step = 0
    params = opt_state = None
    if ckpt is not None and ckpt.latest_step() is not None:
        template = {"params": init_params_fn(), "opt": None}
        template["opt"] = init_opt_state(template["params"], opt_cfg)
        restored, manifest = ckpt.restore(template)
        params, opt_state = restored["params"], restored["opt"]
        start_step = manifest["step"]
    if params is None:
        params = init_params_fn()
        opt_state = init_opt_state(params, opt_cfg)

    losses: List[float] = []
    stragglers: List[int] = []
    durations: List[float] = []
    for step in range(start_step, n_steps):
        if simulate_failure_at is not None and step == simulate_failure_at:
            raise SimulatedFailure(f"injected failure at step {step}")
        t0 = time.monotonic()
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        dt = time.monotonic() - t0
        durations.append(dt)
        if len(durations) >= 8 and dt > straggler_factor * float(np.median(durations)):
            stragglers.append(step)
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt is not None and n_steps > start_step:
        ckpt.save(n_steps, {"params": params, "opt": opt_state})
    return TrainResult(params=params, opt_state=opt_state, losses=losses,
                       start_step=start_step, end_step=n_steps,
                       straggler_steps=stragglers)

"""Compiled search plans + the shape-bucketed plan cache (DESIGN.md §7).

Every MonaVec search — static or mutated, any backend, sharded or not — is
executed through a ``SearchPlan``: a cached pipeline of compiled stages
covering the entire query path (rotate/encode the query -> per-segment
packed or gathered scans -> tombstone/allowlist mask -> segment merge ->
stable top-k -> sentinel marking), keyed by

    (backend fingerprint incl. segment signature, shape bucket, k,
     resolved kernel dispatch, normalized backend knobs)

so serving traffic re-dispatches in O(dict lookup) instead of re-tracing.
Incoming batches are padded up to power-of-two buckets (``shape_bucket``,
floored at 8 — the kernels' block_q granularity); pad queries are masked to
NEG before the top-k and sliced off after, so the bucketed execution is
**bit-identical** to the same plan's full-bucket run and, on the BruteForce
paths, to the eager per-segment oracle at the raw batch size — the same
guarantee style as the dist merge (§3) and the gathered scan (§5): ids
exact, scores to the last ulp.

Three rules make the compile cache sound (full rationale: DESIGN.md §7):

* every ARRAY (packed codes, qnorms, CSR, graph tables, masks, perm) is an
  argument of a stage, never a closure constant — XLA constant-folds
  captured arrays and the folded arithmetic need not be bit-identical to
  the runtime op sequence;
* everything that IS baked into a trace (segment seeds, metric, bit mode,
  std scalars, static graph params, shapes) is part of the fingerprint, so
  two indexes share a plan only when the traced program is truly identical
  — which is also what makes plan reuse across same-shape tenants safe;
* stage boundaries confine floating-point arithmetic exactly where the
  reference computations have op boundaries — whole-pipeline fusion is NOT
  value-preserving (rotation fused into a tiny dot re-associates the
  reduction; the L2 adjustment contracts to an FMA under jit).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import logging
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import binary as bin_mod
from repro.core import bruteforce as bf_mod
from repro.core import hnsw as hnsw_mod
from repro.core import ivf as ivf_mod
from repro.core import predicate as pred
from repro.core import quantize as qz
from repro.core import segments as seg
from repro.core.allowlist import NEG, Allowlist
from repro.core.metadata import MetaStore
from repro.core.rhdh import rhdh_apply
from repro.core.scoring import adjust_scores, topk
from repro.core.standardize import DOT, prepare
from repro.kernels import ops

_LOG = logging.getLogger("repro.engine.plan")

# Stage-capture hook (repro.analysis, DESIGN.md §10): when installed, every
# plan-stage invocation reports (backend kind, stage name, UN-jitted stage
# function, concrete args) before dispatching to the compiled stage.  The
# determinism auditor uses this to jax.make_jaxpr exactly the programs the
# engine compiles — same factories, same operands — instead of a parallel
# hand-maintained stage list that could drift.  Costs one ``is not None``
# check per stage call when uninstalled.
_STAGE_OBSERVER: Optional[Callable[[str, str, Callable, tuple], None]] = None


def set_stage_observer(
    observer: Optional[Callable[[str, str, Callable, tuple], None]],
) -> Optional[Callable[[str, str, Callable, tuple], None]]:
    """Install (or clear, with None) the stage-capture hook; returns the
    previous observer so callers can restore it.  Plans built while an
    observer is installed keep reporting through the module-level slot, so
    clearing the hook also silences previously-built cached plans."""
    global _STAGE_OBSERVER
    prev = _STAGE_OBSERVER
    _STAGE_OBSERVER = observer
    return prev


def shape_bucket(b: int) -> int:
    """Power-of-two batch bucket — the plan cache's shape key.

    Floored at 8, the kernels' block_q/row-chunk granularity: every scoring
    path in the repo computes rows in 8-query tiles, so executing at a
    multiple of 8 keeps the tile decomposition — and therefore every row's
    reduction order — independent of the incoming batch size.
    """
    p = 8
    while p < max(b, 1):
        p <<= 1
    return p


# ---------------------------------------------------------------------------
# Cache + keying.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanKey:
    fingerprint: tuple            # backend + segment signature (trace-static)
    bucket: int                   # padded batch size
    k: int
    dispatch: Tuple[bool, bool]   # resolved (use_kernel, interpret)
    knobs: tuple                  # normalized backend knobs, sorted items


@dataclasses.dataclass
class PlanStats(obs.DeltaStats):
    """Counters for the serving loop: cache hits/misses and actual jit
    traces (a trace == one XLA compile; the acceptance criterion 'repeated
    same-bucket searches incur zero retraces' is asserted on ``traces``).
    ``snapshot``/``since`` come from the shared obs.DeltaStats mixin; the
    same counts also flow into the process-wide metrics registry as
    ``plan_cache.{hits,misses,traces,evictions}``."""

    hits: int = 0
    misses: int = 0
    traces: int = 0
    evictions: int = 0


@dataclasses.dataclass
class SearchPlan:
    """A compiled, reusable execution of one search configuration."""

    key: PlanKey
    fn: Callable   # (q_pad, q_valid, live, perm, where_args, *arrays) -> (vals, pos)


def plan_key_digest(key: PlanKey) -> str:
    """Short stable fingerprint of a PlanKey (debug logs, trace attrs)."""
    return hashlib.sha256(repr(key).encode()).hexdigest()[:12]


class PlanCache:
    """PlanKey -> SearchPlan: LRU with hit/miss/trace/eviction accounting.

    Bounded because mutation churn mints new fingerprints (every add() or
    compact() changes the segment signature, DESIGN.md §7), so a long-lived
    serving process would otherwise accumulate superseded plans — and their
    compiled executables — forever.  ``maxsize`` plans is far above any
    steady-state working set (tenants × buckets × k values × knobs).

    Every event lands twice: in ``stats`` (the cheap in-object PlanStats
    serving windows diff against) and in the process-wide metrics registry
    (``plan_cache.*`` counters + size/capacity gauges, DESIGN.md §9).
    Evictions are no longer silent: each one counts, updates the size
    gauge, and logs the evicted key's fingerprint at DEBUG.
    """

    def __init__(self, maxsize: int = 256) -> None:
        self._plans: "collections.OrderedDict[PlanKey, SearchPlan]" = \
            collections.OrderedDict()
        self.maxsize = maxsize
        self.stats = PlanStats()
        self._publish_gauges()

    def _publish_gauges(self) -> None:
        obs.set_gauge("plan_cache.size", len(self._plans))
        obs.set_gauge("plan_cache.capacity", self.maxsize)
        for c in ("hits", "misses", "traces", "evictions"):
            obs.inc(f"plan_cache.{c}", 0)   # pre-register: snapshots always
            #   carry the full counter family, even all-zero

    def get_or_build(self, key: PlanKey, builder: Callable[[], SearchPlan]) -> SearchPlan:
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
            self.stats.hits += 1
            obs.inc("plan_cache.hits")
            return plan
        self.stats.misses += 1
        obs.inc("plan_cache.misses")
        plan = builder()
        self._plans[key] = plan
        while len(self._plans) > self.maxsize:
            old_key, _ = self._plans.popitem(last=False)   # least-recently-used
            self.stats.evictions += 1
            obs.inc("plan_cache.evictions")
            if _LOG.isEnabledFor(logging.DEBUG):
                _LOG.debug(
                    "plan cache evicted %s (bucket=%d k=%d knobs=%s)",
                    plan_key_digest(old_key), old_key.bucket, old_key.k,
                    dict(old_key.knobs))
        self._publish_gauges()
        return plan

    def clear(self) -> None:
        self._plans.clear()
        self.stats = PlanStats()
        self._publish_gauges()

    def __len__(self) -> int:
        return len(self._plans)


_CACHE = PlanCache()


def plan_cache() -> PlanCache:
    """The process-wide plan cache (shared across indexes and tenants)."""
    return _CACHE


# ---------------------------------------------------------------------------
# Fingerprints: everything the trace bakes in.
# ---------------------------------------------------------------------------

def _std_sig(std: Any) -> Optional[tuple]:
    return None if std is None else (float(std.mean), float(std.inv_std))


def _enc_sig(enc: qz.Encoded) -> tuple:
    return (enc.n, enc.seed, enc.bits, enc.n4_dims, enc.dim, enc.dim_pad,
            _std_sig(enc.std), enc.perm is not None, enc.coarse)


_BACKEND_KNOBS = {
    "BruteForceIndex": frozenset({"rescore_mult"}),
    "IvfFlatIndex": frozenset({"nprobe"}),
    "HnswIndex": frozenset({"ef"}),
}


def _validate_knobs(backend: Any, kwargs: dict) -> None:
    kind = type(backend).__name__
    allowed = _BACKEND_KNOBS.get(kind, frozenset())
    unknown = sorted(set(kwargs) - allowed)
    if unknown:
        raise TypeError(
            f"unexpected search kwargs for the {kind} backend: {unknown}")


def _normalize_knobs(backend: Any, extras: Sequence[Any], kwargs: dict,
                     k: int, tuned: Any = None) -> dict:
    """Fill defaults and clamp exactly like the pre-engine search paths, so
    the normalized knobs are part of the plan key (nprobe=min(nprobe,nlist);
    the HNSW beam auto-widens to max(ef, k)).

    Default resolution (DESIGN.md §12): an EXPLICIT per-call kwarg always
    wins; otherwise a persisted autotune result (``tuned.knobs``) supplies
    the default; otherwise the engine's built-in default.  Passing the knob
    as ``None`` means "not given" on every rung of that ladder.

    BruteForce: ``rescore_mult=r > 0`` selects the binarized cascade with a
    rescore budget of m = r*k survivors per segment.  When every segment
    would rescore all of its rows (m >= n_s for all s) the knob normalizes
    AWAY and the plan IS the plain full-scan plan — which is exactly how
    the m=n cascade is bit-identical to the full 4-bit scan (the exactness
    pin in tests/test_cascade.py)."""
    tuned_knobs = {} if tuned is None else dict(getattr(tuned, "knobs", {}))
    kind = type(backend).__name__
    if kind == "IvfFlatIndex":
        nprobe = kwargs.get("nprobe")
        if nprobe is None:
            nprobe = tuned_knobs.get("nprobe", 8)
        return {"nprobe": min(int(nprobe), backend.nlist)}
    if kind == "HnswIndex":
        ef = kwargs.get("ef")
        if ef is None:
            ef = tuned_knobs.get("ef", 64)
        return {"ef": max(int(ef), k)}
    if kind == "BruteForceIndex":
        rm = kwargs.get("rescore_mult")
        if rm is None:
            rm = tuned_knobs.get("rescore_mult")
        rm = 0 if rm is None else int(rm)
        if rm < 0:
            raise ValueError(f"rescore_mult must be >= 0, got {rm}")
        if rm == 0:
            return {}
        encs = [backend.enc] + [s.enc for s in extras]
        if any(e.ccodes is None for e in encs):
            raise ValueError(
                "rescore_mult requires an index built with a binarized "
                "coarse code (MonaVec.build(..., coarse='sign'|'crumb'))")
        if rm * k >= max(e.n for e in encs):
            return {}   # full rescore everywhere == the full scan
        return {"rescore_mult": rm}
    return {}


def _boost_knobs(backend: Any, extras: Sequence[Any], knobs: dict, k: int,
                 mult: int) -> dict:
    """Scale the candidate budget by a boost-curve multiplier (DESIGN.md §12).

    Applied AFTER normalization and BEFORE plan keying, on selective
    filtered queries only: IVF probes more lists (clamped to nlist), the
    cascade widens its survivor budget (re-checking the full-scan collapse).
    The HNSW beam is not boosted — ef gates graph traversal before the live
    mask is known, and the tuned ef already meets the unfiltered target.
    Boosted knobs mint ordinary plan keys, so the extra plans are bounded by
    the multiplier ladder.
    """
    if mult <= 1 or not knobs:
        return knobs
    kind = type(backend).__name__
    if kind == "IvfFlatIndex":
        return {"nprobe": min(knobs["nprobe"] * int(mult), backend.nlist)}
    if kind == "BruteForceIndex" and "rescore_mult" in knobs:
        rm = knobs["rescore_mult"] * int(mult)
        encs = [backend.enc] + [s.enc for s in extras]
        if rm * k >= max(e.n for e in encs):
            return {}   # boosted into a full rescore == the full scan
        return {"rescore_mult": rm}
    return knobs


def resolve_knobs(backend: Any, state: Any, k: int, *, tuned: Any = None,
                  **kwargs: Any) -> dict:
    """The exact knobs a search with these arguments would run with.

    Same validation + normalization as ``search_backend`` (explicit kwarg >
    persisted tuned knob > engine default; nprobe clamped to nlist, ef
    auto-widened to k, rescore_mult collapsed to the full scan when the
    budget covers every segment) — surfaced so callers can SEE silent
    clamping instead of wondering why nprobe=64 behaves like nprobe=16.
    Selectivity boosting is per-query, so it is not included here.
    """
    _validate_knobs(backend, kwargs)
    extras = state.extras if state is not None else []
    return dict(_normalize_knobs(backend, extras, kwargs, k, tuned=tuned))


def _fingerprint(backend: Any, extras: Sequence[Any], knobs: dict) -> tuple:
    kind = type(backend).__name__
    segs = (_enc_sig(backend.enc),) + tuple(_enc_sig(s.enc) for s in extras)
    head: tuple = (kind, backend.enc.metric, segs)
    if kind == "IvfFlatIndex":
        head += ((backend.nlist, backend.max_candidates(knobs["nprobe"])),)
    elif kind == "HnswIndex":
        head += ((backend.m, backend.entry_point, backend.max_level,
                  int(backend.neighbors0.shape[1])),)
    return head


# ---------------------------------------------------------------------------
# Plan compilation.
# ---------------------------------------------------------------------------

def _rotate(q: jnp.ndarray, *, metric: str, std: Any, seed: int,
            perm: Optional[jnp.ndarray]) -> jnp.ndarray:
    """encode_query as a trace-safe stage: same prepare + RHDH as the corpus,
    with the v7 permutation riding in as an array ARGUMENT."""
    prepared = prepare(q.astype(jnp.float32), metric, std)
    rot = rhdh_apply(prepared, seed, normalized=False)
    if perm is not None:
        rot = rot[..., perm]
    return rot


def _build_plan(backend: Any, extras: Sequence[Any], *, key: PlanKey,
                knobs: dict,
                cache: PlanCache,
                where: Optional[pred.Predicate] = None) -> SearchPlan:
    """Compile one plan: a pipeline of per-plan jitted STAGES driven by a
    plain-Python closure.

    The stage boundaries are load-bearing for bit-identity: XLA may fuse a
    query rotation into a downstream (especially tiny) matmul and
    re-associate the reduction, so the rotation, each floating-point scan,
    and the candidate-set search each compile as their own stage — matching
    the op boundaries of the reference/oracle computations exactly — while
    the mask/concat/merge/top-k finalizer (which performs NO float
    arithmetic, only selection and data movement, and is therefore exact
    under any fusion) compiles as one stage on top.  Each stage bumps the
    cache's trace counter at trace time, so a plan-cache hit provably costs
    zero retraces.
    """
    kind = type(backend).__name__
    enc0 = backend.enc
    metric, bits, n4 = enc0.metric, enc0.bits, enc0.n4_dims
    std = enc0.std
    seeds = (enc0.seed,) + tuple(s.enc.seed for s in extras)
    seg_ns = (enc0.n,) + tuple(s.enc.n for s in extras)
    base_n, n_total = seg_ns[0], sum(seg_ns)
    k = key.k
    use_kernel, interpret = key.dispatch
    stats = cache.stats

    def marked(fn, stage):
        """jit(fn) with the trace counter attached (runs once per trace) and
        the analysis stage-capture hook on the call path (module docstring:
        one None-check per call when no observer is installed)."""
        def wrapper(*args):
            stats.traces += 1
            obs.inc("plan_cache.traces")
            return fn(*args)
        jitted = jax.jit(wrapper)

        def run(*args):
            if _STAGE_OBSERVER is not None:
                _STAGE_OBSERVER(kind, stage, fn, args)
            return jitted(*args)
        return run

    def staged(stage, fn):
        """Host-side per-stage timer (DESIGN.md §9): wraps the CALL to a
        compiled stage — the timer never enters the traced function, so
        instrumentation cannot perturb the compiled program.  Records into
        the ``engine.stage_us{backend,stage}`` histogram and, under an
        active QueryTrace, as a nested span."""
        span_name = f"stage:{stage}"
        labels = {"backend": kind, "stage": stage}

        def run(*args):
            with obs.timed_span(span_name, histogram="engine.stage_us",
                                labels=labels):
                return fn(*args)
        return run

    def make_rot(seed):
        return marked(lambda q, perm: _rotate(q, metric=metric, std=std,
                                              seed=seed, perm=perm), "rotate")

    # Predicate mask stage (DESIGN.md §8): pure boolean algebra over the
    # live mask and the flattened (column keys, constant keys) operands —
    # no float arithmetic, so exact under any fusion.  The stage function
    # depends only on the predicate STRUCTURE (which is in the plan key),
    # never on its constants, so plans are shared across constant values.
    where_stage = None if where is None else staged(
        "predicate_mask", marked(pred.build_stage_fn(where), "predicate_mask"))

    def masked_live(live, where_args):
        return live if where_stage is None else where_stage(live, *where_args)

    def make_scan():
        # Raw dot compiles as its own stage; the metric adjustment runs
        # EAGERLY (op-by-op), exactly like the reference scoring: under jit
        # XLA contracts the L2 multiply+subtract into an FMA and the result
        # is no longer bit-identical to the eager op sequence the oracles
        # (and the pre-engine search paths) compute.
        raw_fn = marked(lambda q_rot, packed: bf_mod.scan_stage(
            q_rot, packed, bits=bits, n4_dims=n4, use_kernel=use_kernel,
            interpret=interpret), "scan")
        if metric == DOT:
            return lambda q_rot, packed, qnorms: raw_fn(q_rot, packed)
        return lambda q_rot, packed, qnorms: adjust_scores(
            raw_fn(q_rot, packed), qnorms, metric)

    rot_stages = [staged("rotate", make_rot(s)) for s in seeds]

    if kind == "BruteForceIndex" and "rescore_mult" in knobs:
        # Binarized cascade (DESIGN.md §11): coarse_scan -> survivor_topk ->
        # gathered_rescore per segment, then one selection-only finalizer.
        # The coarse proxy is INTEGER (bit-identical on every dispatch path);
        # the only float stages are the rotation and the gathered 4-bit
        # rescore — the same score_gathered the IVF/HNSW paths compile.  The
        # live mask (tombstones & allowlist & predicate) gates SURVIVOR
        # SELECTION, so filtered queries spend their whole rescore budget on
        # admissible rows (§3.5: filters must not lose candidates).
        coarse_kind = enc0.coarse
        m = knobs["rescore_mult"] * k
        seg_ms = tuple(min(m, n) for n in seg_ns)
        m_total = sum(seg_ms)
        offsets = [0] + np.cumsum(seg_ns).tolist()

        coarse_stages = [staged("coarse_scan", marked(
            lambda q_rot, ccodes: bin_mod.coarse_scan_stage(
                q_rot, ccodes, kind=coarse_kind, use_kernel=use_kernel,
                interpret=interpret), "coarse_scan")) for _ in seeds]

        def make_surv(m_i):
            return staged("survivor_topk", marked(
                lambda proxy, live_s: bin_mod.survivor_topk_stage(
                    proxy, live_s, m=m_i, vbound=9 * enc0.dim_pad),
                "survivor_topk"))
        surv_stages = [make_surv(m_i) for m_i in seg_ms]

        rescore_stages = [staged("gathered_rescore", marked(
            lambda q_rot, packed, qnorms, cand:
            bin_mod.gathered_rescore_stage(
                q_rot, packed, qnorms, cand, bits=bits, n4_dims=n4,
                metric=metric, use_kernel=use_kernel, interpret=interpret),
            "gathered_rescore")) for _ in seeds]

        n_segs = len(seeds)

        def fin(q_valid, *cols):
            # Selection and data movement only (exact under any fusion):
            # dead survivors already carry NEG from score_gathered and -1
            # in the position columns.
            scores = cols[0] if n_segs == 1 else \
                jnp.concatenate(cols[:n_segs], axis=1)
            gpos = cols[n_segs] if n_segs == 1 else \
                jnp.concatenate(cols[n_segs:], axis=1)
            scores = jnp.where(q_valid[:, None], scores, NEG)
            if m_total < k:   # k > budget: sentinel-pad to the [b, k] contract
                scores = jnp.pad(scores, ((0, 0), (0, k - m_total)),
                                 constant_values=NEG)
                gpos = jnp.pad(gpos, ((0, 0), (0, k - m_total)),
                               constant_values=-1)
            vals, sel = topk(scores, k)
            pos = jnp.take_along_axis(gpos, sel, axis=1)
            return vals, jnp.where(vals > NEG, pos, -1)
        finalize = staged("finalize", marked(fin, "finalize"))

        def fn(q, q_valid, live, perm, where_args, *seg_arrays):
            live = masked_live(live, where_args)
            score_cols, pos_cols = [], []
            for i in range(n_segs):
                off, n_i = offsets[i], seg_ns[i]
                packed, qnorms, ccodes = seg_arrays[3 * i: 3 * i + 3]
                q_rot = rot_stages[i](q, perm)
                proxy = coarse_stages[i](q_rot, ccodes)
                cand = surv_stages[i](proxy, live[off: off + n_i])
                score_cols.append(rescore_stages[i](q_rot, packed, qnorms,
                                                    cand))
                pos_cols.append(jnp.where(cand >= 0, cand + off, -1))
            return finalize(q_valid, *(score_cols + pos_cols))

        return SearchPlan(key=key, fn=fn)

    if kind == "BruteForceIndex":
        scan_stages = [staged("scan", make_scan()) for _ in seeds]

        def fin(q_valid, live, *cols):
            scores = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
            scores = jnp.where(live[None, :], scores, NEG)
            scores = jnp.where(q_valid[:, None], scores, NEG)
            if n_total < k:    # k > n: sentinel-pad to the full [b, k] contract
                scores = jnp.pad(scores, ((0, 0), (0, k - n_total)),
                                 constant_values=NEG)
            vals, pos = topk(scores, k)
            return vals, jnp.where(vals > NEG, pos, -1)
        finalize = staged("finalize", marked(fin, "finalize"))

        def fn(q, q_valid, live, perm, where_args, *seg_arrays):
            live = masked_live(live, where_args)
            cols = [scan_stages[i](rot_stages[i](q, perm),
                                   seg_arrays[2 * i], seg_arrays[2 * i + 1])
                    for i in range(len(seeds))]
            return finalize(q_valid, live, *cols)

        return SearchPlan(key=key, fn=fn)

    # Candidate-set backends: one compiled main-scan stage (the same jit
    # body the pre-engine paths ran), brute-force side-scan stages for the
    # extra segments, and an exact merge/finalize stage.
    if kind == "IvfFlatIndex":
        nprobe = knobs["nprobe"]
        max_cand = backend.max_candidates(nprobe)
        main = staged("main", marked(
            lambda q_rot, centroids, order, offsets, packed, qnorms,
            live0: ivf_mod.search_stage(
                q_rot, centroids, order, offsets, packed, qnorms,
                live0, k=k, nprobe=nprobe, max_cand=max_cand,
                metric=metric, bits=bits, n4_dims=n4,
                use_kernel=use_kernel, interpret=interpret), "main"))
        n_head = 3
    elif kind == "HnswIndex":
        ef = knobs["ef"]
        entry, max_level = backend.entry_point, backend.max_level
        main = staged("main", marked(
            lambda q_rot, nbr0, nbr_hi, packed, qnorms, live0:
            hnsw_mod.search_stage(
                q_rot, packed, qnorms, nbr0, nbr_hi, live0,
                entry=entry, ef=ef, k=k, metric=metric, bits=bits,
                n4_dims=n4, max_level=max_level,
                use_kernel=use_kernel, interpret=interpret), "main"))
        n_head = 2
    else:
        raise TypeError(f"no plan builder for backend {kind}")

    # Closures capture COUNTS, never the Segment objects: a superseded plan
    # sitting in the LRU must not pin old segments' quantized arrays.
    n_extras = len(extras)
    scan_stages = [staged("scan", make_scan()) for _ in range(n_extras)]

    def merge(q_valid, live, main_vals, main_pos, *side_cols):
        if side_cols:
            cols = [jnp.where(live[off: off + n][None, :], c, NEG)
                    for c, off, n in zip(
                        side_cols,
                        np.cumsum((base_n,) + seg_ns[1:-1]).tolist(),
                        seg_ns[1:])]
            side = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
            main_vals, main_pos = seg.merge_stage(
                main_vals, main_pos, side, base_n, k)
        vals = jnp.where(q_valid[:, None], main_vals, NEG)
        return vals, jnp.where(vals > NEG, main_pos, -1)
    finalize = staged("merge", marked(merge, "merge"))

    def fn(q, q_valid, live, perm, where_args, *arrays):
        live = masked_live(live, where_args)
        head, seg_arrays = arrays[:n_head], arrays[n_head:]
        q_rot0 = rot_stages[0](q, perm)
        main_vals, main_pos = main(q_rot0, *head, seg_arrays[0],
                                   seg_arrays[1], live[:base_n])
        side_cols = [scan_stages[i](rot_stages[i + 1](q, perm),
                                    seg_arrays[2 * (i + 1)],
                                    seg_arrays[2 * (i + 1) + 1])
                     for i in range(n_extras)]
        return finalize(q_valid, live, main_vals, main_pos, *side_cols)

    return SearchPlan(key=key, fn=fn)


def _bind_arrays(backend: Any, extras: Sequence[Any],
                 with_codes: bool = False) -> tuple:
    """Per-call array operands, in the plan function's positional order.

    ``with_codes`` (cascade plans) appends each segment's packed coarse
    codes after its (packed, qnorms) pair — arrays stay stage ARGUMENTS."""
    kind = type(backend).__name__
    head: tuple = ()
    if kind == "IvfFlatIndex":
        head = (backend.centroids, backend.order_j, backend.offsets_j)
    elif kind == "HnswIndex":
        head = (jnp.asarray(backend.neighbors0),
                jnp.asarray(backend.neighbors_hi) if backend.max_level else None)
    segs: list = []
    for enc in [backend.enc] + [s.enc for s in extras]:
        if with_codes:
            segs.extend((enc.packed, enc.qnorms, enc.ccodes))
        else:
            segs.extend((enc.packed, enc.qnorms))
    return head + tuple(segs)


# ---------------------------------------------------------------------------
# Execution: the one search entry point every backend routes through.
# ---------------------------------------------------------------------------

def search_backend(
    backend: Any,
    state: Any,                  # SegmentedState or None (= static index)
    queries: jnp.ndarray,
    k: int,
    *,
    allow: Optional[Allowlist] = None,
    where: Optional[pred.Predicate] = None,
    meta: Optional[MetaStore] = None,
    where_mask: Optional[np.ndarray] = None,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    tuned: Any = None,
    **kwargs,
) -> Tuple[np.ndarray, np.ndarray]:
    """Bucketed compiled-plan search: (scores [b,k], external ids [b,k]).

    Exactly ``k`` columns always; inadmissible slots carry SENTINEL_ID/NEG.
    Bit-identical to the pre-engine per-path implementations (the oracle
    suites in tests/ pin this), with the whole pipeline compiled once per
    (fingerprint, bucket, k, dispatch, knobs) and reused across calls —
    and across same-shape tenants.

    Filtering (DESIGN.md §8): ``where=`` is a structured predicate over
    ``meta``'s columns, compiled as a mask stage fused with the tombstone/
    allowlist live mask — its STRUCTURE joins the fingerprint, its
    constants (and the column key planes) ride as dynamic arguments, so
    repeated predicate shapes hit the cache with zero retrace.
    ``where_mask=`` is the already-computed [n_total] boolean row mask for
    callers that evaluated a predicate themselves; it is ANDed host-side
    (the live mask is a dynamic argument, so no new plan is minted).

    ``tuned=`` (a ``repro.tune.TuneResult``) supplies knob DEFAULTS and,
    when it carries a boost curve, the per-query selectivity boost on
    filtered searches (DESIGN.md §12).
    """
    _validate_knobs(backend, kwargs)
    extras = state.extras if state is not None else []
    knobs = _normalize_knobs(backend, extras, kwargs, k, tuned=tuned)
    use_kernel, interpret = ops.resolve_dispatch(use_kernel, interpret)
    kind = type(backend).__name__

    q = jnp.atleast_2d(jnp.asarray(queries))
    b = int(q.shape[0])
    bucket = shape_bucket(b)
    obs.inc("engine.searches", **{"backend": kind})
    obs.inc("engine.query_rows", b, **{"backend": kind})

    base_n = backend.enc.n
    n_total = int(base_n + sum(s.enc.n for s in extras))
    if state is not None:
        live = seg.live_mask(state, allow, base_n)
    elif allow is not None:
        mask = np.asarray(allow.mask, dtype=bool)
        if mask.shape[0] != base_n:
            raise ValueError(
                f"allowlist mask covers {mask.shape[0]} rows but the index "
                f"has {base_n}; build it from the index ids")
        live = mask
    else:
        live = np.ones(base_n, dtype=bool)

    boost = None if tuned is None else getattr(tuned, "boost", None)
    filtered = where is not None or where_mask is not None
    # Denominator of the selectivity ratio: live∩allowed rows BEFORE the
    # caller's filter — "1% selectivity" means 1% of what an unfiltered
    # search of this index would rank.
    pre_filter_n = (int(np.count_nonzero(live))
                    if boost is not None and filtered and knobs else 0)

    if where_mask is not None:
        wm = np.asarray(where_mask, dtype=bool)
        if wm.shape != (n_total,):
            raise ValueError(
                f"where_mask covers {wm.shape} rows but the index has "
                f"{n_total}")
        live = np.asarray(live, dtype=bool) & wm

    where_sig = None
    where_args: tuple = ()
    if where is not None:
        if meta is None or not meta:
            raise ValueError(
                "where= requires an index built with metadata columns")
        if meta.n_rows != n_total:
            raise ValueError(
                f"metadata has {meta.n_rows} rows but the index has {n_total}")
        pred.validate(where, meta)
        where_sig = pred.structure(where, meta)
        where_args = tuple(
            jnp.asarray(a) for a in pred.flatten_args(where, meta))

    # Selectivity-aware candidate budgets (DESIGN.md §12): on filtered
    # searches of a boost-tuned index, measure how selective the filter is
    # (exact popcount, cached per predicate structure+constants) and widen
    # nprobe / rescore_mult via the tuned curve BEFORE plan keying — the
    # fix for filtered recall collapsing at 1% selectivity.
    if boost is not None and filtered and knobs and pre_filter_n > 0:
        if where is not None:
            from repro.tune.selectivity import estimate_matches
            matched = estimate_matches(where, meta, live)
        else:
            matched = int(np.count_nonzero(live))
        mult = boost.multiplier(matched / pre_filter_n)
        if mult > 1:
            knobs = _boost_knobs(backend, extras, knobs, k, mult)
            obs.inc("engine.boost_applied",
                    **{"backend": kind, "mult": str(mult)})

    fingerprint = _fingerprint(backend, extras, knobs)
    if where_sig is not None:
        fingerprint = fingerprint + (("where", where_sig),)
    key = PlanKey(
        fingerprint=fingerprint,
        bucket=bucket, k=k, dispatch=(use_kernel, interpret),
        knobs=tuple(sorted(knobs.items())),
    )
    with obs.timed_span("plan_lookup", histogram="engine.stage_us",
                        labels={"backend": kind, "stage": "plan_lookup"}) as sp:
        misses_before = _CACHE.stats.misses
        plan = _CACHE.get_or_build(
            key, lambda: _build_plan(backend, extras, key=key, knobs=knobs,
                                     cache=_CACHE, where=where))
        if sp is not None:
            sp.attrs.update(plan=plan_key_digest(key), bucket=bucket, k=k,
                            hit=_CACHE.stats.misses == misses_before)

    if bucket != b:
        q = jnp.pad(q, ((0, bucket - b), (0, 0)))
    q_valid = jnp.asarray(np.arange(bucket) < b)
    perm = None if backend.enc.perm is None else jnp.asarray(backend.enc.perm)
    with obs.timed_span("execute", histogram="engine.stage_us",
                        labels={"backend": kind, "stage": "execute"},
                        attrs={"backend": kind, "rows": b, "bucket": bucket}):
        vals, pos = plan.fn(q, q_valid, jnp.asarray(live), perm, where_args,
                            *_bind_arrays(backend, extras,
                                          with_codes="rescore_mult" in knobs))
    # The device->host transfer is where outstanding async device work
    # completes: this span/histogram carries the actual device latency.
    with obs.timed_span("sync", histogram="engine.stage_us",
                        labels={"backend": kind, "stage": "sync"}):
        vals = np.asarray(vals)[:b]
        pos = np.asarray(pos)[:b]
    ids = (backend.ids if not extras else
           np.concatenate([backend.ids] + [s.ids for s in extras]))
    return vals, seg.rows_to_ids(pos, ids)


def search_sharded(index: Any, queries: jnp.ndarray, k: int, *,
                   where_mask: Optional[np.ndarray] = None,
                   rescore_mult: Optional[int] = None,
                   tuned: Any = None,
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """The shard_map scan as a cached plan: same bucketing, same counters,
    same [b, k] sentinel-padded contract as the single-device engines.

    ``where_mask`` is an [n] boolean row-admissibility mask (a compiled
    predicate's output, or any caller-built filter), sharded alongside the
    corpus and applied BEFORE the local top-k — slots with no admissible
    row come back as SENTINEL_ID / NEG exactly like the single-device
    filtered path.

    ``rescore_mult=r > 0`` selects the binarized cascade INSIDE each shard
    (coarse proxy -> local survivor top-m -> gathered 4-bit rescore -> local
    top-k), normalized exactly like the single-device knob: when m = r*k
    covers the whole corpus the knob drops away and the plan is the plain
    sharded scan (the m=n bit-identity pin)."""
    q = jnp.atleast_2d(jnp.asarray(queries))
    b = int(q.shape[0])
    bucket = shape_bucket(b)
    enc = index.enc
    k_eff = min(k, index.n)
    masked = where_mask is not None
    if masked:
        where_mask = np.asarray(where_mask, dtype=bool)
        if where_mask.shape != (index.n,):
            raise ValueError(
                f"where_mask covers {where_mask.shape} rows but the index "
                f"has {index.n}")
    if rescore_mult is None and tuned is not None:
        rescore_mult = dict(getattr(tuned, "knobs", {})).get("rescore_mult")
    rm = 0 if rescore_mult is None else int(rescore_mult)
    if rm < 0:
        raise ValueError(f"rescore_mult must be >= 0, got {rm}")
    boost = None if tuned is None else getattr(tuned, "boost", None)
    if boost is not None and masked and rm > 0 and index.n > 0:
        # Sharded corpora are static (no tombstones): selectivity is the
        # mask's exact popcount over the whole corpus.
        mult = boost.multiplier(int(np.count_nonzero(where_mask)) / index.n)
        if mult > 1:
            rm *= int(mult)
            obs.inc("engine.boost_applied",
                    **{"backend": "ShardedMonaVec", "mult": str(mult)})
    if rm > 0 and enc.ccodes is None:
        raise ValueError(
            "rescore_mult requires an index built with a binarized coarse "
            "code (MonaVec.build(..., coarse='sign'|'crumb'))")
    if rm * k_eff >= index.n:
        rm = 0              # full rescore everywhere == the full scan
    cascade = rm > 0
    # Content-keyed like search_backend — the plan must not retain the index:
    # the closure holds only scalars + the (small, long-lived) mesh, arrays
    # ride in as arguments, and same-config corpora on one mesh share plans.
    key = PlanKey(
        fingerprint=("ShardedMonaVec", id(index.mesh), index.n,
                     _enc_sig(enc), enc.metric, masked),
        bucket=bucket, k=k_eff, dispatch=(None, None),
        knobs=(("rescore_mult", rm),) if cascade else (),
    )

    def build() -> SearchPlan:
        from repro.dist.retrieval import (make_cascade_topk_shardmap,
                                          make_scan_topk_shardmap)
        stats = _CACHE.stats

        def on_trace() -> None:
            stats.traces += 1
            obs.inc("plan_cache.traces")

        mesh = index.mesh
        metric, std, seed = enc.metric, enc.std, enc.seed
        if cascade:
            scan = make_cascade_topk_shardmap(
                mesh, metric=metric, k=k_eff, bits=enc.bits,
                n4_dims=enc.n4_dims, n_valid=index.n, on_trace=on_trace,
                with_mask=masked, kind=enc.coarse, m=rm * k_eff)
        else:
            scan = make_scan_topk_shardmap(
                mesh, metric=metric, k=k_eff, bits=enc.bits,
                n4_dims=enc.n4_dims, n_valid=index.n, on_trace=on_trace,
                with_mask=masked)
        stage = "cascade_shard_scan" if cascade else "shard_scan"

        def raw(q_pad, packed, qnorms, ccodes, perm, mask):
            # Eager rotation: the exact op sequence of qz.encode_query.
            q_rot = _rotate(q_pad, metric=metric, std=std, seed=seed,
                            perm=perm)
            args = (q_rot, packed, qnorms)
            if ccodes is not None:
                args += (ccodes,)
            if mask is not None:
                args += (mask,)
            if _STAGE_OBSERVER is not None:
                _STAGE_OBSERVER("ShardedMonaVec", stage, scan, args)
            with mesh:
                return scan(*args)

        return SearchPlan(key=key, fn=raw)

    n_shards = int(getattr(index.mesh, "size", 1))
    obs.inc("engine.searches", **{"backend": "ShardedMonaVec"})
    obs.inc("engine.query_rows", b, **{"backend": "ShardedMonaVec"})
    with obs.timed_span("plan_lookup", histogram="engine.stage_us",
                        labels={"backend": "ShardedMonaVec",
                                "stage": "plan_lookup"}) as sp:
        plan = _CACHE.get_or_build(key, build)
        if sp is not None:
            sp.attrs.update(plan=plan_key_digest(key), shards=n_shards)
    if bucket != b:
        q = jnp.pad(q, ((0, bucket - b), (0, 0)))
    perm = None if enc.perm is None else jnp.asarray(enc.perm)
    with obs.timed_span("shard_scan", histogram="engine.stage_us",
                        labels={"backend": "ShardedMonaVec",
                                "stage": "shard_scan"},
                        attrs={"shards": n_shards, "rows": b}):
        vals, gidx = plan.fn(q, enc.packed, enc.qnorms,
                             enc.ccodes if cascade else None, perm,
                             jnp.asarray(where_mask) if masked else None)
    with obs.timed_span("sync", histogram="engine.stage_us",
                        labels={"backend": "ShardedMonaVec", "stage": "sync"}):
        vals = np.asarray(vals)[:b]
        gidx = np.asarray(gidx)
    ids = index.ids[gidx[:b]]
    if masked or cascade:
        # Filtered shards (and cascade shards with dead survivor slots)
        # surface inadmissible slots as -inf; convert to the engine-wide
        # sentinel contract (NEG score, SENTINEL_ID id).
        bad = ~np.isfinite(vals)
        vals = np.where(bad, NEG, vals).astype(vals.dtype)
        ids = np.where(bad, seg.SENTINEL_ID, ids)
    if k_eff < k:   # k > n: sentinel-pad to the full [b, k] contract
        vals = np.pad(vals, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        ids = np.pad(ids, ((0, 0), (0, k - k_eff)),
                     constant_values=seg.SENTINEL_ID)
    return vals, ids


# ---------------------------------------------------------------------------
# The searcher handle.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Searcher:
    """A bound (index, k, dispatch, knobs) handle: ``searcher(queries)``.

    Produced by ``MonaVec.searcher(...)`` / ``ShardedMonaVec.searcher(...)``;
    plans resolve through the shared cache on every call, so a searcher is
    always consistent with the index's CURRENT mutation state (add/delete/
    compact simply select a different plan).  ``warmup()`` pre-compiles the
    plan for a bucket so serving never pays the trace inside a measured or
    latency-sensitive window.
    """

    index: object
    k: int = 10
    use_kernel: Optional[bool] = None
    interpret: Optional[bool] = None
    knobs: dict = dataclasses.field(default_factory=dict)
    where: Optional[pred.Predicate] = None
    # Extra metric labels, e.g. (("namespace", ns), ("collection", name))
    # from TenantRegistry.searcher: when set, every call counts one
    # ``tenancy.requests`` and lands in the ``tenancy.search_us`` histogram /
    # ``tenancy.errors`` counter under those labels (per-namespace serving
    # metrics, DESIGN.md §9).
    labels: tuple = ()

    def __call__(self, queries: jnp.ndarray, *,
                 allow: Optional[Allowlist] = None,
                 ) -> Tuple[np.ndarray, np.ndarray]:
        kw = dict(self.knobs)
        if self.use_kernel is not None:
            kw["use_kernel"] = self.use_kernel
        if self.interpret is not None:
            kw["interpret"] = self.interpret
        if allow is not None:
            kw["allow"] = allow
        if self.where is not None:
            kw["where"] = self.where
        if not self.labels:
            return self.index.search(queries, self.k, **kw)
        labels = dict(self.labels)
        obs.inc("tenancy.requests", **labels)
        try:
            with obs.timed_span("tenant_search",
                                histogram="tenancy.search_us", labels=labels):
                return self.index.search(queries, self.k, **kw)
        except Exception:
            obs.inc("tenancy.errors", kind="search", **labels)
            raise

    def warmup(self, batch_size: int = 1) -> "Searcher":
        enc = self.index.enc if hasattr(self.index, "enc") else \
            self.index.backend.enc
        bucket = shape_bucket(batch_size)
        self(np.zeros((bucket, enc.dim), dtype=np.float32))
        return self

# Query-execution engine (DESIGN.md §7): compiled SearchPlans, the
# shape-bucketed plan cache, the bound Searcher handle, and the
# micro-batched multi-tenant serving queue.
#
# Every search path in the repo — facade, raw backend, segmented, sharded —
# routes through plan.search_backend / plan.search_sharded, so "one index
# abstraction over many backends" (Faiss-style) is also one COMPILED
# abstraction: same keying, same bucketing, same hit/miss/trace accounting.

from .batcher import BatcherStats, MicroBatcher, Ticket
from .fusion import search_hybrid
from .plan import (PlanCache, PlanKey, PlanStats, SearchPlan, Searcher,
                   plan_cache, search_backend, search_sharded, shape_bucket)

__all__ = [
    "BatcherStats", "MicroBatcher", "Ticket",
    "PlanCache", "PlanKey", "PlanStats", "SearchPlan", "Searcher",
    "plan_cache", "search_backend", "search_hybrid", "search_sharded",
    "shape_bucket",
]

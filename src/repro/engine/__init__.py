# Query-execution engine (DESIGN.md §7): compiled SearchPlans, the
# shape-bucketed plan cache, the bound Searcher handle, and the
# micro-batched multi-tenant serving queue.
#
# Every search path in the repo — facade, raw backend, segmented, sharded —
# routes through plan.search_backend / plan.search_sharded, so "one index
# abstraction over many backends" (Faiss-style) is also one COMPILED
# abstraction: same keying, same bucketing, same hit/miss/trace accounting.

from repro.obs import DeltaStats  # noqa: F401 — back-compat re-export: the
#   shared snapshot/since mixin PlanStats and BatcherStats now inherit.

from .batcher import BatcherStats, MicroBatcher, Ticket
from .plan import (PlanCache, PlanKey, PlanStats, SearchPlan, Searcher,
                   plan_cache, plan_key_digest, resolve_knobs,
                   search_backend, search_sharded, set_stage_observer,
                   shape_bucket)
from .fusion import search_hybrid

__all__ = [
    "BatcherStats", "DeltaStats", "MicroBatcher", "Ticket",
    "PlanCache", "PlanKey", "PlanStats", "SearchPlan", "Searcher",
    "plan_cache", "plan_key_digest", "resolve_knobs", "search_backend",
    "search_hybrid", "search_sharded", "set_stage_observer", "shape_bucket",
]

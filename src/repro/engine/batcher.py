"""Micro-batched multi-tenant serving queue (DESIGN.md §7).

The paper's service layer answers one HTTP request at a time; at "millions
of users" scale the winning shape is the classic serving micro-batch:
requests arriving across calls (and across tenants) are queued, coalesced
per **(namespace, collection, k, where, hybrid?, knobs)** group, and
executed as ONE bucketed SearchPlan call per group — so ten 3-query
requests cost one 32-bucket plan execution instead of ten traces/
dispatches.  Filtered (``where=``) and hybrid (``text=``) requests coalesce
the same way: identical predicates share a group, same-structure
predicates with different constants share a compiled plan (DESIGN.md §8).

Because bucketed plan execution is bit-identical to direct search (plan.py),
coalescing is invisible to callers: every request gets exactly the rows a
solo ``index.search`` would have returned, in submission order.  Isolation
is structural — the group key contains the resolved namespace, so two
tenants' queries can never share a plan execution, and authentication
failures surface at ``submit`` time (the 401 contract of TenantRegistry).

    batcher = MicroBatcher(registry)
    t1 = batcher.submit(tok_a, "docs", q1, k=10)
    t2 = batcher.submit(tok_b, "docs", q2, k=10)    # different tenant
    scores, ids = t1.result()                       # flushes the queue
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs


@dataclasses.dataclass
class BatcherStats(obs.DeltaStats):
    """``snapshot``/``since`` come from the shared obs.DeltaStats mixin;
    the same counts also land in the metrics registry (``batcher.*``)."""

    requests: int = 0      # submit() calls accepted
    rows: int = 0          # total query rows submitted
    executions: int = 0    # plan executions issued by flush()
    flushes: int = 0


class Ticket:
    """Handle for one submitted request; ``result()`` flushes if needed."""

    __slots__ = ("_batcher", "_result", "_error")

    def __init__(self, batcher: "MicroBatcher") -> None:
        self._batcher = batcher
        self._result: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        """(scores [m,k], ids [m,k]) for this request's rows — identical to
        what a direct ``index.search`` on the same queries returns.  If this
        request's group failed (e.g. invalid knobs for the collection's
        backend), the failure re-raises HERE, on the affected tickets only."""
        if not self.done():
            self._batcher.flush()
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


@dataclasses.dataclass
class _Group:
    """One coalescible (namespace, collection, k, where, knobs) stream."""

    token: Optional[str]          # any token resolving to this namespace
    namespace: str                # resolved at submit — metric label only
    collection: str
    k: int
    knobs: tuple
    where: Optional[object] = None          # predicate bound to every row
    queries: List[np.ndarray] = dataclasses.field(default_factory=list)
    texts: Optional[List[List[str]]] = None   # hybrid: texts per request
    tickets: List[Ticket] = dataclasses.field(default_factory=list)


class MicroBatcher:
    """Cross-request, cross-tenant query coalescing over a TenantRegistry.

    ``submit`` never executes; ``flush`` drains every group with as few
    bucketed plan executions as possible (whole requests are packed into
    batches of at most ``max_batch`` rows; an oversized single request runs
    alone rather than being split).  Dispatch overrides (``use_kernel`` /
    ``interpret``) apply batcher-wide: they are part of every group's
    execution, exactly like a serve-loop flag.
    """

    def __init__(
        self,
        registry,
        *,
        max_batch: int = 1024,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
    ) -> None:
        self.registry = registry
        self.max_batch = int(max_batch)
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.stats = BatcherStats()
        self._groups: Dict[tuple, _Group] = {}

    # -- enqueue -----------------------------------------------------------

    def submit(
        self,
        token: Optional[str],
        collection: str,
        queries,
        *,
        k: int = 10,
        where=None,
        text=None,
        **knobs,
    ) -> Ticket:
        """Queue one request; auth AND collection existence resolve NOW
        (401 = PermissionError, missing collection = KeyError, both here —
        never poisoning other tenants' flush).  Execution happens at the
        next ``flush()``.

        ``where=`` is a metadata predicate (DESIGN.md §8); predicates are
        frozen (hashable), so identical predicates coalesce into one group
        while same-structure/different-constant predicates form separate
        groups that still share one compiled plan.  ``text=`` (a str, or one
        str per query row) routes the group through the hybrid engine path —
        texts concatenate alongside the query rows."""
        ns = self.registry.resolve_namespace(token)
        if ns is None:
            raise PermissionError("401: token rejected")
        self.registry.get(token, collection)    # missing collection: raise now
        q = np.atleast_2d(np.asarray(queries, dtype=np.float32))
        texts: Optional[List[str]] = None
        if text is not None:
            texts = [text] * int(q.shape[0]) if isinstance(text, str) \
                else list(text)
            if len(texts) != int(q.shape[0]):
                raise ValueError(
                    f"submit: {q.shape[0]} query rows but {len(texts)} texts")
        key = (ns, collection, k, where, texts is not None,
               tuple(sorted(knobs.items())))
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _Group(
                token=token, namespace=ns, collection=collection, k=k,
                knobs=tuple(sorted(knobs.items())), where=where,
                texts=[] if texts is not None else None)
        ticket = Ticket(self)
        group.queries.append(q)
        if texts is not None:
            group.texts.append(texts)
        group.tickets.append(ticket)
        self.stats.requests += 1
        self.stats.rows += int(q.shape[0])
        obs.inc("batcher.requests", **{"namespace": ns})
        obs.inc("batcher.rows", int(q.shape[0]), **{"namespace": ns})
        obs.set_gauge("batcher.queue_depth", self.pending)
        obs.set_gauge("batcher.queued_rows", self.pending_rows)
        return ticket

    @property
    def pending(self) -> int:
        return sum(len(g.tickets) for g in self._groups.values())

    @property
    def pending_rows(self) -> int:
        return sum(int(q.shape[0]) for g in self._groups.values()
                   for q in g.queries)

    # -- drain -------------------------------------------------------------

    def _execute(self, group: _Group, queries: List[np.ndarray],
                 tickets: List[Ticket],
                 texts: Optional[List[List[str]]] = None) -> None:
        """Run one coalesced chunk; a failure (stale collection, knobs the
        collection's backend rejects, ...) is delivered to THIS chunk's
        tickets — other groups and chunks are isolated and still execute."""
        labels = {"namespace": group.namespace}
        rows = sum(int(q.shape[0]) for q in queries)
        with obs.timed_span("batcher.execute", histogram="batcher.flush_us",
                            labels=labels,
                            attrs={"namespace": group.namespace,
                                   "collection": group.collection,
                                   "requests": len(tickets), "rows": rows}):
            # Coalescing factor: requests folded into this one plan call.
            obs.observe("batcher.coalesced_requests", len(tickets),
                        edges=obs.DEFAULT_COUNT_EDGES, **labels)
            try:
                index = self.registry.get(group.token, group.collection)
                kw = dict(group.knobs)
                if self.use_kernel is not None:
                    kw["use_kernel"] = self.use_kernel
                if self.interpret is not None:
                    kw["interpret"] = self.interpret
                if group.where is not None:
                    kw["where"] = group.where
                qcat = queries[0] if len(queries) == 1 \
                    else np.concatenate(queries)
                if texts is not None:
                    tcat = [t for ts in texts for t in ts]
                    scores, ids = index.search(qcat, tcat, k=group.k, **kw)
                else:
                    scores, ids = index.search(qcat, k=group.k, **kw)
            except Exception as e:  # noqa: BLE001 — re-raised at result()
                obs.inc("batcher.errors", **labels)
                for t in tickets:
                    t._error = e
                return
            self.stats.executions += 1
            obs.inc("batcher.executions", **labels)
            with obs.timed_span("batcher.scatter",
                                attrs={"requests": len(tickets)}):
                off = 0
                for q, t in zip(queries, tickets):
                    m = q.shape[0]
                    t._result = (scores[off: off + m], ids[off: off + m])
                    off += m

    def flush(self) -> int:
        """Execute every pending group; returns the number of plan
        executions attempted.  Request order within a group is preserved by
        construction (concat order == submission order)."""
        groups, self._groups = self._groups, {}
        executions = 0
        for group in groups.values():
            chunk_q: List[np.ndarray] = []
            chunk_t: List[Ticket] = []
            chunk_x: Optional[List[List[str]]] = \
                [] if group.texts is not None else None
            rows = 0
            texts = group.texts or [None] * len(group.queries)
            for q, x, t in zip(group.queries, texts, group.tickets):
                if chunk_q and rows + q.shape[0] > self.max_batch:
                    self._execute(group, chunk_q, chunk_t, chunk_x)
                    executions += 1
                    chunk_q, chunk_t, rows = [], [], 0
                    chunk_x = [] if group.texts is not None else None
                chunk_q.append(q)
                chunk_t.append(t)
                if chunk_x is not None:
                    chunk_x.append(x)
                rows += int(q.shape[0])
            if chunk_q:
                self._execute(group, chunk_q, chunk_t, chunk_x)
                executions += 1
        if executions:
            self.stats.flushes += 1
            obs.inc("batcher.flushes")
        obs.set_gauge("batcher.queue_depth", self.pending)
        obs.set_gauge("batcher.queued_rows", self.pending_rows)
        return executions

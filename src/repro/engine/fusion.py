"""Hybrid dense+sparse retrieval as an engine path (DESIGN.md §8, paper §3.6).

The pre-engine ``HybridIndex.search`` was a side-door: single-query only
(it silently dropped every query row past the first), brute-force only, and
it bypassed ``repro.engine`` entirely — no plan cache, no bucketing, no
micro-batch coalescing.  This module routes the dense channel through the
same compiled ``SearchPlan`` as every other search (predicate mask stage
included), keeps BM25 as the host-side stage it semantically is, and fuses
with the deterministic host RRF merge:

  1. dense channel — one bucketed ``search_backend`` call over the WHOLE
     query batch, with ``allow`` and ``where`` compiled into the plan's
     live-mask stage;
  2. sparse channel — per-row BM25 top-k with the SAME combined
     allowlist ∧ predicate row mask applied BEFORE the top-k (§3.5: both
     channels pre-filter, so selective filters still surface ``fetch_k``
     candidates per channel instead of a post-filtered remnant);
  3. RRF merge — ``rrf_fuse`` per row, ties by smaller id.

Contract: a single query (1-D ``query_vec``, ``str`` text) returns exactly
the pre-refactor 1-D ``(scores, ids)`` — possibly shorter than ``k`` when
the candidate pool is small (pinned bit-for-bit by the golden fixture).  A
batch returns ``[b, k]`` arrays, rows independently identical to their
single-query results, padded with id -1 / score 0.0.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro import obs
from repro.core import predicate as pred
from repro.core.allowlist import Allowlist
from repro.core.rrf import rrf_fuse
from repro.core.segments import SENTINEL_ID

from .plan import search_backend

#: repro.analysis coverage hook (DESIGN.md §10): the dense channel of
#: ``search_hybrid`` runs as an ordinary compiled SearchPlan (every stage
#: captured through plan.py's observer); this export makes the hybrid path
#: enumerable so the auditor's grid provably drives it.
PLAN_STAGES = ("search_hybrid",)


def _sparse_mask(index, allow: Optional[Allowlist],
                 where: Optional[pred.Predicate]) -> Optional[np.ndarray]:
    """The combined allowlist ∧ predicate row mask for the BM25 channel.

    Evaluated host-side against the exact original column values — the same
    oracle the dense channel's compiled mask stage is pinned to, so both
    channels filter identically.
    """
    mask = None if allow is None else np.asarray(allow.mask, dtype=bool)
    if where is not None:
        if index.meta is None or not index.meta:
            raise ValueError(
                "where= requires a hybrid index built with metadata columns")
        pred.validate(where, index.meta)
        pm = pred.evaluate(where, index.meta)
        mask = pm if mask is None else mask & pm
    return mask


def search_hybrid(
    index,                                   # HybridIndex
    query_vec,
    query_text: Union[str, Sequence[str]],
    k: int = 10,
    *,
    fetch_k: Optional[int] = None,
    rrf_k: int = 60,
    allow: Optional[Allowlist] = None,
    where: Optional[pred.Predicate] = None,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Filtered hybrid search through the compiled engine (module docstring).

    ``query_vec`` is [d] (with a ``str`` text) or [b, d] (with ``b`` texts);
    rows past the first are first-class — each gets its own BM25 channel and
    RRF merge against its slice of the one batched dense scan.
    """
    fetch_k = fetch_k or max(2 * k, 20)
    qv = np.asarray(query_vec)
    single = qv.ndim == 1
    texts = [query_text] if isinstance(query_text, str) else list(query_text)
    b = 1 if single else int(qv.shape[0])
    if len(texts) != b:
        raise ValueError(
            f"hybrid search: {b} query rows but {len(texts)} query texts")
    for t in texts:
        if not isinstance(t, str):
            raise TypeError(f"query text must be a string, got {t!r}")

    obs.inc("engine.hybrid_searches")
    # Dense channel: ONE bucketed plan execution for the whole batch, the
    # predicate compiled into the plan's mask stage (plan.py).
    _, dense_ids = search_backend(
        index.dense, None, qv, fetch_k, allow=allow, where=where,
        meta=index.meta, use_kernel=use_kernel, interpret=interpret,
    )

    with obs.timed_span("hybrid.sparse_fuse", histogram="engine.stage_us",
                        labels={"backend": "HybridIndex",
                                "stage": "sparse_fuse"},
                        attrs={"rows": b}):
        return _fuse_rows(index, texts, dense_ids, allow, where,
                          fetch_k, rrf_k, k, b, single)


def _fuse_rows(index, texts, dense_ids, allow, where, fetch_k, rrf_k, k,
               b, single):
    mask = _sparse_mask(index, allow, where)
    corpus_ids = np.asarray(index.dense.ids)

    out_vals = np.zeros((b, k), dtype=np.float32)
    out_ids = np.full((b, k), -1, dtype=np.int64)
    for i in range(b):
        # A selective filter can return fewer than fetch_k real rows;
        # SENTINEL_ID slots must not enter the fusion as if they were docs.
        drow = dense_ids[i]
        drow = drow[drow != SENTINEL_ID]
        _, sparse_rows = index.sparse.search(texts[i], fetch_k,
                                             allow_mask=mask)
        sparse_ids = corpus_ids[sparse_rows]
        vals, ids = rrf_fuse([drow, sparse_ids], k=rrf_k, top_k=k)
        if single:
            return vals, ids
        m = ids.shape[0]
        out_vals[i, :m] = vals
        out_ids[i, :m] = ids
    return out_vals, out_ids

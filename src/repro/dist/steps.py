"""Dry-run cell construction: one jit-able step per (arch x shape x variant).

A Cell bundles everything ``launch.dryrun`` needs to lower + compile a
production step on a mesh WITHOUT allocating parameters: the step fn, its
argument ShapeDtypeStructs (with NamedShardings attached per
``dist.sharding``), donation, and an analytic MODEL_FLOPS term for the
roofline tables.

Variants (LM family):
  baseline   python-unrolled layer stack — XLA cost_analysis counts a scanned
             while-loop body once regardless of trip count, so only the
             unrolled form reports true FLOPs.  Carries a scan-form memory
             twin (fn_mem): XLA:CPU's scheduler keeps far more live in the
             unrolled form than a real job would.
  scan       the production (lax.scan) form itself — compact HLO, the
             memory/collective artifact for heavy archs.
  probeN     unrolled at reduced depth N — per-layer costs extrapolate
             linearly to full depth (benchmarks.roofline._extrapolate).

This module is also the home of the per-arch init/loss tables the training
launcher and smoke tests share (_RS_INIT / _RS_LOSS).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import data_axes
from repro.models import gnn as gnn_m
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig, init_opt_state, make_train_step

from . import sharding as shd
from .partition import data_axis_size

# Per-arch recsys init/loss tables (shared with launch.train + smoke tests).
_RS_INIT = {
    "dlrm-rm2": rs.dlrm_init,
    "dien": rs.dien_init,
    "fm": rs.fm_init,
    "two-tower-retrieval": rs.two_tower_init,
}
_RS_LOSS = {
    "dlrm-rm2": rs.dlrm_loss,
    "dien": rs.dien_loss,
    "fm": rs.fm_loss,
    "two-tower-retrieval": rs.two_tower_loss,
}


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one dry-run cell (see launch.dryrun)."""
    step_name: str
    model_flops: float
    fn: Callable
    args: Tuple[Any, ...]
    out_shardings: Any = None
    donate: Tuple[int, ...] = ()
    # Optional memory twin (production scan form of an unrolled cell).
    fn_mem: Optional[Callable] = None
    args_mem: Optional[Tuple[Any, ...]] = None
    out_shardings_mem: Any = None
    donate_mem: Tuple[int, ...] = ()


# ---------------------------------------------------------------------------
# Shared helpers.
# ---------------------------------------------------------------------------

_KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _struct(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype),
                                sharding=sharding)


def _eval_params(init_fn):
    return jax.eval_shape(init_fn, _KEY)


def _maybe_batch(mesh, axes, ndim: int, dim0: int):
    """Batch sharding over the data axes iff dim0 divides evenly."""
    n = data_axis_size(mesh)
    if n > 1 and dim0 % n == 0:
        return shd.batch_sharding(mesh, ndim, axes)
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P())


def _count_params(struct_tree, exclude: str = "") -> int:
    """Total leaf elements, minus paths matching `exclude` (regex)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(struct_tree)[0]:
        if exclude and re.search(exclude, jax.tree_util.keystr(path)):
            continue
        total += int(np.prod(leaf.shape))
    return total


def _train_cell(step_name, loss_fn, params_struct, param_shardings, mesh,
                axes, batch_structs, flops, *, rules, moment_dtype="float32"):
    """Assemble a train-step Cell: fn(params, opt, *batch) with donation."""
    ocfg = AdamWConfig(moment_dtype=moment_dtype)
    opt_struct = jax.eval_shape(lambda p: init_opt_state(p, ocfg),
                                params_struct)
    # The rule regexes are sub-path matches, so they apply unchanged under
    # the opt state's ['m'] / ['v'] prefixes.
    opt_shardings = shd.tree_shardings(opt_struct, mesh, rules)
    step = make_train_step(loss_fn, ocfg)

    def fn(params, opt, *batch):
        return step(params, opt, batch)

    args = (shd.with_shardings(params_struct, param_shardings),
            shd.with_shardings(opt_struct, opt_shardings)) + tuple(batch_structs)
    return Cell(step_name=step_name, model_flops=flops, fn=fn, args=args,
                donate=(0, 1))


# ---------------------------------------------------------------------------
# LM cells.
# ---------------------------------------------------------------------------

def _parse_variant(variant: str, n_layers: int) -> Tuple[bool, int]:
    """variant -> (unroll, depth)."""
    if variant == "scan":
        return False, n_layers
    m = re.fullmatch(r"probe(\d+)", variant)
    if m:
        return True, int(m.group(1))
    if variant != "baseline":      # a typo'd variant must not silently run
        raise ValueError(f"unknown LM variant {variant!r} "
                         "(expected baseline | scan | probeN)")
    return True, n_layers


def _lm_cfg(arch, mesh, *, unroll: bool, depth: int, kind: str):
    cfg = arch.make_config()
    axes = data_axes(mesh)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, dp_axes=axes, ep_axis="model",
            first_dense_layers=min(moe.first_dense_layers, max(depth - 1, 0)))
    return dataclasses.replace(
        cfg, n_layers=depth, unroll=unroll, moe=moe,
        dp_axes=axes, vocab_shard="model",
        loss_chunk=2048 if kind == "train" else 0,
    )


def _lm_flops(cfg, batch: int, seq: int, *, mode: str) -> float:
    """Analytic global-batch FLOPs: 2*active_params*tokens matmul term plus
    the attention score/value term (window-aware), x3 for backward."""
    n_act = cfg.active_param_count()
    d_attn = cfg.n_heads * cfg.head_dim
    if mode == "decode":
        matmul = 2.0 * n_act * batch
        attn = sum(4.0 * batch * min(seq, w if w > 0 else seq) * d_attn
                   for w in cfg.layer_windows())
        return matmul + attn
    matmul = 2.0 * n_act * batch * seq
    attn = sum(4.0 * batch * seq * min(seq, w if w > 0 else seq) * d_attn
               for w in cfg.layer_windows())
    fwd = matmul + attn
    return 3.0 * fwd if mode == "train" else fwd


def _decode_cache_structs(cfg, mesh, axes, batch: int, max_len: int):
    cache = jax.eval_shape(
        lambda: tf.init_decode_cache(cfg, batch, max_len))
    from jax.sharding import NamedSharding, PartitionSpec as P
    n_data = data_axis_size(mesh)
    shard_batch = batch % n_data == 0 and n_data > 1

    def sh(leaf):
        # [L, B, S, KV, dh] (GQA) or [L, B, S, C] (MLA latent).
        spec = [None] * len(leaf.shape)
        if shard_batch:
            spec[1] = axes
        else:
            spec[2] = axes             # long-context: sequence-sharded cache
        if (len(leaf.shape) == 5
                and leaf.shape[3] % mesh.shape["model"] == 0):
            spec[3] = "model"          # KV heads over the model axis
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(lambda l: _struct(l.shape, l.dtype, sh(l)), cache), \
        shard_batch


def _build_lm(arch, shape, mesh, variant: str) -> Cell:
    axes = data_axes(mesh)
    kind = shape.kind
    dims = shape.dims
    seq, batch = dims["seq_len"], dims["global_batch"]
    unroll, depth = _parse_variant(variant, arch.make_config().n_layers)
    cfg = _lm_cfg(arch, mesh, unroll=unroll, depth=depth, kind=kind)
    params_struct = _eval_params(lambda k: tf.init_params(cfg, k))
    p_shard = shd.tree_shardings(params_struct, mesh, shd.LM_RULES)
    flops = _lm_flops(cfg, batch, seq,
                      mode="train" if kind == "train" else
                      ("decode" if kind == "decode" else "prefill"))

    if kind == "train":
        tok = _struct((batch, seq), jnp.int32,
                      _maybe_batch(mesh, axes, 2, batch))
        cell = _train_cell(
            "lm_train_step", lambda p, b: tf.lm_loss(p, cfg, b[0]),
            params_struct, p_shard, mesh, axes, (tok,), flops,
            rules=shd.LM_RULES,
            moment_dtype="bfloat16" if cfg.moe else "float32")
        cell.step_name = f"lm_train[{variant}]"
        if unroll and variant == "baseline":
            _attach_scan_twin(cell, arch, shape, mesh)
        return cell

    if kind == "prefill":
        tok = _struct((batch, seq), jnp.int32,
                      _maybe_batch(mesh, axes, 2, batch))

        def fn(params, tokens):
            return tf.prefill(params, cfg, tokens, last_only=True)

        cell = Cell(step_name=f"lm_prefill[{variant}]", model_flops=flops,
                    fn=fn, args=(shd.with_shardings(params_struct, p_shard),
                                 tok))
        if unroll and variant == "baseline":
            _attach_scan_twin(cell, arch, shape, mesh)
        return cell

    # decode: one token against a [*, batch, seq] cache.
    cache_structs, shard_batch = _decode_cache_structs(cfg, mesh, axes,
                                                       batch, seq)
    if not shard_batch:
        # Sequence-sharded cache (gemma2 long_500k): attend over the sharded
        # key axis; wsc constraints inside attention keep the tile sharded.
        # dp_axes must be dropped — a mesh axis can map to one dim only, and
        # at global_batch=1 there is nothing to data-parallelize anyway.
        cfg = dataclasses.replace(cfg, attn_seq_shard=axes[-1],
                                  attn_seq_axis="kv", dp_axes=None)
    tok = _struct((batch, 1), jnp.int32,
                  _maybe_batch(mesh, axes, 2, batch))
    cur = _struct((), jnp.int32)

    def fn(params, cache, tokens, cur_len):
        return tf.decode_step(params, cfg, cache, tokens, cur_len)

    cell = Cell(step_name=f"lm_decode[{variant}]", model_flops=flops, fn=fn,
                args=(shd.with_shardings(params_struct, p_shard),
                      cache_structs, tok, cur),
                donate=(1,))
    if unroll and variant == "baseline":
        _attach_scan_twin(cell, arch, shape, mesh)
    return cell


def _attach_scan_twin(cell: Cell, arch, shape, mesh) -> None:
    """Give an unrolled cell its production (scan) memory twin."""
    twin = _build_lm(arch, shape, mesh, "scan")
    cell.fn_mem = twin.fn
    cell.args_mem = twin.args
    cell.out_shardings_mem = twin.out_shardings
    cell.donate_mem = twin.donate


# ---------------------------------------------------------------------------
# GNN cells.
# ---------------------------------------------------------------------------

def _gnn_flops(cfg, n_nodes: int, n_edges: int, train: bool) -> float:
    per_node = 0.0
    for i in range(cfg.n_layers):
        d_in = cfg.d_feat if i == 0 else cfg.d_hidden
        per_node += 2.0 * (d_in * cfg.d_hidden + cfg.d_hidden * cfg.d_hidden)
    fwd = n_nodes * per_node + 2.0 * n_edges * cfg.d_hidden  # + scatter adds
    return 3.0 * fwd if train else fwd


def _build_gnn(arch, shape, mesh, variant: str) -> Cell:
    axes = data_axes(mesh)
    dims = shape.dims
    base = arch.make_config()

    if shape.kind == "minibatch":
        cfg = dataclasses.replace(base, d_feat=dims["d_feat"],
                                  n_classes=dims["n_classes"],
                                  n_layers=2)   # depth = len(fanout)
        b = dims["batch_nodes"]
        f0, f1 = dims["fanout0"], dims["fanout1"]
        # Worst-case nested frontiers (sampler guarantees <= these).
        n1 = b + b * f0
        e_outer, e_inner = n1 * f1, b * f0
        n2 = n1 + e_outer
        params_struct = _eval_params(lambda k: gnn_m.init_params(cfg, k))
        p_shard = shd.tree_shardings(params_struct, mesh, shd.GNN_RULES)

        def loss_fn(p, batch):
            feats, sa, da, sb, db, labels = batch
            logits = gnn_m.forward_sampled(p, cfg, feats,
                                           [(sa, da, n1), (sb, db, b)])
            return gnn_m.nll_loss(logits, labels)

        batch_structs = (
            _struct((n2, cfg.d_feat), jnp.float32),
            _struct((e_outer,), jnp.int32, _maybe_batch(mesh, axes, 1, e_outer)),
            _struct((e_outer,), jnp.int32, _maybe_batch(mesh, axes, 1, e_outer)),
            _struct((e_inner,), jnp.int32, _maybe_batch(mesh, axes, 1, e_inner)),
            _struct((e_inner,), jnp.int32, _maybe_batch(mesh, axes, 1, e_inner)),
            _struct((b,), jnp.int32),
        )
        flops = _gnn_flops(cfg, n2, e_outer + e_inner, True)
        return _train_cell("gnn_minibatch_train", loss_fn, params_struct,
                           p_shard, mesh, axes, batch_structs, flops,
                           rules=shd.GNN_RULES)

    if shape.kind == "graphs":
        cfg = dataclasses.replace(base, d_feat=dims["d_feat"],
                                  n_classes=dims["n_classes"],
                                  readout="graph")
        g = dims["batch"]
        n, e = dims["n_nodes"] * g, dims["n_edges"] * g
        params_struct = _eval_params(lambda k: gnn_m.init_params(cfg, k))
        p_shard = shd.tree_shardings(params_struct, mesh, shd.GNN_RULES)
        gid = np.repeat(np.arange(g), dims["n_nodes"])

        def loss_fn(p, batch):
            x, src, dst, labels = batch
            logits = gnn_m.forward_full(p, cfg, x, src, dst,
                                        graph_ids=jnp.asarray(gid), n_graphs=g)
            return gnn_m.nll_loss(logits, labels)

        batch_structs = (
            _struct((n, cfg.d_feat), jnp.float32,
                    _maybe_batch(mesh, axes, 2, n)),
            _struct((e,), jnp.int32, _maybe_batch(mesh, axes, 1, e)),
            _struct((e,), jnp.int32, _maybe_batch(mesh, axes, 1, e)),
            _struct((g,), jnp.int32),
        )
        return _train_cell("gnn_graphs_train", loss_fn, params_struct,
                           p_shard, mesh, axes, batch_structs,
                           _gnn_flops(cfg, n, e, True), rules=shd.GNN_RULES)

    # full_graph (cora-like / ogbn-products-like).
    cfg = dataclasses.replace(base, d_feat=dims["d_feat"],
                              n_classes=dims["n_classes"])
    n, e = dims["n_nodes"], dims["n_edges"]
    params_struct = _eval_params(lambda k: gnn_m.init_params(cfg, k))
    p_shard = shd.tree_shardings(params_struct, mesh, shd.GNN_RULES)

    def loss_fn(p, batch):
        x, src, dst, labels = batch
        return gnn_m.nll_loss(gnn_m.forward_full(p, cfg, x, src, dst), labels)

    batch_structs = (
        _struct((n, cfg.d_feat), jnp.float32, _maybe_batch(mesh, axes, 2, n)),
        _struct((e,), jnp.int32, _maybe_batch(mesh, axes, 1, e)),
        _struct((e,), jnp.int32, _maybe_batch(mesh, axes, 1, e)),
        _struct((n,), jnp.int32),
    )
    return _train_cell("gnn_full_graph_train", loss_fn, params_struct,
                       p_shard, mesh, axes, batch_structs,
                       _gnn_flops(cfg, n, e, True), rules=shd.GNN_RULES)


# ---------------------------------------------------------------------------
# RecSys cells.
# ---------------------------------------------------------------------------

# Embedding-table paths = exactly what RECSYS_RULES shards (one source of
# truth); DLRM/FM tables are indexed lists, so a dense layer's terminal
# ['w'] never matches.
_RS_TABLES = "|".join(pat for pat, _ in shd.RECSYS_RULES)


def _rs_batch_structs(arch_id: str, cfg, batch: int, mesh, axes,
                      serve: bool = False):
    bsh1 = _maybe_batch(mesh, axes, 1, batch)
    bsh2 = _maybe_batch(mesh, axes, 2, batch)
    if arch_id == "dlrm-rm2":
        d = {"dense": _struct((batch, cfg.n_dense), jnp.float32, bsh2),
             "sparse": _struct((batch, cfg.n_sparse), jnp.int32, bsh2)}
    elif arch_id == "dien":
        d = {"hist_items": _struct((batch, cfg.seq_len), jnp.int32, bsh2),
             "hist_cats": _struct((batch, cfg.seq_len), jnp.int32, bsh2),
             "target_item": _struct((batch,), jnp.int32, bsh1),
             "target_cat": _struct((batch,), jnp.int32, bsh1)}
    elif arch_id == "fm":
        d = {"sparse": _struct((batch, cfg.n_sparse), jnp.int32, bsh2)}
    else:  # two-tower-retrieval
        d = {"user_hist": _struct((batch, cfg.n_user_feats), jnp.int32, bsh2),
             "item_id": _struct((batch,), jnp.int32, bsh1),
             "item_freq": _struct((batch,), jnp.float32, bsh1)}
    if not serve and arch_id != "two-tower-retrieval":
        d["label"] = _struct((batch,), jnp.int32, bsh1)
    return d


def _rs_forward(arch_id: str, params, cfg, batch):
    if arch_id == "dlrm-rm2":
        return rs.dlrm_forward(params, cfg, batch["dense"], batch["sparse"])
    if arch_id == "dien":
        return rs.dien_forward(params, cfg, batch)
    if arch_id == "fm":
        return rs.fm_forward(params, cfg, batch["sparse"])
    u = rs.user_embedding(params, cfg, batch["user_hist"])
    v = rs.item_embedding(params, cfg, batch["item_id"])
    return jnp.sum(u * v, axis=-1)


def _rs_flops(arch_id: str, params_struct, cfg, batch: int,
              train: bool) -> float:
    dense_params = _count_params(params_struct, exclude=_RS_TABLES)
    fwd = 2.0 * dense_params * batch
    if arch_id == "dien":  # recurrences run seq_len steps over [B, H]
        fwd *= cfg.seq_len / 4.0
    return 3.0 * fwd if train else fwd


def _build_recsys(arch, shape, mesh, variant: str) -> Cell:
    axes = data_axes(mesh)
    arch_id = arch.arch_id
    cfg = arch.make_config()
    init, loss = _RS_INIT[arch_id], _RS_LOSS[arch_id]
    params_struct = _eval_params(lambda k: init(cfg, k))
    p_shard = shd.tree_shardings(params_struct, mesh, shd.RECSYS_RULES)

    if shape.kind == "recsys_train":
        batch = shape.dims["batch"]
        structs = _rs_batch_structs(arch_id, cfg, batch, mesh, axes)

        def loss_fn(p, b):
            return loss(p, cfg, b[0])

        return _train_cell(f"{arch_id}_train", loss_fn, params_struct,
                           p_shard, mesh, axes, (structs,),
                           _rs_flops(arch_id, params_struct, cfg, batch, True),
                           rules=shd.RECSYS_RULES)

    if shape.kind == "recsys_serve":
        batch = shape.dims["batch"]
        structs = _rs_batch_structs(arch_id, cfg, batch, mesh, axes,
                                    serve=True)

        def fn(params, b):
            return _rs_forward(arch_id, params, cfg, b)

        return Cell(step_name=f"{arch_id}_serve",
                    model_flops=_rs_flops(arch_id, params_struct, cfg, batch,
                                          False),
                    fn=fn,
                    args=(shd.with_shardings(params_struct, p_shard), structs))

    # retrieval_cand: 1 user vs n_candidates items.
    n_cand = shape.dims["n_candidates"]
    return _build_rs_retrieval(arch_id, cfg, params_struct, p_shard, mesh,
                               axes, n_cand)


def _build_rs_retrieval(arch_id, cfg, params_struct, p_shard, mesh, axes,
                        n_cand: int) -> Cell:
    csh1 = _maybe_batch(mesh, axes, 1, n_cand)
    csh2 = _maybe_batch(mesh, axes, 2, n_cand)

    if arch_id == "two-tower-retrieval":
        # The paper's own setting: the user vector scans a PACKED 4-bit item
        # corpus through the dist.retrieval kernels (see configs/recsys notes).
        from repro.core.rhdh import next_pow2, rhdh_apply
        from repro.core.standardize import COSINE, prepare
        from repro.dist.retrieval import scan_topk_pjit
        d_pad = next_pow2(cfg.embed_dim)
        structs = (
            shd.with_shardings(params_struct, p_shard),
            _struct((1, cfg.n_user_feats), jnp.int32),
            _struct((n_cand, d_pad // 2), jnp.uint8, csh2),
            _struct((n_cand,), jnp.float32, csh1),
        )

        def fn(params, user_hist, packed, qnorms):
            u = rs.user_embedding(params, cfg, user_hist)
            q_rot = rhdh_apply(prepare(u, COSINE), 0x6D6F6E61,
                               normalized=False)
            return scan_topk_pjit(q_rot, packed, qnorms, metric=COSINE, k=10)

        flops = 2.0 * n_cand * d_pad + 2.0 * _count_params(
            params_struct, exclude=_RS_TABLES)
        return Cell(step_name="two_tower_packed_scan", model_flops=flops,
                    fn=fn, args=structs)

    if arch_id == "dien":
        # One user history broadcast against every candidate (AUGRU
        # re-evolved per candidate — the DIEN scoring semantics).
        structs = (
            shd.with_shardings(params_struct, p_shard),
            _struct((1, cfg.seq_len), jnp.int32),
            _struct((1, cfg.seq_len), jnp.int32),
            _struct((n_cand,), jnp.int32, csh1),
            _struct((n_cand,), jnp.int32, csh1),
        )

        def fn(params, hist_items, hist_cats, target_item, target_cat):
            batch = {
                "hist_items": jnp.broadcast_to(hist_items,
                                               (n_cand, cfg.seq_len)),
                "hist_cats": jnp.broadcast_to(hist_cats,
                                              (n_cand, cfg.seq_len)),
                "target_item": target_item, "target_cat": target_cat,
            }
            return rs.dien_forward(params, cfg, batch)

        return Cell(step_name="dien_candidate_scan",
                    model_flops=_rs_flops("dien", params_struct, cfg, n_cand,
                                          False),
                    fn=fn, args=structs)

    # dlrm / fm: pointwise scoring of the candidate batch.
    structs_d = _rs_batch_structs(arch_id, cfg, n_cand, mesh, axes,
                                  serve=True)

    def fn(params, b):
        return _rs_forward(arch_id, params, cfg, b)

    return Cell(step_name=f"{arch_id}_candidate_scan",
                model_flops=_rs_flops(arch_id, params_struct, cfg, n_cand,
                                      False),
                fn=fn,
                args=(shd.with_shardings(params_struct, p_shard), structs_d))


# ---------------------------------------------------------------------------
# Retrieval cells (monavec-scan — the paper's workload as an arch).
# ---------------------------------------------------------------------------

def _build_retrieval(arch, shape, mesh, variant: str) -> Cell:
    from repro.core.rhdh import next_pow2
    from repro.dist.retrieval import make_scan_topk_shardmap
    from .partition import corpus_sharding, shard_sizes

    cfg = arch.make_config()
    n, bq = shape.dims["n_corpus"], shape.dims["batch_q"]
    d_pad = next_pow2(cfg.dim)
    _, n_pad = shard_sizes(n, data_axis_size(mesh))

    fn = make_scan_topk_shardmap(mesh, metric=cfg.metric, k=cfg.k,
                                 bits=cfg.bits, n_valid=n)
    args = (
        _struct((bq, d_pad), jnp.float32),
        _struct((n_pad, d_pad // 2), jnp.uint8, corpus_sharding(mesh, 2)),
        _struct((n_pad,), jnp.float32, corpus_sharding(mesh, 1)),
    )
    # Same MAC count as the f32 scan (dequantization is elementwise).
    flops = 2.0 * bq * float(n) * d_pad
    return Cell(step_name="monavec_scan_shardmap", model_flops=flops, fn=fn,
                args=args)


# ---------------------------------------------------------------------------
# Entry point.
# ---------------------------------------------------------------------------

def build_cell(arch, shape, mesh, variant: str = "baseline") -> Cell:
    """Construct the dry-run Cell for one (arch, shape) on a mesh.

    Struct-level only: parameters and batches are ShapeDtypeStructs with
    NamedShardings attached — nothing is allocated until dryrun compiles.
    """
    if arch.family == "lm":
        return _build_lm(arch, shape, mesh, variant)
    if arch.family == "gnn":
        return _build_gnn(arch, shape, mesh, variant)
    if arch.family == "recsys":
        return _build_recsys(arch, shape, mesh, variant)
    if arch.family == "retrieval":
        return _build_retrieval(arch, shape, mesh, variant)
    raise ValueError(f"unknown family {arch.family!r}")

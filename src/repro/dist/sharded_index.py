"""ShardedMonaVec: the MonaVec facade over a device mesh.

Wraps an Encoded corpus (from a built MonaVec or a loaded .mvec file), pads
it to the shard grid, places each contiguous row block on its device, and
serves the same ``search(queries, k)`` contract through the shard_map scan —
results are identical to the single-device index (DESIGN.md §3).

    idx = MonaVec.build(vectors, metric="cosine")
    sharded = idx.shard()                 # all local devices
    scores, ids = sharded.search(queries, k=10)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import predicate as pred
from repro.core import quantize as qz
from repro.core.bruteforce import BruteForceIndex
from repro.core.metadata import MetaStore
from repro.launch.mesh import make_local_mesh

from .partition import place_sharded


@dataclasses.dataclass
class ShardedMonaVec:
    enc: qz.Encoded          # metadata + SHARDED padded packed/qnorms
    ids: np.ndarray          # [n] external ids (unpadded)
    mesh: object
    n: int                   # true (unpadded) corpus rows
    meta: Optional[MetaStore] = None   # metadata columns (carried from MonaVec)
    tuned: Optional[object] = None     # repro.tune.TuneResult (carried over)

    # -- construction ------------------------------------------------------

    @staticmethod
    def shard(index, mesh=None) -> "ShardedMonaVec":
        """Shard a MonaVec / BruteForceIndex / Encoded over `mesh` (default:
        all local devices on the data axis).

        Only the BruteForce backend shards: it is the paper's deterministic
        core and the only scan whose partition merge is exact by construction
        (IVF/HNSW traversals are pointer-chasing, not row scans).
        """
        from repro.core.api import MonaVec
        meta = tuned = None
        if isinstance(index, MonaVec):
            meta = index.meta
            tuned = index.tuned
            index = index.backend
        if isinstance(index, BruteForceIndex):
            enc, ids = index.enc, index.ids
        elif isinstance(index, qz.Encoded):
            enc, ids = index, np.arange(index.n, dtype=np.uint64)
        else:
            raise TypeError(
                f"cannot shard a {type(index).__name__}: only the BruteForce "
                "scan has an exact cross-shard merge")
        if mesh is None:
            mesh = make_local_mesh()
        packed, qnorms, n = place_sharded(mesh, enc.packed, enc.qnorms)
        ccodes = None
        if enc.ccodes is not None:
            # Coarse codes shard row-contiguously alongside the packed bytes
            # (zero pad rows: the scan masks gid >= n before any selection).
            import jax

            from .partition import (corpus_sharding, data_axis_size,
                                    pad_rows, shard_sizes)
            _, n_pad = shard_sizes(n, data_axis_size(mesh))
            ccodes = jax.device_put(pad_rows(enc.ccodes, n_pad),
                                    corpus_sharding(mesh, 2))
        enc_sharded = dataclasses.replace(enc, packed=packed, qnorms=qnorms,
                                          ccodes=ccodes)
        return ShardedMonaVec(enc=enc_sharded, ids=np.asarray(ids), mesh=mesh,
                              n=n, meta=meta, tuned=tuned)

    @staticmethod
    def load(path: str, mesh=None) -> "ShardedMonaVec":
        from repro.core.api import MonaVec
        return ShardedMonaVec.shard(MonaVec.load(path), mesh)

    # -- search ------------------------------------------------------------

    def search(self, queries: jnp.ndarray, k: int = 10, *,
               where: Optional[pred.Predicate] = None,
               where_mask=None,
               rescore_mult: Optional[int] = None,
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(scores [b,k], external ids [b,k]) — same contract, same results
        as the single-device BruteForce search.  The shard_map scan runs as
        a cached SearchPlan (repro.engine, DESIGN.md §7): bucketed batches,
        shared hit/miss/trace counters, and exactly ``k`` columns
        (SENTINEL_ID / NEG padding when k exceeds the corpus).

        ``where=`` filters through the index's metadata columns: the
        predicate is evaluated host-side against the exact original values
        (the same oracle the engine's compiled stage is pinned to) and the
        resulting row mask is sharded alongside the corpus, applied before
        every local top-k.  ``where_mask=`` passes a precomputed [n] mask
        directly; both compose (AND)."""
        from repro import engine
        n_shards = int(getattr(self.mesh, "size", 1))
        obs.inc("dist.requests", **{"shards": n_shards})
        with obs.timed_span("sharded_search", histogram="dist.search_us",
                            labels={"shards": n_shards},
                            attrs={"shards": n_shards, "n": self.n}):
            mask = None if where_mask is None else np.asarray(where_mask, bool)
            if where is not None:
                if self.meta is None or not self.meta:
                    raise ValueError(
                        "where= requires an index built with metadata columns")
                if self.meta.n_rows != self.n:
                    raise ValueError(
                        f"metadata has {self.meta.n_rows} rows but the index "
                        f"has {self.n}")
                with obs.timed_span("predicate_eval",
                                    histogram="dist.predicate_us"):
                    pred.validate(where, self.meta)
                    pm = pred.evaluate(where, self.meta)
                mask = pm if mask is None else mask & pm
            self._trace_shards(n_shards)
            return engine.search_sharded(self, queries, k, where_mask=mask,
                                         rescore_mult=rescore_mult,
                                         tuned=self.tuned)

    def _trace_shards(self, n_shards: int) -> None:
        """Under an active QueryTrace, record one structural span per shard
        (row range + device).  shard_map executes every shard in lockstep
        inside ONE device program, so these spans carry placement metadata,
        not isolated per-device wall time (DESIGN.md §9)."""
        tr = obs.current_trace()
        if tr is None:
            return
        pad_rows = int(self.enc.packed.shape[0])
        per_shard = pad_rows // max(n_shards, 1)
        devices = list(np.asarray(self.mesh.devices).flat) \
            if hasattr(self.mesh, "devices") else [None] * n_shards
        for i in range(n_shards):
            lo = i * per_shard
            hi = min(self.n, lo + per_shard)
            sp = tr.push(f"shard:{i}", rows=max(0, hi - lo),
                         device=str(devices[i]) if devices[i] else "?")
            tr.pop(sp)

    def searcher(self, k: int = 10, *,
                 where: Optional[pred.Predicate] = None, **knobs):
        """Bound search handle over the sharded scan (``engine.Searcher``).
        ``**knobs`` (e.g. ``rescore_mult=``) bind into every call."""
        from repro import engine
        return engine.Searcher(self, k=k, where=where, knobs=knobs)

"""Deterministic corpus partitioning over the mesh 'data' axis.

The contract (DESIGN.md §3): a corpus of n rows is split into contiguous
equal-size shards in row order — shard s owns global rows
[s * ceil(n/S), (s+1) * ceil(n/S)) — after padding n up to a multiple of the
shard count.  Contiguity is what makes the cross-shard merge tie-consistent
with the single-device scan: global ids increase with (shard, local row), so
the stable per-shard top-k followed by a stable merge top-k reproduces
jax.lax.top_k's lower-index-wins ordering exactly.

Padding rows never enter a top-k: the scan masks any global id >= n to -inf
BEFORE the local top-k (a score sentinel, not a data sentinel — padded packed
bytes decode to the lowest centroid, which is a perfectly valid score, so
masking by id is the only airtight guard).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def data_axis_size(mesh) -> int:
    """Number of corpus shards = product of data-parallel axis sizes."""
    from repro.launch.mesh import data_axes
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def shard_sizes(n: int, n_shards: int) -> Tuple[int, int]:
    """(rows per shard, padded total) for an n-row corpus on n_shards."""
    per = round_up(n, n_shards) // n_shards
    return per, per * n_shards


def partition_bounds(n: int, n_shards: int, shard: int) -> Tuple[int, int]:
    """[lo, hi) of global rows owned by `shard` (hi clamped to n)."""
    per, _ = shard_sizes(n, n_shards)
    return shard * per, min((shard + 1) * per, n)


def pad_rows(x: jnp.ndarray, n_pad: int, fill=0) -> jnp.ndarray:
    """Pad axis 0 to n_pad rows with a constant (see module docstring for why
    the fill value is irrelevant to correctness)."""
    n = x.shape[0]
    if n == n_pad:
        return x
    widths = [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def corpus_sharding(mesh, ndim: int = 2) -> NamedSharding:
    """NamedSharding that splits corpus rows over the data axes."""
    from repro.launch.mesh import data_axes
    axes = data_axes(mesh)
    spec = (axes,) + (None,) * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def place_sharded(mesh, packed: jnp.ndarray, qnorms: jnp.ndarray):
    """Pad a (packed, qnorms) corpus to the shard grid and place each shard on
    its device.  Returns (packed', qnorms', n_orig)."""
    n = int(packed.shape[0])
    n_shards = data_axis_size(mesh)
    _, n_pad = shard_sizes(n, n_shards)
    packed_p = jax.device_put(pad_rows(packed, n_pad), corpus_sharding(mesh, 2))
    qnorms_p = jax.device_put(pad_rows(qnorms, n_pad, fill=1.0),
                              corpus_sharding(mesh, 1))
    return packed_p, qnorms_p, n

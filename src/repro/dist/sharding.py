"""Parameter partition rules for the dry-run cells (referenced by moe.py).

One rule table per model family, matched against the pytree path string of
each leaf.  Rules name only the TRAILING dims of a leaf: layer-stacked block
params carry an extra leading [L] axis (transformer init vmaps per block), so
specs are right-aligned and left-padded with None.

LM layout (megatron-style tensor parallelism over the 'model' axis):
  embed [V, D]             V/model   (tied head -> vocab-sharded logits)
  lm_head w [D, V]         V/model
  attn q/k/v w [D, H*dh]   out/model     o w [H*dh, D]  in/model
  mla up-projections       out/model     mla w_o        in/model
  swiglu gate/up [D, F]    F/model       down [F, D]    F/model
  moe w_* [E, D, F]        E/model   (expert parallelism)
  norms / scalars / routers / biases-of-replicated-outs   replicated

RecSys: embedding tables [V, D] are row-sharded (V/model) — the tables are
~all the params; the MLPs are replicated.  GNN: everything replicated (the
graphs, not the weights, are what's big; edges shard over 'data' at runtime).
"""

from __future__ import annotations

import re
from typing import Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# (path regex, trailing-dims spec) — first match wins.
LM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"\['mtp'\]", ()),                              # MTP head: replicated
    (r"\['embed'\]$", ("model", None)),
    (r"\['lm_head'\]\['w'\]", (None, "model")),
    (r"\['lm_head'\]\['b'\]", ("model",)),
    (r"\['mla'\]\['w_(uq|uk|uv)'\]\['w'\]", (None, "model")),
    (r"\['mla'\]\['w_o'\]\['w'\]", ("model", None)),
    (r"\['attn'\]\['(q|k|v)'\]\['w'\]", (None, "model")),
    (r"\['attn'\]\['(q|k|v)'\]\['b'\]", ("model",)),
    (r"\['attn'\]\['o'\]\['w'\]", ("model", None)),
    (r"\['ffn'\]\['(gate|up)'\]\['w'\]", (None, "model")),
    (r"\['ffn'\]\['down'\]\['w'\]", ("model", None)),
    (r"\['ffn'\]\['w_(gate|up|down)'\]", ("model", None, None)),
    (r"\['ffn'\]\['shared'\]\['(gate|up)'\]\['w'\]", (None, "model")),
    (r"\['ffn'\]\['shared'\]\['down'\]\['w'\]", ("model", None)),
)

RECSYS_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"\['(tables|v|w)'\]\[\d+\]$", ("model", None)),        # DLRM / FM tables
    (r"\['(item_emb|cat_emb|user_emb)'\]$", ("model", None)),  # DIEN / two-tower
)

GNN_RULES: Tuple[Tuple[str, Tuple], ...] = ()


def spec_for_path(path_str: str, ndim: int,
                  rules: Sequence[Tuple[str, Tuple]]) -> P:
    """Match a leaf path against the rule table; right-align the spec."""
    for pat, trailing in rules:
        if re.search(pat, path_str):
            if len(trailing) > ndim:       # e.g. bias of a matched dense
                trailing = trailing[-ndim:] if ndim else ()
            return P(*((None,) * (ndim - len(trailing)) + tuple(trailing)))
    return P()


def tree_shardings(tree, mesh, rules: Sequence[Tuple[str, Tuple]],
                   drop_model: bool = False):
    """Map a pytree of arrays/ShapeDtypeStructs to NamedShardings.

    drop_model=True degrades every rule to fully replicated (1-device meshes
    or memory twins where only data parallelism is wanted).
    """
    def one(path, leaf):
        if drop_model:
            return NamedSharding(mesh, P())
        spec = spec_for_path(jax.tree_util.keystr(path), len(leaf.shape), rules)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def batch_sharding(mesh, ndim: int, axes) -> NamedSharding:
    """Shard dim 0 (the batch) over the data axes, rest replicated."""
    return NamedSharding(mesh, P(tuple(axes), *((None,) * (ndim - 1))))


def with_shardings(struct_tree, sharding_tree):
    """Attach shardings to a ShapeDtypeStruct tree (jit.lower aot inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, sharding_tree)

# Multi-device subsystem: sharded retrieval, dry-run cell construction,
# and parameter partition rules.  Importing this package never touches jax
# device state (same contract as launch.mesh).
from .partition import (corpus_sharding, pad_rows, partition_bounds,
                        shard_sizes)  # noqa: F401
from .retrieval import (make_scan_topk_f32_shardmap, make_scan_topk_shardmap,
                        scan_topk_f32, scan_topk_pjit)  # noqa: F401
from .sharded_index import ShardedMonaVec  # noqa: F401

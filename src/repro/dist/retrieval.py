"""Sharded top-k retrieval: the MonaVec scan over a device mesh.

Decomposition (the standard MIPS-over-partitions scheme; DESIGN.md §3):

  1. the corpus (packed codes + qnorms) is split into contiguous row shards
     along the mesh data axes (``partition.py``);
  2. every shard scores its rows against the replicated rotated queries with
     the SAME kernels the single-device scan uses (``repro.kernels``),
     adjusts by metric, masks padding rows to -inf, and takes a LOCAL
     stable top-k;
  3. local winners are offset to global ids, all-gathered in shard order,
     and re-top-k'd — also stable.

Because shards are contiguous and both top-k stages are stable
(``jax.lax.top_k``: lower index wins ties), the merged (scores, ids) are
identical to the single-device scan on any mesh shape — bit-identical ids,
and scores equal to the last ulp (each row's dot product is computed by the
same kernel on the same bytes; sharding only removes rows from a block, it
never re-associates a row's reduction).

``scan_topk_pjit`` / ``scan_topk_f32`` are the jit'd single-logical-array
references (GSPMD partitions the matmul if the inputs are sharded);
``make_scan_topk_shardmap`` / ``make_scan_topk_f32_shardmap`` build the
explicitly-collective shard_map versions whose communication is exactly one
all-gather of [b, S*k] candidates instead of the full [b, n] score matrix.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import binary as bin_mod
from repro.core.scoring import adjust_scores, score_f32, topk
from repro.kernels.ops import score_raw
from repro.launch.mesh import data_axes

from .partition import data_axis_size, pad_rows, shard_sizes

#: repro.analysis coverage hook (DESIGN.md §10): the shard_map scan factories'
#: outputs run as the engine's ``shard_scan`` / ``cascade_shard_scan`` plan
#: stages; the determinism auditor's grid must capture both.
PLAN_STAGES = ("make_scan_topk_shardmap", "make_cascade_topk_shardmap")


# ---------------------------------------------------------------------------
# Single-logical-array references (jit / pjit).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "k", "bits", "n4_dims"))
def scan_topk_pjit(
    q_rot: jnp.ndarray,      # [b, d'] rotated f32 queries (encode_query output)
    packed: jnp.ndarray,     # [n, bytes] packed corpus codes
    qnorms: jnp.ndarray,     # [n] f32 dequantized-vector norms
    *,
    metric: str = "cosine",
    k: int = 10,
    bits: int = 4,
    n4_dims: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference quantized scan: (scores [b,k], global indices [b,k]).

    Runs as one jit program over the full logical arrays; under `with mesh:`
    and sharded inputs GSPMD partitions it, which is the implicit-parallelism
    baseline the shard_map factories are validated against.
    """
    raw = score_raw(packed, q_rot, bits=bits, n4_dims=n4_dims)
    return topk(adjust_scores(raw, qnorms, metric), k)


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def scan_topk_f32(
    queries: jnp.ndarray,    # [b, d] raw queries
    corpus: jnp.ndarray,     # [n, d] f32 corpus
    *,
    metric: str = "dot",
    k: int = 10,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact f32 scan reference (the accuracy ceiling): (scores, indices)."""
    return topk(score_f32(queries, corpus, metric), k)


# ---------------------------------------------------------------------------
# shard_map factories: explicit local-scan + cross-shard merge.
# ---------------------------------------------------------------------------

def _mesh_data_info(mesh):
    """(axes tuple, total shard count) for the corpus partition."""
    return data_axes(mesh), data_axis_size(mesh)


def _shard_index(axes, mesh) -> jnp.ndarray:
    """Row-major linear shard index over the data axes (matches the
    concatenation order of all_gather over the same axis tuple)."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _merge_topk(vals: jnp.ndarray, gids: jnp.ndarray, axes, k: int):
    """All-gather per-shard candidates (shard order) and re-top-k.

    Shard order == global-id order (contiguous partition), and lax.top_k is
    stable, so ties resolve exactly as in the single-device scan.
    """
    vg = jax.lax.all_gather(vals, axes, axis=1, tiled=True)   # [b, S*k_local]
    gg = jax.lax.all_gather(gids, axes, axis=1, tiled=True)
    vv, mi = jax.lax.top_k(vg, k)
    return vv, jnp.take_along_axis(gg, mi, axis=1)


def make_scan_topk_shardmap(
    mesh,
    *,
    metric: str = "cosine",
    k: int = 10,
    bits: int = 4,
    n4_dims: int = 0,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    n_valid: Optional[int] = None,
    on_trace=None,
    with_mask: bool = False,
):
    """Build fn(q_rot, packed, qnorms) -> (scores [b,k], global ids [b,k])
    scanning corpus shards along the mesh data axes.

    The returned fn accepts the full logical corpus (replicated or already
    sharded); shard_map's in_specs reshard it row-contiguously, padding first
    so every shard is equal-size.  Pass n_valid when the corpus is ALREADY
    padded (ShardedMonaVec) so the padding mask still knows the true row
    count.  ``on_trace`` (if given) runs once per jit trace — the engine's
    plan cache hangs its retrace counter on it (DESIGN.md §7).
    ``with_mask=True`` makes the fn take a fourth argument — an [n] boolean
    row-admissibility mask, sharded alongside the corpus (padding rows are
    masked False) and applied with the padding sentinel BEFORE the local
    top-k, so filtered shards merge exactly like unfiltered ones.  Results
    are identical to scan_topk_pjit (slots with no admissible row surface
    as -inf for the caller to sentinel-convert).
    """
    axes, n_shards = _mesh_data_info(mesh)

    @jax.jit
    def call(q_rot, packed, qnorms, mask=None):
        if on_trace is not None:
            on_trace()
        n = packed.shape[0] if n_valid is None else n_valid
        per, n_pad = shard_sizes(n, n_shards)
        k_local = min(k, per)
        packed_p = pad_rows(packed, n_pad)
        qnorms_p = pad_rows(qnorms, n_pad, fill=1.0)

        def local_scan(q, pk, qn, *rest):
            # pk [per, bytes], qn [per] — this shard's contiguous row block.
            gid0 = _shard_index(axes, mesh) * per
            raw = score_raw(pk, q, bits=bits, n4_dims=n4_dims,
                            use_kernel=use_kernel, interpret=interpret)
            s = adjust_scores(raw, qn, metric)
            gids = gid0 + jnp.arange(per, dtype=jnp.int32)
            ok = gids[None, :] < n                          # padding sentinel
            if rest:
                ok = ok & rest[0][None, :]                  # row admissibility
            s = jnp.where(ok, s, -jnp.inf)
            v, li = jax.lax.top_k(s, k_local)               # local stable top-k
            return _merge_topk(v, jnp.take(gids, li), axes, k)

        in_specs = [P(), P(axes, None), P(axes)]
        operands = [q_rot, packed_p, qnorms_p]
        if with_mask:
            in_specs.append(P(axes))
            operands.append(pad_rows(mask, n_pad, fill=False))
        return shard_map(
            local_scan, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=(P(), P()),
            check_rep=False,
        )(*operands)

    return call


def make_cascade_topk_shardmap(
    mesh,
    *,
    metric: str = "cosine",
    k: int = 10,
    bits: int = 4,
    n4_dims: int = 0,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    n_valid: Optional[int] = None,
    on_trace=None,
    with_mask: bool = False,
    kind: str = bin_mod.SIGN,
    m: int = 320,
):
    """Binarized-cascade variant of make_scan_topk_shardmap (DESIGN.md §11):
    fn(q_rot, packed, qnorms, ccodes[, mask]) -> (scores [b,k], gids [b,k]).

    Each shard runs the WHOLE cascade locally on its contiguous row block —
    integer coarse proxy, survivor top-m (padding and admissibility masks
    fused BEFORE selection, so filtered shards spend their full budget on
    admissible rows), gathered 4-bit rescore — then local top-k and the
    same stable all-gather merge as the plain scan.  Dead survivor slots
    surface as -inf for the caller to sentinel-convert (exactly the
    with_mask contract of the plain factory).
    """
    axes, n_shards = _mesh_data_info(mesh)

    @jax.jit
    def call(q_rot, packed, qnorms, ccodes, mask=None):
        if on_trace is not None:
            on_trace()
        n = packed.shape[0] if n_valid is None else n_valid
        per, n_pad = shard_sizes(n, n_shards)
        m_local = min(m, per)
        k_local = min(k, per, m_local)
        packed_p = pad_rows(packed, n_pad)
        qnorms_p = pad_rows(qnorms, n_pad, fill=1.0)
        ccodes_p = pad_rows(ccodes, n_pad)

        def local_scan(q, pk, qn, cc, *rest):
            gid0 = _shard_index(axes, mesh) * per
            gids = gid0 + jnp.arange(per, dtype=jnp.int32)
            live = gids < n                                 # padding sentinel
            if rest:
                live = live & rest[0]                       # row admissibility
            proxy = bin_mod.coarse_scan_stage(
                q, cc, kind=kind, use_kernel=use_kernel, interpret=interpret)
            # |proxy| <= 9 d'; d' recovers from the plane width (d'/8 bytes
            # per sign plane, two planes for crumb).
            d_rot = cc.shape[-1] * (8 if kind == bin_mod.SIGN else 4)
            cand = bin_mod.survivor_topk_stage(proxy, live, m=m_local,
                                               vbound=9 * d_rot)
            s = bin_mod.gathered_rescore_stage(
                q, pk, qn, cand, bits=bits, n4_dims=n4_dims, metric=metric,
                use_kernel=use_kernel, interpret=interpret)
            s = jnp.where(cand >= 0, s, -jnp.inf)           # dead survivors
            v, si = jax.lax.top_k(s, k_local)               # local stable top-k
            wrow = jnp.take_along_axis(cand, si, axis=1)
            wgid = jnp.where(wrow >= 0, gid0 + wrow, 0)
            return _merge_topk(v, wgid, axes, k)

        in_specs = [P(), P(axes, None), P(axes), P(axes, None)]
        operands = [q_rot, packed_p, qnorms_p, ccodes_p]
        if with_mask:
            in_specs.append(P(axes))
            operands.append(pad_rows(mask, n_pad, fill=False))
        return shard_map(
            local_scan, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=(P(), P()),
            check_rep=False,
        )(*operands)

    return call


def make_scan_topk_f32_shardmap(
    mesh,
    *,
    metric: str = "dot",
    k: int = 10,
):
    """f32 variant of make_scan_topk_shardmap: fn(queries, corpus).

    Every score_f32 metric is row-local on the corpus side (per-row norms /
    squared norms), so sharding rows never changes a score's value.
    """
    axes, n_shards = _mesh_data_info(mesh)

    @jax.jit
    def call(queries, corpus):
        n = corpus.shape[0]
        per, n_pad = shard_sizes(n, n_shards)
        k_local = min(k, per)
        corpus_p = pad_rows(corpus, n_pad)

        def local_scan(q, c):
            gid0 = _shard_index(axes, mesh) * per
            s = score_f32(q, c, metric)
            gids = gid0 + jnp.arange(per, dtype=jnp.int32)
            s = jnp.where(gids[None, :] < n, s, -jnp.inf)
            v, li = jax.lax.top_k(s, k_local)
            return _merge_topk(v, jnp.take(gids, li), axes, k)

        return shard_map(
            local_scan, mesh=mesh,
            in_specs=(P(), P(axes, None)),
            out_specs=(P(), P()),
            check_rep=False,
        )(queries, corpus_p)

    return call

"""Reciprocal Rank Fusion (paper §3.6): RRF(d) = sum_i 1 / (k + rank_i(d))."""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np


def rrf_fuse(
    rankings: Sequence[np.ndarray],
    *,
    k: int = 60,
    top_k: int = 10,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fuse ranked id lists (best first).  Returns (fused_scores, ids).

    Deterministic: ties broken by smaller id.
    """
    scores: Dict[int, float] = {}
    for ranking in rankings:
        for rank, doc in enumerate(np.asarray(ranking).tolist()):
            scores[int(doc)] = scores.get(int(doc), 0.0) + 1.0 / (k + rank + 1)
    items = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))[:top_k]
    if not items:
        return np.zeros(0, np.float32), np.zeros(0, np.int64)
    ids = np.array([i for i, _ in items], dtype=np.int64)
    vals = np.array([v for _, v in items], dtype=np.float32)
    return vals, ids

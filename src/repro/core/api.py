"""MonaVec facade: one class, one file, one call (the SQLite deployment model).

    idx = MonaVec.build(vectors, metric="cosine", index="hnsw")
    scores, ids = idx.search(queries, k=10)
    idx.save("corpus.mvec");  idx2 = MonaVec.load("corpus.mvec")

The default configuration (BruteForce over RHDH+Lloyd-Max 4-bit) is
data-oblivious end to end; `fit()` adds the optional single-pass L2
calibration; `index="ivf"` is the single opt-in *trained* component.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from . import mvec_format as fmt
from .allowlist import Allowlist
from .bruteforce import BruteForceIndex
from .hnsw import HnswIndex, recommended_m
from .ivf import IvfFlatIndex
from .standardize import COSINE, GlobalStd

Backend = Union[BruteForceIndex, IvfFlatIndex, HnswIndex]
_TYPE_CODE = {BruteForceIndex: fmt.INDEX_BRUTEFORCE, IvfFlatIndex: fmt.INDEX_IVF,
              HnswIndex: fmt.INDEX_HNSW}


@dataclasses.dataclass
class MonaVec:
    backend: Backend

    # -- construction ------------------------------------------------------

    @staticmethod
    def fit(sample: jnp.ndarray) -> GlobalStd:
        """Single-pass global standardization for L2 corpora (paper fit())."""
        return GlobalStd.fit(sample)

    @staticmethod
    def recommended_m(n: int) -> int:
        return recommended_m(n)

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        *,
        metric: str = COSINE,
        index: str = "bruteforce",
        seed: int = 0x6D6F6E61,
        bits: int = 4,
        avg_bits: Optional[float] = None,
        std: Optional[GlobalStd] = None,
        ids: Optional[np.ndarray] = None,
        **kwargs,
    ) -> "MonaVec":
        vectors = jnp.asarray(vectors)
        if index == "bruteforce":
            be = BruteForceIndex.build(
                vectors, metric=metric, seed=seed, bits=bits, std=std, ids=ids,
                avg_bits=avg_bits,
            )
        elif index == "ivf":
            be = IvfFlatIndex.build(
                vectors, metric=metric, seed=seed, bits=bits, std=std, ids=ids, **kwargs
            )
        elif index == "hnsw":
            be = HnswIndex.build(
                vectors, metric=metric, seed=seed, bits=bits, std=std, ids=ids, **kwargs
            )
        else:
            raise ValueError(f"unknown index {index!r}")
        return MonaVec(backend=be)

    # -- distribution ------------------------------------------------------

    def shard(self, mesh=None):
        """Shard this index's corpus over a device mesh (default: all local
        devices) and return a ShardedMonaVec with the same search() contract
        and identical results (repro.dist; BruteForce backend only)."""
        from repro.dist.sharded_index import ShardedMonaVec
        return ShardedMonaVec.shard(self, mesh)

    # -- search --------------------------------------------------------------

    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        *,
        allow: Optional[Allowlist] = None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        **kwargs,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over the active backend.  Every backend honors the same
        kernel-dispatch contract: ``use_kernel=None`` picks the Pallas kernel
        on TPU and the pure-jnp path elsewhere; ``use_kernel=True`` with
        ``interpret=True`` runs the kernel body in interpret mode (validation,
        bit-identical to the jnp path); backend-specific knobs (``nprobe``,
        ``ef``) ride in ``**kwargs``."""
        return self.backend.search(
            jnp.asarray(queries), k, allow=allow, use_kernel=use_kernel,
            interpret=interpret, **kwargs,
        )

    # -- persistence -----------------------------------------------------------

    def save(self, path: str) -> None:
        be = self.backend
        if isinstance(be, BruteForceIndex):
            blob, param = None, 0
        elif isinstance(be, IvfFlatIndex):
            blob = fmt.pack_ivf_blob(np.asarray(be.centroids), be.order, be.offsets)
            param = be.nlist
        else:
            blob = fmt.pack_hnsw_blob(be)
            param = be.m
        fmt.save(path, fmt.MvecFile(
            enc=be.enc, ids=be.ids, index_type=_TYPE_CODE[type(be)],
            index_param=param, index_data=blob,
        ))

    @staticmethod
    def load(path: str) -> "MonaVec":
        f = fmt.load(path)
        if f.index_type == fmt.INDEX_BRUTEFORCE:
            return MonaVec(BruteForceIndex(enc=f.enc, ids=f.ids))
        if f.index_type == fmt.INDEX_IVF:
            cents, order, offsets = fmt.unpack_ivf_blob(f.index_data)
            return MonaVec(IvfFlatIndex(
                enc=f.enc, ids=f.ids, centroids=jnp.asarray(cents),
                order=order, offsets=offsets, nlist=f.index_param,
            ))
        if f.index_type == fmt.INDEX_HNSW:
            nbr0, nbr_hi, node_level, entry, max_level = fmt.unpack_hnsw_blob(f.index_data)
            return MonaVec(HnswIndex(
                enc=f.enc, ids=f.ids, neighbors0=nbr0, neighbors_hi=nbr_hi,
                node_level=node_level, entry_point=entry, max_level=max_level,
                m=f.index_param,
            ))
        raise ValueError(f"unknown index type {f.index_type}")

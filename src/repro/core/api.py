"""MonaVec facade: one class, one file, one call (the SQLite deployment model).

    idx = MonaVec.build(vectors, metric="cosine", index="hnsw")
    scores, ids = idx.search(queries, k=10)
    idx.save("corpus.mvec");  idx2 = MonaVec.load("corpus.mvec")

The default configuration (BruteForce over RHDH+Lloyd-Max 4-bit) is
data-oblivious end to end; `fit()` adds the optional single-pass L2
calibration; `index="ivf"` is the single opt-in *trained* component.

Mutation facade (DESIGN.md §6) — the index is a sequence of immutable
quantized segments plus per-segment deletion bitmaps, so a deployed corpus
can grow and churn between sessions without a rebuild:

    idx.add(new_vectors)            # quantizes a new segment (derived seed)
    idx.delete([3, 17])             # tombstones rows, codes untouched
    idx.compact()                   # deterministic rewrite into one segment

`search()` scans every segment with tombstones masked BEFORE top-k (the §3.5
pre-filter guarantee survives mutation); `save()` writes the v8 multi-segment
`.mvec` layout once the index is mutated, and still writes v6/v7 for
single-segment indexes.  Replaying the same op sequence reproduces the same
file byte-for-byte on any platform (pinned by the golden + hypothesis
suites).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from . import mvec_format as fmt
from . import segments as seg
from .allowlist import Allowlist
from .bruteforce import BruteForceIndex
from .hnsw import HnswIndex, recommended_m
from .ivf import IvfFlatIndex
from .metadata import MetaStore
from .predicate import Predicate
from .standardize import COSINE, GlobalStd

Backend = Union[BruteForceIndex, IvfFlatIndex, HnswIndex]
_TYPE_CODE = {BruteForceIndex: fmt.INDEX_BRUTEFORCE, IvfFlatIndex: fmt.INDEX_IVF,
              HnswIndex: fmt.INDEX_HNSW}


@dataclasses.dataclass
class MonaVec:
    backend: Backend
    mut: Optional[seg.SegmentedState] = None
    meta: Optional[MetaStore] = None   # per-row metadata columns (v9, §8)
    tuned: Optional[object] = None     # repro.tune.TuneResult (v11, §12)

    def __post_init__(self):
        if self.mut is None:
            self.mut = seg.SegmentedState.fresh(self.backend.enc.n)

    # -- construction ------------------------------------------------------

    @staticmethod
    def fit(sample: jnp.ndarray) -> GlobalStd:
        """Single-pass global standardization for L2 corpora (paper fit())."""
        return GlobalStd.fit(sample)

    @staticmethod
    def recommended_m(n: int) -> int:
        return recommended_m(n)

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        *,
        metric: str = COSINE,
        index: str = "bruteforce",
        seed: int = 0x6D6F6E61,
        bits: int = 4,
        avg_bits: Optional[float] = None,
        std: Optional[GlobalStd] = None,
        ids: Optional[np.ndarray] = None,
        meta: Optional[dict] = None,
        coarse: Optional[str] = None,
        autotune: Union[bool, float, dict, None] = None,
        **kwargs,
    ) -> "MonaVec":
        vectors = jnp.asarray(vectors)
        if coarse is not None and index != "bruteforce":
            raise ValueError(
                "coarse= (the binarized cascade) requires the bruteforce "
                f"index, got index={index!r}")
        if index == "bruteforce":
            be = BruteForceIndex.build(
                vectors, metric=metric, seed=seed, bits=bits, std=std, ids=ids,
                avg_bits=avg_bits,
            )
        elif index == "ivf":
            be = IvfFlatIndex.build(
                vectors, metric=metric, seed=seed, bits=bits, std=std, ids=ids, **kwargs
            )
        elif index == "hnsw":
            be = HnswIndex.build(
                vectors, metric=metric, seed=seed, bits=bits, std=std, ids=ids, **kwargs
            )
        else:
            raise ValueError(f"unknown index {index!r}")
        store = (MetaStore.build(meta, int(vectors.shape[0]))
                 if meta else None)
        idx = MonaVec(backend=be, meta=store)
        if coarse is not None:
            idx.enable_coarse(coarse)
        if autotune is not None and autotune is not False:
            # autotune=True -> defaults; a float is the recall target; a
            # dict is passed through to MonaVec.autotune verbatim.
            if autotune is True:
                idx.autotune()
            elif isinstance(autotune, dict):
                idx.autotune(**autotune)
            else:
                idx.autotune(recall_target=float(autotune))
        return idx

    # -- corpus introspection ---------------------------------------------

    @property
    def ids(self) -> np.ndarray:
        """External ids of EVERY row (tombstoned included), concatenated in
        segment order — the id universe allowlists are built against."""
        cols = [self.backend.ids] + [s.ids for s in self.mut.extras]
        return np.concatenate(cols) if len(cols) > 1 else self.backend.ids

    @property
    def n_total(self) -> int:
        return int(self.backend.enc.n + sum(s.n for s in self.mut.extras))

    @property
    def n_live(self) -> int:
        dead = int(self.mut.base_tombs.sum()) + sum(
            int(s.tombs.sum()) for s in self.mut.extras)
        return self.n_total - dead

    def _live_masks(self) -> list:
        return [~self.mut.base_tombs] + [~s.tombs for s in self.mut.extras]

    # -- mutation lifecycle (DESIGN.md §6) --------------------------------

    def add(
        self,
        vectors: jnp.ndarray,
        ids: Optional[Sequence[int]] = None,
        meta: Optional[dict] = None,
    ) -> np.ndarray:
        """Append a new immutable segment quantized through the same
        RHDH + Lloyd-Max pipeline under ``derive_segment_seed(root, ordinal)``.
        Returns the assigned external ids.  Ids duplicating a LIVE row are
        rejected (tombstoned ids may be reused).  An index built with
        metadata columns requires ``meta`` for every batch (exact schema
        match); a metadata-free index rejects it."""
        vectors = jnp.atleast_2d(jnp.asarray(vectors))
        n_new = int(vectors.shape[0])
        if self.meta is not None and meta is None:
            raise ValueError(
                "add: this index has metadata columns "
                f"{[n for n, _ in self.meta.schema]}; pass meta= for the batch")
        if self.meta is None and meta is not None:
            raise ValueError(
                "add: meta= given but the index was built without metadata "
                "columns")
        if n_new == 0:
            return np.zeros(0, dtype=np.uint64)
        if vectors.shape[1] != self.backend.enc.dim:
            raise ValueError(
                f"add: expected dim {self.backend.enc.dim}, got {vectors.shape[1]}")
        if ids is None:
            new_ids = np.arange(n_new, dtype=np.uint64) + (
                np.uint64(0) if self.n_total == 0
                else self.ids.max() + np.uint64(1))
        else:
            new_ids = np.asarray(list(ids), dtype=np.uint64)
            if new_ids.shape[0] != n_new:
                raise ValueError("add: len(ids) != len(vectors)")
        if np.unique(new_ids).shape[0] != n_new:
            raise ValueError("add: duplicate ids within the batch")
        live_ids = np.concatenate(
            [i[m] for i, m in zip(
                [self.backend.ids] + [s.ids for s in self.mut.extras],
                self._live_masks())])
        clash = np.intersect1d(new_ids, live_ids)
        if clash.size:
            raise ValueError(f"add: ids already live in the index: {clash[:8].tolist()}")
        if self.meta is not None:
            self.meta.append(meta, n_new)    # atomic: validates before commit
        seed = seg.derive_segment_seed(self.backend.enc.seed, self.mut.next_ordinal)
        enc = seg.encode_segment(vectors, self.backend.enc, seed)
        self.mut.extras.append(
            seg.Segment(enc=enc, ids=new_ids, tombs=np.zeros(n_new, dtype=bool)))
        self.mut.next_ordinal += 1
        return new_ids

    def delete(self, ids: Sequence[int]) -> int:
        """Tombstone every live row whose external id is in ``ids``.  Codes
        are never rewritten; returns the number of rows newly tombstoned."""
        targets = np.asarray(list(ids), dtype=np.uint64)
        hit = np.isin(self.backend.ids, targets) & ~self.mut.base_tombs
        self.mut.base_tombs |= hit
        n = int(hit.sum())
        for s in self.mut.extras:
            hit = np.isin(s.ids, targets) & ~s.tombs
            s.tombs |= hit
            n += int(hit.sum())
        return n

    def compact(self) -> int:
        """Deterministically rewrite the live rows into a single fresh base
        segment (root seed) and rebuild the backend structure over them.

        The live rows' codes are decoded to rotated space, carried back
        through the inverse rotation of their segment seed, and re-encoded
        under the root seed — a pure function of the current codes, so two
        identical op sequences compact to byte-identical indexes.  Returns
        the number of dead rows reclaimed.
        """
        reclaimed = self.n_total - self.n_live
        if not self.mut.extras and reclaimed == 0:
            return 0
        if self.n_live == 0:
            raise ValueError("compact: no live rows to rewrite")
        if self.meta is not None:
            self.meta = self.meta.gather(np.concatenate(self._live_masks()))
        encs = [self.backend.enc] + [s.enc for s in self.mut.extras]
        all_ids = [self.backend.ids] + [s.ids for s in self.mut.extras]
        vec_parts, id_parts = [], []
        for enc, sids, live in zip(encs, all_ids, self._live_masks()):
            if live.any():
                vec_parts.append(seg.reconstruct_vectors(enc)[live])
                id_parts.append(sids[live])
        live_vecs = jnp.asarray(np.concatenate(vec_parts))
        live_ids = np.concatenate(id_parts)
        base = self.backend.enc
        if isinstance(self.backend, BruteForceIndex):
            enc = seg.encode_segment(live_vecs, base, base.seed)
            self.backend = BruteForceIndex(enc=enc, ids=live_ids)
        elif isinstance(self.backend, IvfFlatIndex):
            self.backend = IvfFlatIndex.build(
                live_vecs, ids=live_ids, metric=base.metric, seed=base.seed,
                bits=base.bits, std=base.std,
                nlist=min(self.backend.nlist, live_ids.shape[0]),
            )
        else:
            self.backend = HnswIndex.build(
                live_vecs, ids=live_ids, metric=base.metric, seed=base.seed,
                bits=base.bits, std=base.std, m=self.backend.m,
                ef_construction=self.backend.ef_construction or 100,
            )
        self.mut = seg.SegmentedState.fresh(self.backend.enc.n)
        return reclaimed

    def enable_coarse(self, kind: str = "sign") -> "MonaVec":
        """Derive + attach the binarized coarse code (DESIGN.md §11) to every
        segment, in place.  Pure function of the packed codes, so enabling on
        a loaded pre-v10 index yields exactly the codes a ``coarse=`` build
        would have persisted.  Unlocks ``search(..., rescore_mult=r)``."""
        from . import binary
        if not isinstance(self.backend, BruteForceIndex):
            raise TypeError(
                "the binarized cascade requires the bruteforce backend, "
                f"got {type(self.backend).__name__}")
        self.backend = dataclasses.replace(
            self.backend, enc=binary.attach_coarse(self.backend.enc, kind))
        for s in self.mut.extras:
            s.enc = binary.attach_coarse(s.enc, kind)
        return self

    # -- autotuning (DESIGN.md §12) ---------------------------------------

    def autotune(
        self,
        recall_target: float = 0.95,
        k: int = 10,
        *,
        n_queries: int = 32,
        seed: int = 0xA07001,
        boost: bool = True,
    ) -> "MonaVec":
        """Pick the cheapest backend knobs meeting ``recall@k >= target``.

        Deterministic and training-free: seeded sample queries are drawn
        from the corpus itself, recall is measured against an exact
        full-scan oracle over the SAME quantized segments, and the chosen
        knob is the smallest ladder rung meeting the target.  The result
        rides on ``self.tuned`` (knob defaults for every later search) and
        persists in ``save()`` as the v11 TUNE block.  ``boost=True`` also
        tunes the selectivity boost curve so filtered recall holds at 1%
        selectivity.  Returns ``self`` for chaining.
        """
        from repro.tune import autotune as tune_fn
        self.tuned = tune_fn(
            self, recall_target=recall_target, k=k, n_queries=n_queries,
            seed=seed, boost=boost)
        return self

    def resolved_knobs(self, k: int = 10, **kwargs) -> dict:
        """The exact knobs ``search(queries, k, **kwargs)`` would run with —
        after tuned-default resolution, the silent nprobe<=nlist clamp, the
        ef>=k auto-widen, and the rescore_mult full-scan collapse.  An empty
        dict means the plain full scan."""
        from .. import engine
        return engine.resolve_knobs(
            self.backend, None if self.mut.is_static else self.mut, k,
            tuned=self.tuned, **kwargs)

    # -- distribution ------------------------------------------------------

    def shard(self, mesh=None):
        """Shard this index's corpus over a device mesh (default: all local
        devices) and return a ShardedMonaVec with the same search() contract
        and identical results (repro.dist; BruteForce backend only)."""
        if not self.mut.is_static:
            raise TypeError("shard() requires an unmutated index — compact() first")
        from repro.dist.sharded_index import ShardedMonaVec
        return ShardedMonaVec.shard(self, mesh)

    # -- search --------------------------------------------------------------

    def search(
        self,
        queries: jnp.ndarray,
        k: int = 10,
        *,
        allow: Optional[Allowlist] = None,
        where: Optional[Predicate] = None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        **kwargs,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over the active backend, executed as one compiled SearchPlan
        (repro.engine, DESIGN.md §7): rotate -> per-segment scans -> tombstone/
        allowlist mask -> merge -> stable top-k, cached per (backend
        fingerprint, shape bucket, k, dispatch) so repeated traffic never
        re-traces.  Every backend honors the same kernel-dispatch contract:
        ``use_kernel=None`` picks the Pallas kernel on TPU and the pure-jnp
        path elsewhere; ``use_kernel=True`` with ``interpret=True`` runs the
        kernel body in interpret mode (validation, bit-identical to the jnp
        path); backend-specific knobs (``nprobe``, ``ef``) ride in
        ``**kwargs``.  ``where=`` takes a structured predicate over the
        index's metadata columns, compiled into the same plan as a mask
        stage (DESIGN.md §8) — its structure joins the fingerprint, its
        constants ride as dynamic arguments.  On a mutated index the scan
        covers every segment with tombstones masked pre-top-k (allowlists
        are built from ``MonaVec.ids``).  Always exactly ``k`` columns:
        inadmissible slots carry SENTINEL_ID / NEG."""
        from .. import engine
        return engine.search_backend(
            self.backend, None if self.mut.is_static else self.mut,
            queries, k, allow=allow, where=where, meta=self.meta,
            use_kernel=use_kernel, interpret=interpret, tuned=self.tuned,
            **kwargs,
        )

    def searcher(
        self,
        k: int = 10,
        *,
        where: Optional[Predicate] = None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        **kwargs,
    ):
        """Bound search handle: ``s = idx.searcher(k=10, nprobe=16);
        s(queries)``.  The handle resolves its compiled plan through the
        shared engine cache on every call (so it tracks add/delete/compact),
        and ``s.warmup(batch_size)`` pre-compiles a bucket so serving never
        pays jit tracing inside a measured window.  ``where=`` binds a
        predicate over metadata columns into every call."""
        from .. import engine
        return engine.Searcher(self, k=k, where=where, use_kernel=use_kernel,
                               interpret=interpret, knobs=kwargs)

    # -- persistence -----------------------------------------------------------

    def save(self, path: str) -> None:
        be = self.backend
        param2 = 0
        if isinstance(be, BruteForceIndex):
            blob, param = None, 0
        elif isinstance(be, IvfFlatIndex):
            blob = fmt.pack_ivf_blob(np.asarray(be.centroids), be.order, be.offsets)
            param = be.nlist
        else:
            blob = fmt.pack_hnsw_blob(be)
            param = be.m
            param2 = be.ef_construction or 0
        fmt.save(path, fmt.MvecFile(
            enc=be.enc, ids=be.ids, index_type=_TYPE_CODE[type(be)],
            index_param=param, index_data=blob, index_param2=param2,
            extras=[fmt.ExtraSegment(enc=s.enc, ids=s.ids)
                    for s in self.mut.extras],
            tombs=[self.mut.base_tombs] + [s.tombs for s in self.mut.extras],
            meta=self.meta,
            tune=self.tuned,
        ))

    @staticmethod
    def load(path: str) -> "MonaVec":
        f = fmt.load(path)
        if f.index_type == fmt.INDEX_BRUTEFORCE:
            be: Backend = BruteForceIndex(enc=f.enc, ids=f.ids)
        elif f.index_type == fmt.INDEX_IVF:
            cents, order, offsets = fmt.unpack_ivf_blob(f.index_data)
            be = IvfFlatIndex(
                enc=f.enc, ids=f.ids, centroids=jnp.asarray(cents),
                order=order, offsets=offsets, nlist=f.index_param,
            )
        elif f.index_type == fmt.INDEX_HNSW:
            nbr0, nbr_hi, node_level, entry, max_level = fmt.unpack_hnsw_blob(f.index_data)
            be = HnswIndex(
                enc=f.enc, ids=f.ids, neighbors0=nbr0, neighbors_hi=nbr_hi,
                node_level=node_level, entry_point=entry, max_level=max_level,
                m=f.index_param, ef_construction=f.index_param2 or None,
            )
        else:
            raise ValueError(f"unknown index type {f.index_type}")
        mut = seg.SegmentedState(
            base_tombs=(f.tombs[0] if f.tombs is not None
                        else np.zeros(f.enc.n, dtype=bool)),
            extras=[seg.Segment(enc=e.enc, ids=e.ids, tombs=f.tombs[i + 1])
                    for i, e in enumerate(f.extras)],
            next_ordinal=len(f.extras) + 1,
        )
        return MonaVec(backend=be, mut=mut, meta=f.meta, tuned=f.tune)

"""BruteForce backend (paper §3.4.1): SIMD-vectorized linear scan.

Zero build time, deterministic, memory-compact — the recommended default for
embedded/offline corpora.  On TPU the scan is the Pallas nibble-dot kernel
over the full packed corpus; scores then pre-filter + top-k.

This backend's scan body IS the shared primitive ``ops.score_raw`` /
``score_packed``: the query engine (``repro.engine``, DESIGN.md §7) builds
its per-segment scan stages directly on it and composes them with the
merge and top-k into a compiled ``SearchPlan``; ``search`` is a thin
routing shim over that engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import quantize as qz
from .allowlist import Allowlist

#: Pure plan-stage callables this module exports (repro.analysis coverage
#: hook, DESIGN.md §10: the determinism auditor fails if a listed stage is
#: never captured on its grid).
PLAN_STAGES = ("scan_stage",)


def scan_stage(
    q_rot: jnp.ndarray,
    packed: jnp.ndarray,
    *,
    bits: int,
    n4_dims: int = 0,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw full-corpus scan — the jitted body exposed as a pure PLAN STAGE
    (DESIGN.md §7): [b, d'] rotated queries × [n, bytes] packed codes →
    [b, n] RAW scores.  The metric adjustment deliberately stays outside
    (the engine runs it eagerly so XLA cannot FMA-contract the L2 adjust);
    every array is an argument, never a trace constant."""
    return ops.score_raw(packed, q_rot, bits=bits, n4_dims=n4_dims,
                         use_kernel=use_kernel, interpret=interpret)


@dataclasses.dataclass
class BruteForceIndex:
    enc: qz.Encoded
    ids: np.ndarray  # [n] external ids (u64 in the .mvec file)

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        *,
        ids: Optional[np.ndarray] = None,
        metric: str = "cosine",
        seed: int = 0x6D6F6E61,
        bits: int = 4,
        std=None,
        avg_bits: Optional[float] = None,
    ) -> "BruteForceIndex":
        n = vectors.shape[0]
        if avg_bits is not None and avg_bits != 4:
            enc = qz.encode_mixed(vectors, metric=metric, seed=seed, avg_bits=avg_bits, std=std)
        else:
            enc = qz.encode(vectors, metric=metric, seed=seed, bits=bits, std=std)
        if ids is None:
            ids = np.arange(n, dtype=np.uint64)
        return BruteForceIndex(enc=enc, ids=np.asarray(ids, dtype=np.uint64))

    def scores(
        self,
        queries: jnp.ndarray,
        *,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
    ) -> jnp.ndarray:
        """Adjusted scores [b, n] of the full packed corpus — the per-segment
        scan primitive the segmented search concatenates (DESIGN.md §6)."""
        q_rot = qz.encode_query(jnp.atleast_2d(queries), self.enc)
        return ops.score_packed(q_rot, self.enc, use_kernel=use_kernel,
                                interpret=interpret)

    def search(
        self,
        queries: jnp.ndarray,
        k: int,
        *,
        allow: Optional[Allowlist] = None,
        where_mask=None,
        use_kernel: Optional[bool] = None,   # None = backend dispatch
        interpret: Optional[bool] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (scores [b,k], external_ids [b,k]).  Deterministic:
        stable top-k (lower row index wins ties).  Always exactly ``k``
        columns: slots with no admissible row (a selective allowlist — or a
        corpus — smaller than k) come back with SENTINEL_ID and a NEG score,
        the same no-result contract as IVF/HNSW and the segmented scan
        (§3.5: exactly min(k, allowed) real results, never disallowed
        filler).  ``where_mask`` is a compiled predicate's [n] boolean row
        mask (DESIGN.md §8), ANDed into the live mask pre-top-k.  Routed
        through the compiled-plan engine (DESIGN.md §7)."""
        from .. import engine
        return engine.search_backend(
            self, None, queries, k, allow=allow, where_mask=where_mask,
            use_kernel=use_kernel, interpret=interpret,
        )

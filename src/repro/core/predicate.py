"""Structured predicates over metadata columns (DESIGN.md §8).

A small AST — ``Eq/Ne/Lt/Le/Gt/Ge/In`` over columns, composed with
``And/Or/Not`` (also spelled ``&``, ``|``, ``~``) — that compiles to a
vectorized boolean-mask PLAN STAGE fused with the engine's tombstone/
allowlist live-mask machinery:

    idx.search(q, 10, where=Eq("lang", "en") & (Ge("date", 20260101)))

Three views of one predicate, all guaranteed to agree:

  * ``evaluate(p, store)`` — the host-side numpy oracle, computed on the
    exact original values (int64/float64/str).  This is the semantics; the
    golden and hypothesis suites pin everything else against it.
  * ``structure(p, schema)`` — the predicate's SHAPE (ops, column names and
    kinds, In-set sizes) with the constants stripped.  This tuple goes into
    the plan fingerprint, so two queries with the same predicate structure
    but different constants share one compiled plan: zero retraces.
  * ``build_stage_fn(p)`` + ``flatten_args(p, store)`` — the device lowering.
    The stage function consumes, per comparison leaf in preorder, the
    column's uint32 key planes and the constant's key planes (dynamic
    arguments), and reproduces the host comparison bit-exactly: the u64 keys
    are order-and-equality-preserving (metadata.py), and lexicographic
    comparison on (hi, lo) uint32 pairs is u64 comparison.

Ordering comparisons on ``str`` columns are rejected at validation: codes
are interning order, not collation order.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Iterator, List, Tuple

if TYPE_CHECKING:                  # typing only: this module stays jax-free
    import jax.numpy as jnp

import numpy as np

from .metadata import (KIND_STR, MetaStore, NO_MATCH_KEY, encode_constant,
                       split_key)


#: repro.analysis coverage hook (DESIGN.md §10): ``build_stage_fn`` output is
#: the predicate-mask plan stage; the auditor's grid must capture it.
PLAN_STAGES = ("build_stage_fn",)


class Predicate:
    """Base: composable with ``&``, ``|``, ``~``."""

    def __and__(self, other: "Predicate") -> "Predicate":
        return And(self, other)

    def __or__(self, other: "Predicate") -> "Predicate":
        return Or(self, other)

    def __invert__(self) -> "Predicate":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class _Cmp(Predicate):
    col: str
    value: object

    op = ""          # overridden

    def __str__(self) -> str:
        return f"{self.op}({self.col}, {self.value!r})"


class Eq(_Cmp):
    op = "eq"


class Ne(_Cmp):
    op = "ne"


class Lt(_Cmp):
    op = "lt"


class Le(_Cmp):
    op = "le"


class Gt(_Cmp):
    op = "gt"


class Ge(_Cmp):
    op = "ge"


@dataclasses.dataclass(frozen=True)
class In(Predicate):
    col: str
    values: tuple

    op = "in"

    def __post_init__(self) -> None:
        object.__setattr__(self, "values", tuple(self.values))
        if not self.values:
            raise ValueError("In() needs at least one value")


@dataclasses.dataclass(frozen=True)
class And(Predicate):
    lhs: Predicate
    rhs: Predicate


@dataclasses.dataclass(frozen=True)
class Or(Predicate):
    lhs: Predicate
    rhs: Predicate


@dataclasses.dataclass(frozen=True)
class Not(Predicate):
    inner: Predicate


_ORDERING = frozenset({"lt", "le", "gt", "ge"})


def _leaves(p: Predicate) -> Iterator[Predicate]:
    """Comparison leaves in preorder — the canonical argument order."""
    if isinstance(p, (And, Or)):
        yield from _leaves(p.lhs)
        yield from _leaves(p.rhs)
    elif isinstance(p, Not):
        yield from _leaves(p.inner)
    else:
        yield p


def used_columns(p: Predicate) -> Tuple[str, ...]:
    out: List[str] = []
    for leaf in _leaves(p):
        if leaf.col not in out:
            out.append(leaf.col)
    return tuple(out)


def validate(p: Predicate, store: MetaStore) -> None:
    """Check columns exist, ops suit their kinds, constants are typed right.

    Raises before any plan work, with the column/op named — the same errors
    the host oracle would hit, surfaced eagerly.
    """
    for leaf in _leaves(p):
        if not isinstance(leaf, (_Cmp, In)):
            raise TypeError(f"not a predicate node: {leaf!r}")
        col = store[leaf.col]
        if leaf.op in _ORDERING and col.kind == KIND_STR:
            raise TypeError(
                f"ordering comparison {leaf.op!r} is not defined on str "
                f"column {leaf.col!r} (codes are interning order)")
        vocab = col.vocab_map()
        values = leaf.values if isinstance(leaf, In) else (leaf.value,)
        for v in values:
            encode_constant(col.kind, v, vocab)     # raises on bad type


# ---------------------------------------------------------------------------
# Structure fingerprint: the shape without the constants.
# ---------------------------------------------------------------------------

def structure(p: Predicate, store: MetaStore) -> tuple:
    if isinstance(p, And):
        return ("and", structure(p.lhs, store), structure(p.rhs, store))
    if isinstance(p, Or):
        return ("or", structure(p.lhs, store), structure(p.rhs, store))
    if isinstance(p, Not):
        return ("not", structure(p.inner, store))
    kind = store[p.col].kind
    if isinstance(p, In):
        # len(values) is a traced SHAPE (the constant array's), so it is
        # structure, not constant.
        return ("in", p.col, kind, len(p.values))
    return (p.op, p.col, kind)


# ---------------------------------------------------------------------------
# Host oracle (numpy, exact original values).
# ---------------------------------------------------------------------------

def evaluate(p: Predicate, store: MetaStore) -> np.ndarray:
    """[n_rows] bool — the reference semantics every other path must match."""
    if isinstance(p, And):
        return evaluate(p.lhs, store) & evaluate(p.rhs, store)
    if isinstance(p, Or):
        return evaluate(p.lhs, store) | evaluate(p.rhs, store)
    if isinstance(p, Not):
        return ~evaluate(p.inner, store)
    col = store[p.col]
    vals = col.values
    if col.kind == KIND_STR:
        lut = col.vocab_map()
        if isinstance(p, In):
            codes = [lut.get(v, -1) for v in p.values]
            return np.isin(vals, np.asarray(codes, dtype=np.int64))
        code = lut.get(p.value, -1)
        hit = vals == code
        return ~hit if p.op == "ne" else hit
    if isinstance(p, In):
        return np.isin(vals, np.asarray(list(p.values), dtype=vals.dtype))
    c = vals.dtype.type(p.value)
    return {
        "eq": lambda: vals == c, "ne": lambda: vals != c,
        "lt": lambda: vals < c, "le": lambda: vals <= c,
        "gt": lambda: vals > c, "ge": lambda: vals >= c,
    }[p.op]()


# ---------------------------------------------------------------------------
# Device lowering: stage builder + per-call argument packing.
# ---------------------------------------------------------------------------

def _key_cmp(op: str, ch: jnp.ndarray, cl: jnp.ndarray, kh: jnp.ndarray,
             kl: jnp.ndarray) -> jnp.ndarray:
    """u64 comparison on (hi, lo) uint32 planes — jnp, selection-only."""
    eq = (ch == kh) & (cl == kl)
    if op == "eq":
        return eq
    if op == "ne":
        return ~eq
    lt = (ch < kh) | ((ch == kh) & (cl < kl))
    if op == "lt":
        return lt
    if op == "ge":
        return ~lt
    if op == "le":
        return lt | eq
    return ~(lt | eq)                                # gt


def build_stage_fn(p: Predicate) -> Callable[..., jnp.ndarray]:
    """Compile the AST into ``fn(live, *args) -> live & mask``.

    Pure jnp boolean algebra over the flat argument tuple (preorder leaf
    order: column hi, column lo, constant hi, constant lo).  No float
    arithmetic anywhere — the mask is exact under any XLA fusion, so the
    stage composes with the engine's bit-identity contract for free.
    """
    def rec(node):
        if isinstance(node, And):
            fa, fb = rec(node.lhs), rec(node.rhs)
            return lambda it: fa(it) & fb(it)
        if isinstance(node, Or):
            fa, fb = rec(node.lhs), rec(node.rhs)
            return lambda it: fa(it) | fb(it)
        if isinstance(node, Not):
            fa = rec(node.inner)
            return lambda it: ~fa(it)
        op = node.op

        def leaf(it, op=op):
            ch, cl, kh, kl = (next(it) for _ in range(4))
            if op == "in":          # [n,1] vs [m] -> any over the value set
                hit = (ch[:, None] == kh[None, :]) & (cl[:, None] == kl[None, :])
                return hit.any(axis=1)
            return _key_cmp(op, ch, cl, kh, kl)
        return leaf

    inner = rec(p)

    def fn(live: jnp.ndarray, *args: jnp.ndarray) -> jnp.ndarray:
        return live & inner(iter(args))

    return fn


def flatten_args(p: Predicate, store: MetaStore) -> Tuple[np.ndarray, ...]:
    """Per-call dynamic operands for the compiled stage, in preorder.

    Constants are mapped through the column's key function HERE, at call
    time — they are arguments of the stage, never trace constants, which is
    what makes "same structure, different constants" a plan-cache hit.
    """
    out: List[np.ndarray] = []
    for leaf in _leaves(p):
        col = store[leaf.col]
        vocab = col.vocab_map()
        out.append(col.key_hi)
        out.append(col.key_lo)
        values = leaf.values if isinstance(leaf, In) else (leaf.value,)
        keys = np.asarray(
            [encode_constant(col.kind, v, vocab) for v in values],
            dtype=np.uint64)
        kh, kl = split_key(keys)
        if not isinstance(leaf, In):
            kh, kl = kh[0], kl[0]                   # scalar operands
        out.append(kh)
        out.append(kl)
    return tuple(out)


__all__ = [
    "Predicate", "Eq", "Ne", "Lt", "Le", "Gt", "Ge", "In", "And", "Or",
    "Not", "validate", "structure", "evaluate", "build_stage_fn",
    "flatten_args", "used_columns", "NO_MATCH_KEY",
]

"""Asymmetric scoring (paper §3.3): f32 query × packed 4-bit corpus.

The reference path here is pure jnp (dequantize-then-matmul); the production
hot path is the Pallas kernel in ``repro.kernels.nibble_dot`` which fuses the
nibble unpack, compare-select dequant, and the MXU matmul.  Both share the
metric adjustment below and are validated against each other in tests.

Metric adjustments (q_norm = ||dequantized rotated vector||):
    cosine: s / q_norm        (length renormalization, RaBitQ-inspired)
    dot:    s
    l2:     s - q_norm^2 / 2  (from -||q-v||^2 = 2<q,v> - ||q||^2 - ||v||^2,
                               dropping the query-constant; HIGHER = closer)
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import quantize as qz
from .standardize import COSINE, DOT, L2


def adjust_scores(raw: jnp.ndarray, qnorms: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Apply the per-metric score correction.  raw: [..., n]; qnorms: [n]."""
    if metric == COSINE:
        return raw / jnp.maximum(qnorms, 1e-12)
    if metric == DOT:
        return raw
    if metric == L2:
        return raw - 0.5 * qnorms * qnorms
    raise ValueError(f"unknown metric {metric!r}")


def score_packed_ref(
    q_rot: jnp.ndarray,
    enc: qz.Encoded,
) -> jnp.ndarray:
    """Reference scoring: [b, d'] rotated f32 queries vs Encoded corpus -> [b, n].

    Dequantize the whole corpus then one matmul.  Used as the oracle for the
    Pallas kernel and for small corpora; O(n d') f32 intermediate.
    """
    deq = qz.decode(enc)                     # [n, d']
    raw = q_rot @ deq.T                      # [b, n]
    return adjust_scores(raw, enc.qnorms, enc.metric)


def score_f32(
    q: jnp.ndarray,
    corpus: jnp.ndarray,
    metric: str,
) -> jnp.ndarray:
    """Exact f32 scoring (the sqlite-vec-style accuracy ceiling / ground truth).

    Returns 'higher is better' scores for every metric.
    """
    if metric == COSINE:
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        cn = corpus / jnp.maximum(jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-12)
        return qn @ cn.T
    if metric == DOT:
        return q @ corpus.T
    if metric == L2:
        # -||q - v||^2, expanded for one matmul.
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        v2 = jnp.sum(corpus * corpus, axis=-1)
        return 2.0 * (q @ corpus.T) - q2 - v2[None, :]
    raise ValueError(f"unknown metric {metric!r}")


def topk(scores: jnp.ndarray, k: int):
    """Deterministic top-k: jax.lax.top_k is stable (lower index wins ties)."""
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx


def build_score_l2(q_rot: jnp.ndarray, v_rot: jnp.ndarray) -> jnp.ndarray:
    """HNSW L2 *build-time* score  <q,v> - ||v||^2/2  (paper contribution #3).

    Monotone in -||q-v||^2 for fixed q; using plain <q,v> here corrupts the
    graph topology (0.31 -> 0.62 Recall@10 on fashion-mnist when fixed).
    """
    return q_rot @ v_rot.T - 0.5 * jnp.sum(v_rot * v_rot, axis=-1)[None, :]

"""Segmented mutable corpus lifecycle (DESIGN.md §6).

Every backend in this repo builds an IMMUTABLE quantized artifact — the
SQLite deployment profile the paper targets (on-device RAG, offline agents)
needs corpora that grow and churn between sessions.  The resolution here is
the classic LSM/FAISS shape: a ``MonaVec`` is a *sequence of immutable
quantized segments* plus per-segment *deletion bitmaps*:

  * segment 0 is the backend built by ``MonaVec.build`` (BruteForce, IVF or
    HNSW), quantized under the root seed;
  * ``add(vectors, ids)`` quantizes a NEW segment through the same
    RHDH + Lloyd-Max pipeline, under a seed derived deterministically from
    (root seed, segment ordinal) — ``derive_segment_seed`` — so replaying
    the same op sequence reproduces the same packed bytes everywhere;
  * ``delete(ids)`` never rewrites codes: it sets tombstone bits;
  * ``compact()`` deterministically rewrites the live rows into a single
    fresh segment-0 (codes → rotated space → inverse RHDH → re-encode under
    the root seed; IVF/HNSW rebuild their structure over the reconstructed
    vectors).

``search`` scans every segment and merges PRE-top-k: tombstoned (and
disallowed) rows are masked to the NEG sentinel before any ranking, so the
§3.5 pre-filter guarantee ("exactly min(k, live∩allowed) real results")
survives mutation.  BruteForce concatenates the per-segment packed-scan
score matrices into one [b, n_total] matrix and runs a single stable top-k;
IVF/HNSW search the main index (tombstones folded into the allowlist mask)
and merge a brute-force side-scan of the extra segments through the same
``scoring.topk`` machinery — main-index candidates occupy the lower columns,
so stable top-k resolves score ties exactly like the concatenated-row-order
oracle.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import quantize as qz
from .allowlist import NEG, Allowlist
from .rhdh import rhdh_inverse
from .scoring import topk
from .standardize import L2

#: "no result" external id (the IVF/HNSW sentinel contract, extended to every
#: mutated-index search path).
SENTINEL_ID = np.uint64(0xFFFFFFFFFFFFFFFF)

_MASK64 = 0xFFFFFFFFFFFFFFFF
_GOLDEN = 0x9E3779B97F4A7C15

#: repro.analysis coverage hook (DESIGN.md §10): pure plan stages exported
#: here; the determinism auditor's grid must capture each one.
PLAN_STAGES = ("merge_stage",)


def derive_segment_seed(root_seed: int, ordinal: int) -> int:
    """Deterministic per-segment RHDH seed.

    Ordinal 0 (the base segment) keeps the root seed — a never-mutated index
    is byte-identical to the pre-segment format.  Later ordinals go through
    a splitmix64 finalizer so segment rotations are mutually independent but
    a pure function of (root, ordinal): the same op sequence replays to the
    same packed bytes on any platform.
    """
    if ordinal == 0:
        return root_seed & _MASK64
    z = (root_seed + _GOLDEN * ordinal) & _MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return z ^ (z >> 31)


@dataclasses.dataclass
class Segment:
    """One immutable quantized block + its (mutable) deletion bitmap."""

    enc: qz.Encoded
    ids: np.ndarray                  # [n] u64 external ids
    tombs: np.ndarray                # [n] bool — True = deleted

    @property
    def n(self) -> int:
        return int(self.ids.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.n - self.tombs.sum())


@dataclasses.dataclass
class SegmentedState:
    """The mutation state riding on a MonaVec: base-segment tombstones plus
    the extra segments appended by add()."""

    base_tombs: np.ndarray                       # [base_n] bool
    extras: List[Segment] = dataclasses.field(default_factory=list)
    next_ordinal: int = 1                        # ordinal of the NEXT add()

    @staticmethod
    def fresh(base_n: int) -> "SegmentedState":
        return SegmentedState(base_tombs=np.zeros(base_n, dtype=bool))

    @property
    def is_static(self) -> bool:
        """True when the index is indistinguishable from a build-once one
        (no extra segments, nothing tombstoned) — the fast path, and the
        condition under which save() still writes v6/v7."""
        return not self.extras and not self.base_tombs.any()


# ---------------------------------------------------------------------------
# Segment encoding: the add() quantization path.
# ---------------------------------------------------------------------------

def encode_segment(vectors: jnp.ndarray, base: qz.Encoded, seed: int) -> qz.Encoded:
    """Quantize a new segment under the BASE segment's configuration (metric,
    bit mode, std, v7 permutation, coarse-code kind) but its own derived
    seed.  When the base carries a binarized coarse code the new segment
    derives its own from its packed codes (a pure function — DESIGN.md §11),
    so add()/compact() keep every segment cascade-capable."""
    vectors = jnp.asarray(vectors)
    if base.bits in (2, 4):
        enc = qz.encode(vectors, metric=base.metric, seed=seed,
                        bits=base.bits, std=base.std)
    else:
        # Mixed mode: pin n4_dims to the base split (allocate_bits is
        # avg-driven; the override keeps every segment's packed layout
        # byte-compatible).
        enc = qz.encode_mixed(vectors, metric=base.metric, seed=seed,
                              std=base.std, perm=base.perm,
                              n4_dims=base.n4_dims)
    if base.coarse is not None:
        from . import binary
        enc = binary.attach_coarse(enc, base.coarse)
    return enc


def reconstruct_vectors(enc: qz.Encoded) -> np.ndarray:
    """Codes → approximate input-space f32 rows (the compact() rewrite path).

    Dequantize to rotated space, invert the unnormalized RHDH (Z = H D x, so
    x = D H Z / d'), then undo the metric preparation: L2 standardization is
    affine-invertible; cosine preparation loses magnitude, which cosine
    scoring never used; dot preparation is the identity.  Pure function of
    the codes — compaction is deterministic by construction.
    """
    deq = qz.decode(enc)                               # [n, d'] rotated f32
    d_pad = deq.shape[-1]
    x = rhdh_inverse(deq, enc.seed, enc.dim) * np.float32(1.0 / np.sqrt(d_pad))
    x = np.asarray(x, dtype=np.float32)
    if enc.metric == L2 and enc.std is not None:
        x = x / np.float32(enc.std.inv_std) + np.float32(enc.std.mean)
    return x


def reconstruct_rows(enc: qz.Encoded, rows: np.ndarray) -> np.ndarray:
    """``reconstruct_vectors`` restricted to a row subset.

    The autotuner (repro.tune) draws its seeded sample queries from the
    corpus itself; decoding only the sampled rows keeps tuning O(samples)
    instead of O(n).  Row-sliced packed codes decode independently (packing
    is per-row), so this equals ``reconstruct_vectors(enc)[rows]``.
    """
    rows = np.asarray(rows, dtype=np.int64)
    sub = dataclasses.replace(
        enc,
        packed=np.asarray(enc.packed)[rows],
        qnorms=np.asarray(enc.qnorms)[rows],
    )
    return reconstruct_vectors(sub)


# ---------------------------------------------------------------------------
# Segmented search.
# ---------------------------------------------------------------------------

def rows_to_ids(rows: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Map row positions to external ids; negative rows → SENTINEL_ID
    (the shared no-result contract of every candidate-set search path)."""
    out = ids[np.maximum(rows, 0)].copy()
    out[rows < 0] = SENTINEL_ID
    return out


def _split_allow_mask(
    allow: Optional[Allowlist], base_n: int, extras: Sequence[Segment]
) -> Tuple[Optional[np.ndarray], List[Optional[np.ndarray]]]:
    """Slice a concatenated-row allowlist into per-segment masks.

    Allowlists against a mutated index are built over ``MonaVec.ids`` — the
    concatenation of every segment's id array (tombstoned rows included, so
    positions are stable across delete()).
    """
    if allow is None:
        return None, [None] * len(extras)
    mask = np.asarray(allow.mask, dtype=bool)
    total = base_n + sum(s.n for s in extras)
    if mask.shape[0] != total:
        raise ValueError(
            f"allowlist mask covers {mask.shape[0]} rows but the segmented "
            f"index has {total}; build it from MonaVec.ids"
        )
    out, off = [], base_n
    for s in extras:
        out.append(mask[off: off + s.n])
        off += s.n
    return mask[:base_n], out


def live_mask(
    state: SegmentedState, allow: Optional[Allowlist], base_n: int
) -> np.ndarray:
    """Concatenated [n_total] bool mask of live∩allowed rows — the single
    dynamic mask argument every SearchPlan takes (tombstones and allowlists
    change between calls; the compiled plan does not)."""
    base_mask, extra_masks = _split_allow_mask(allow, base_n, state.extras)
    cols = [~state.base_tombs if base_mask is None
            else (~state.base_tombs & base_mask)]
    for s, am in zip(state.extras, extra_masks):
        cols.append(~s.tombs if am is None else (~s.tombs & am))
    return np.concatenate(cols) if len(cols) > 1 else cols[0]


def merge_stage(
    main_vals: jnp.ndarray,      # [b, k] candidate-scan scores (NEG sentinels)
    main_pos: jnp.ndarray,       # [b, k] base row positions, -1 sentinel
    side_scores: jnp.ndarray,    # [b, n_extra] masked extra-segment scores
    base_n: int,
    k: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-top-k merge of a candidate-set scan (IVF/HNSW) with the extra
    segments' brute-force side-scan — a pure PLAN STAGE (DESIGN.md §7).

    Main candidates occupy the lower columns, so the stable top-k resolves
    score ties to the base segment first, then extras in row order — exactly
    like the concatenated-row-order oracle.  Returns (vals [b,k], positions
    [b,k] in concatenated row order, -1 sentinel).
    """
    b, n_extra = side_scores.shape
    side_pos = jnp.broadcast_to(
        base_n + jnp.arange(n_extra, dtype=main_pos.dtype)[None, :],
        (b, n_extra))
    cand_scores = jnp.concatenate([main_vals, side_scores], axis=1)
    cand_pos = jnp.concatenate([main_pos, side_pos], axis=1)
    vals, sel = topk(cand_scores, min(k, cand_scores.shape[1]))
    pos = jnp.take_along_axis(cand_pos, sel, axis=1)
    return vals, jnp.where(vals > NEG, pos, -1)


def search_segmented(
    backend,
    state: SegmentedState,
    queries: jnp.ndarray,
    k: int,
    *,
    allow: Optional[Allowlist] = None,
    where_mask=None,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    tuned=None,
    **kwargs,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k over base segment + extras, tombstones masked pre-top-k.

    Slots with no admissible candidate (k exceeds the live∩allowed count)
    come back with SENTINEL_ID and a NEG score — the IVF/HNSW no-result
    contract, uniform across every mutated search path.  Since DESIGN.md §7
    this is a thin delegate: the per-segment scans and the merge run as
    stages of one compiled SearchPlan (``repro.engine``)."""
    from .. import engine
    return engine.search_backend(
        backend, state, queries, k, allow=allow, where_mask=where_mask,
        use_kernel=use_kernel, interpret=interpret, tuned=tuned, **kwargs,
    )

"""Identity-based multi-tenancy (paper §3.9) as a pure-function contract.

The paper's service layer verifies Bearer tokens against an OAuth2-style
introspection endpoint; here the HTTP hop is abstracted to an injected
``verify(token) -> user_id | None`` callable (the five-line adapter the paper
describes), with the same semantics:

  * verifier configured  -> failures are rejected (None namespace);
    responses are cached for ``cache_ttl`` seconds; a stale cache entry is
    served if the verifier raises (graceful degradation).
  * standalone mode (no verifier) -> the token IS the namespace key.
  * no token -> the shared ``__public__`` namespace.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

from repro import obs

from .api import MonaVec

PUBLIC_NAMESPACE = "__public__"


@dataclasses.dataclass
class TenantRegistry:
    verifier: Optional[Callable[[str], Optional[str]]] = None
    cache_ttl: float = 30.0
    _cache: Dict[str, Tuple[float, Optional[str]]] = dataclasses.field(default_factory=dict)
    _spaces: Dict[str, Dict[str, MonaVec]] = dataclasses.field(default_factory=dict)
    _clock: Callable[[], float] = time.monotonic

    # -- identity ----------------------------------------------------------

    def resolve_namespace(self, token: Optional[str]) -> Optional[str]:
        """Token -> namespace key (None = reject / 401)."""
        if token is None or token == "":
            return PUBLIC_NAMESPACE
        if self.verifier is None:
            return token  # standalone: token-as-namespace
        now = self._clock()
        hit = self._cache.get(token)
        if hit is not None and now - hit[0] < self.cache_ttl:
            return hit[1]
        try:
            user = self.verifier(token)
        except Exception:
            if hit is not None:  # stale cache served on verifier outage
                return hit[1]
            return None
        self._cache[token] = (now, user)
        return user

    # -- collections ----------------------------------------------------------

    def put(self, token: Optional[str], name: str, index: MonaVec) -> str:
        ns = self.resolve_namespace(token)
        if ns is None:
            obs.inc("tenancy.errors", kind="401")
            raise PermissionError("401: token rejected")
        self._spaces.setdefault(ns, {})[name] = index
        return ns

    def get(self, token: Optional[str], name: str) -> MonaVec:
        """Resolve + fetch; every successful call counts as one request
        under its ``{namespace, collection}`` labels (DESIGN.md §9) — the
        per-namespace request counter the metrics snapshot exposes."""
        ns = self.resolve_namespace(token)
        if ns is None:
            obs.inc("tenancy.errors", kind="401")
            raise PermissionError("401: token rejected")
        try:
            index = self._spaces[ns][name]
        except KeyError:
            obs.inc("tenancy.errors", kind="missing_collection",
                    **{"namespace": ns})
            raise KeyError(f"collection {name!r} not found in namespace {ns!r}") from None
        obs.inc("tenancy.requests", **{"namespace": ns, "collection": name})
        return index

    def collections(self, token: Optional[str]):
        ns = self.resolve_namespace(token)
        if ns is None:
            obs.inc("tenancy.errors", kind="401")
            raise PermissionError("401: token rejected")
        return sorted(self._spaces.get(ns, {}).keys())

    # -- per-namespace mutation (DESIGN.md §6) -----------------------------
    #
    # The segmented lifecycle surfaces through the same token -> namespace
    # -> collection resolution as search: a tenant can only grow/churn its
    # own collections, and every path 401s exactly like get().

    def searcher(self, token: Optional[str], name: str, k: int = 10,
                 where=None, **knobs):
        """Bound engine Searcher over a tenant's collection (DESIGN.md §7):
        the handle the serving loop keeps per (tenant, collection) so every
        request is a plan-cache hit, with the same 401 semantics as get().
        ``where=`` binds a metadata predicate (DESIGN.md §8) into every call
        — per-namespace filtered serving.  The returned Searcher carries
        ``{namespace, collection}`` metric labels, so each call lands in the
        per-namespace ``tenancy.search_us`` latency histogram (DESIGN.md
        §9)."""
        if where is not None:
            knobs["where"] = where
        ns = self.resolve_namespace(token)   # get() below re-checks + counts
        searcher = self.get(token, name).searcher(k=k, **knobs)
        searcher.labels = (("namespace", ns), ("collection", name))
        return searcher

    def add(self, token: Optional[str], name: str, vectors, ids=None,
            meta=None):
        """Append rows to a tenant's collection; returns the assigned ids."""
        return self.get(token, name).add(vectors, ids=ids, meta=meta)

    def delete(self, token: Optional[str], name: str, ids) -> int:
        """Tombstone rows in a tenant's collection; returns rows deleted."""
        return self.get(token, name).delete(ids)

    def compact(self, token: Optional[str], name: str) -> int:
        """Compact a tenant's collection; returns rows reclaimed."""
        return self.get(token, name).compact()

    def autotune(self, token: Optional[str], name: str,
                 recall_target: float = 0.95, **kwargs):
        """Autotune a tenant's collection (DESIGN.md §12); returns the
        TuneResult now riding on the collection (and persisted by save())."""
        return self.get(token, name).autotune(
            recall_target=recall_target, **kwargs).tuned

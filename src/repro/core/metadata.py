"""Per-row metadata columns (DESIGN.md §8, format v9).

The paper's deployment scenario — on-device RAG — is rarely "top-k over
everything": real queries are "top-k WHERE lang=en AND date>cutoff" (the
Faiss library paper treats metadata-filtered search as a first-class index
operation).  A ``MetaStore`` attaches named, typed columns to an index,
row-aligned with ``MonaVec.ids`` (the concatenation of every segment's rows,
tombstoned included, so positions are stable across delete()):

  * ``i64``  — numpy int64 values, exact;
  * ``f64``  — numpy float64 values, exact (NaN rejected, -0.0 canonicalized
    to +0.0 so equality and ordering are total);
  * ``str``  — small-enum interned strings: an index-global vocabulary per
    column plus int32 codes per row (the classic dictionary encoding).

Exactness contract.  Predicates over these columns must evaluate to the SAME
boolean mask on the host (the numpy oracle, ``predicate.evaluate``) and on
the device (the compiled plan stage) — but JAX runs with x64 disabled, so
shipping raw int64/float64 to a trace would silently truncate values and
flip comparisons.  The resolution: every column lowers ONCE to an
order-and-equality-preserving unsigned-64 key, stored as two uint32 planes
(``key_hi``/``key_lo``):

  * i64  -> two's-complement bits with the sign bit flipped (monotone);
  * f64  -> the IEEE-754 total-order map (negatives -> ~bits, positives ->
    bits | 2^63), which preserves <, =, > exactly on non-NaN values;
  * str  -> the non-negative vocab code (equality-only; ordering rejected).

Any comparison on (hi, lo) pairs — lexicographic on two uint32 planes — then
reproduces the original int64/float64 comparison bit-exactly inside a trace,
with the predicate CONSTANTS mapped through the same function at call time
(so they ride as dynamic arguments and never force a retrace).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

KIND_I64, KIND_F64, KIND_STR = "i64", "f64", "str"
KINDS = (KIND_I64, KIND_F64, KIND_STR)
_KIND_CODE = {KIND_I64: 0, KIND_F64: 1, KIND_STR: 2}
_KIND_NAME = {v: k for k, v in _KIND_CODE.items()}

_U64_MASK = (1 << 64) - 1
_SIGN = 1 << 63
#: u64 key guaranteed to equal no interned code (codes are int32 >= 0).
NO_MATCH_KEY = _U64_MASK


def kind_code(kind: str) -> int:
    return _KIND_CODE[kind]


def kind_name(code: int) -> str:
    if code not in _KIND_NAME:
        raise ValueError(f"unknown metadata column kind code {code}")
    return _KIND_NAME[code]


# ---------------------------------------------------------------------------
# Order-preserving u64 keys (host-side, computed once per column version).
# ---------------------------------------------------------------------------

def _i64_keys(values: np.ndarray) -> np.ndarray:
    return values.view(np.uint64) ^ np.uint64(_SIGN)


def _f64_keys(values: np.ndarray) -> np.ndarray:
    bits = values.view(np.uint64)
    return np.where(bits >> np.uint64(63) != 0,
                    ~bits, bits | np.uint64(_SIGN))


def encode_constant(kind: str, value, vocab: Optional[Dict[str, int]]) -> int:
    """Map one predicate constant through the column's key function.

    Returns a python int in [0, 2^64); out-of-vocabulary strings map to
    ``NO_MATCH_KEY`` so equality against them is False for every row.
    """
    if kind == KIND_I64:
        if isinstance(value, bool) or not isinstance(value, (int, np.integer)):
            raise TypeError(
                f"i64 column constant must be an int, got {value!r}")
        v = int(value)
        if not (-(1 << 63) <= v < (1 << 63)):
            raise TypeError(f"i64 constant out of range: {value!r}")
        return (v & _U64_MASK) ^ _SIGN
    if kind == KIND_F64:
        if isinstance(value, bool) or not isinstance(
                value, (int, float, np.integer, np.floating)):
            raise TypeError(
                f"f64 column constant must be a number, got {value!r}")
        arr = np.asarray([value], dtype=np.float64)
        if np.isnan(arr[0]):
            raise TypeError("f64 column constant may not be NaN")
        arr[arr == 0.0] = 0.0          # -0.0 == +0.0: one canonical key
        return int(_f64_keys(arr)[0])
    if kind == KIND_STR:
        if not isinstance(value, str):
            raise TypeError(
                f"str column constant must be a string, got {value!r}")
        code = (vocab or {}).get(value)
        return NO_MATCH_KEY if code is None else code
    raise ValueError(f"unknown column kind {kind!r}")


def split_key(keys) -> Tuple[np.ndarray, np.ndarray]:
    """u64 key(s) -> (hi, lo) uint32 planes (trace-safe dtypes)."""
    k = np.asarray(keys, dtype=np.uint64)
    return ((k >> np.uint64(32)).astype(np.uint32),
            (k & np.uint64(0xFFFFFFFF)).astype(np.uint32))


# ---------------------------------------------------------------------------
# Columns + the store.
# ---------------------------------------------------------------------------

#: Monotone token minted per Column construction.  Every mutation path
#: (append / gather / load) builds NEW Column objects, so a column's
#: ``version`` changing is a sound proxy for "its values may have changed" —
#: the selectivity estimator (repro.tune) keys its caches on these tokens
#: instead of hashing the value arrays.
_COLUMN_VERSIONS = itertools.count(1)


@dataclasses.dataclass
class Column:
    """One typed column: exact host values + the precomputed device keys."""

    kind: str
    values: np.ndarray                    # i64 / f64, or int32 codes for str
    vocab: Optional[List[str]] = None     # str columns: code -> string
    key_hi: np.ndarray = dataclasses.field(init=False)
    key_lo: np.ndarray = dataclasses.field(init=False)
    version: int = dataclasses.field(init=False, compare=False, repr=False)

    def __post_init__(self) -> None:
        self.version = next(_COLUMN_VERSIONS)
        self._rekey()

    def _rekey(self) -> None:
        if self.kind == KIND_I64:
            keys = _i64_keys(self.values)
        elif self.kind == KIND_F64:
            keys = _f64_keys(self.values)
        else:
            keys = self.values.astype(np.uint64)    # codes are >= 0
        self.key_hi, self.key_lo = split_key(keys)

    @property
    def n(self) -> int:
        return int(self.values.shape[0])

    def vocab_map(self) -> Optional[Dict[str, int]]:
        return None if self.vocab is None else {
            s: i for i, s in enumerate(self.vocab)}

    def decoded(self) -> np.ndarray:
        """Host-facing values (strings materialized for str columns)."""
        if self.kind != KIND_STR:
            return self.values
        return np.asarray([self.vocab[c] for c in self.values], dtype=object)


def _ingest(name: str, data, vocab: Optional[List[str]],
            kind: Optional[str]) -> Column:
    """Coerce one user-supplied column; kind inferred unless pinned."""
    arr = np.asarray(data)
    if arr.ndim != 1:
        raise ValueError(f"metadata column {name!r} must be 1-D, got shape "
                         f"{arr.shape}")
    if kind is None:
        if arr.dtype == bool or np.issubdtype(arr.dtype, np.integer):
            kind = KIND_I64
        elif np.issubdtype(arr.dtype, np.floating):
            kind = KIND_F64
        elif arr.dtype.kind in ("U", "O", "S"):
            kind = KIND_STR
        else:
            raise TypeError(f"metadata column {name!r}: cannot infer a kind "
                            f"from dtype {arr.dtype}")
    if kind == KIND_I64:
        if not (arr.dtype == bool or np.issubdtype(arr.dtype, np.integer)):
            raise TypeError(f"metadata column {name!r} is i64 but got "
                            f"dtype {arr.dtype}")
        return Column(kind=KIND_I64, values=arr.astype(np.int64))
    if kind == KIND_F64:
        if not np.issubdtype(arr.dtype, np.number) or arr.dtype == bool:
            raise TypeError(f"metadata column {name!r} is f64 but got "
                            f"dtype {arr.dtype}")
        vals = arr.astype(np.float64).copy()
        if np.isnan(vals).any():
            raise ValueError(f"metadata column {name!r} contains NaN "
                             "(unsupported: NaN breaks total ordering)")
        vals[vals == 0.0] = 0.0        # canonicalize -0.0
        return Column(kind=KIND_F64, values=vals)
    # str: intern against the (possibly pre-existing, index-global) vocab.
    voc = list(vocab) if vocab else []
    lut = {s: i for i, s in enumerate(voc)}
    codes = np.empty(arr.shape[0], dtype=np.int32)
    for i, v in enumerate(arr.tolist()):
        if not isinstance(v, str):
            raise TypeError(f"metadata column {name!r} is str but row {i} "
                            f"is {v!r}")
        code = lut.get(v)
        if code is None:
            code = lut[v] = len(voc)
            voc.append(v)
        codes[i] = code
    return Column(kind=KIND_STR, values=codes, vocab=voc)


@dataclasses.dataclass
class MetaStore:
    """Named typed columns, row-aligned with the index's concatenated rows."""

    columns: "collections.OrderedDict[str, Column]"

    # -- construction ------------------------------------------------------

    @staticmethod
    def build(data: Mapping[str, Sequence], n_rows: int) -> "MetaStore":
        cols: "collections.OrderedDict[str, Column]" = collections.OrderedDict()
        for name in data:
            if not isinstance(name, str) or not name:
                raise ValueError(f"metadata column name must be a non-empty "
                                 f"string, got {name!r}")
            col = _ingest(name, data[name], vocab=None, kind=None)
            if col.n != n_rows:
                raise ValueError(
                    f"metadata column {name!r} has {col.n} rows but the "
                    f"index has {n_rows}")
            cols[name] = col
        return MetaStore(columns=cols)

    # -- introspection -----------------------------------------------------

    @property
    def n_rows(self) -> int:
        return next(iter(self.columns.values())).n if self.columns else 0

    @property
    def schema(self) -> Tuple[Tuple[str, str], ...]:
        """Ordered (name, kind) pairs — part of the plan fingerprint."""
        return tuple((name, c.kind) for name, c in self.columns.items())

    def __bool__(self) -> bool:
        return bool(self.columns)

    def __getitem__(self, name: str) -> Column:
        try:
            return self.columns[name]
        except KeyError:
            raise KeyError(
                f"unknown metadata column {name!r}; this index has "
                f"{sorted(self.columns)}") from None

    # -- lifecycle ---------------------------------------------------------

    def append(self, data: Mapping[str, Sequence], n_new: int) -> None:
        """Extend every column by one segment's rows (add() path).

        The batch must supply EXACTLY the schema's columns; enum values not
        yet in a column's vocabulary extend it (the vocab is index-global,
        codes are append-only so existing rows never re-encode).
        """
        got, want = set(data), set(self.columns)
        if got != want:
            raise ValueError(
                f"add: metadata columns {sorted(got)} do not match the "
                f"index schema {sorted(want)}")
        staged = {}
        for name, col in self.columns.items():
            new = _ingest(name, data[name], vocab=col.vocab, kind=col.kind)
            if new.n != n_new:
                raise ValueError(
                    f"add: metadata column {name!r} has {new.n} rows, "
                    f"expected {n_new}")
            staged[name] = new
        for name, col in self.columns.items():
            new = staged[name]
            self.columns[name] = Column(
                kind=col.kind,
                values=np.concatenate([col.values, new.values]),
                vocab=new.vocab if col.kind == KIND_STR else None,
            )

    def gather(self, keep: np.ndarray) -> "MetaStore":
        """Row-select every column (compact() carries columns through)."""
        cols: "collections.OrderedDict[str, Column]" = collections.OrderedDict()
        for name, c in self.columns.items():
            cols[name] = Column(kind=c.kind, values=c.values[keep],
                                vocab=None if c.vocab is None else list(c.vocab))
        return MetaStore(columns=cols)

    def slice(self, lo: int, hi: int) -> Dict[str, np.ndarray]:
        """Per-segment value blocks, for the v9 writer."""
        return {name: c.values[lo:hi] for name, c in self.columns.items()}

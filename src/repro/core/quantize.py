"""Encode pipeline: prepare -> RHDH -> Lloyd-Max -> nibble pack (+ norms).

This is the paper's quantization core (§3.1), end to end.  Everything here is
data-oblivious for cosine/dot; L2 optionally consumes a GlobalStd from fit().

Packed layouts
--------------
4-bit: two codes per byte, code[2i] in the low nibble, code[2i+1] in the high
nibble (matches the paper's .mvec payload arithmetic: d=1024 -> 512 B/vector).
2-bit: four codes per byte, little-endian within the byte.
Mixed: [4-bit block | 2-bit block] per vector (§3.2), with the 4-bit block
holding either the leading dims (paper-faithful mode) or the top-variance dims
under a persisted permutation (our format v7 extension — the paper computes the
permutation but does not persist it; we do, and record the deviation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import lloydmax
from .rhdh import rhdh_apply
from .standardize import COSINE, GlobalStd, prepare


# ---------------------------------------------------------------------------
# Nibble / crumb packing.
# ---------------------------------------------------------------------------

def pack_4bit(codes: jnp.ndarray) -> jnp.ndarray:
    """[..., d] uint8 codes in [0,16) -> [..., d//2] packed bytes."""
    d = codes.shape[-1]
    assert d % 2 == 0, "4-bit packing requires even dim"
    c = codes.reshape(codes.shape[:-1] + (d // 2, 2)).astype(jnp.uint8)
    return (c[..., 0] | (c[..., 1] << 4)).astype(jnp.uint8)


def unpack_4bit(packed: jnp.ndarray) -> jnp.ndarray:
    """[..., d//2] packed bytes -> [..., d] uint8 codes."""
    lo = packed & 0xF
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (packed.shape[-1] * 2,))


def pack_2bit(codes: jnp.ndarray) -> jnp.ndarray:
    """[..., d] uint8 codes in [0,4) -> [..., d//4] packed bytes."""
    d = codes.shape[-1]
    assert d % 4 == 0, "2-bit packing requires dim % 4 == 0"
    c = codes.reshape(codes.shape[:-1] + (d // 4, 4)).astype(jnp.uint8)
    return (c[..., 0] | (c[..., 1] << 2) | (c[..., 2] << 4) | (c[..., 3] << 6)).astype(jnp.uint8)


def unpack_2bit(packed: jnp.ndarray) -> jnp.ndarray:
    parts = [(packed >> (2 * i)) & 0x3 for i in range(4)]
    return jnp.stack(parts, axis=-1).reshape(packed.shape[:-1] + (packed.shape[-1] * 4,))


# ---------------------------------------------------------------------------
# Encoded corpus container.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Encoded:
    """A quantized corpus (the in-memory form of the .mvec payload)."""

    packed: jnp.ndarray          # [n, bytes_per_vec] uint8
    qnorms: jnp.ndarray          # [n] f32 — norm of the DEQUANTIZED rotated vector
    seed: int                    # RHDH seed (lives in the .mvec header)
    metric: str
    bits: int                    # 4, 2, or 3 (mixed)
    dim: int                     # original input dim d
    dim_pad: int                 # rotated dim d' = next_pow2(d)
    n4_dims: int = 0             # 4-bit dims in mixed mode (paper header N4_DIMS)
    std: Optional[GlobalStd] = None
    perm: Optional[np.ndarray] = None   # mixed-mode variance permutation (v7 ext)
    coarse: Optional[str] = None        # binarized coarse-code kind ("sign"/"crumb")
    ccodes: Optional[jnp.ndarray] = None  # [n, code_bytes] uint8 coarse codes (v10)

    @property
    def n(self) -> int:
        return int(self.packed.shape[0])

    def bytes_per_vector(self) -> int:
        return int(self.packed.shape[-1])


def _quantize_rotated(rot: jnp.ndarray, bits: int, table: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rotated f32 -> (codes, dequantized values)."""
    codes = lloydmax.quantize(rot, bits, table=table)
    deq = lloydmax.dequantize(codes, bits, table=table)
    return codes, deq


def encode(
    x: jnp.ndarray,
    *,
    metric: str = COSINE,
    seed: int = 0x6D6F6E61,  # "mona"
    bits: int = 4,
    std: Optional[GlobalStd] = None,
    table: str = "lloydmax",
) -> Encoded:
    """Full pipeline on a [n, d] batch.  Pure function of (x, args) — the same
    inputs produce the same packed bytes on every platform (determinism)."""
    assert bits in (2, 4), "use encode_mixed for the 4/2 split"
    n, d = x.shape
    prepared = prepare(x.astype(jnp.float32), metric, std)
    rot = rhdh_apply(prepared, seed, normalized=False)  # quantizer space: ~N(0,1)
    codes, deq = _quantize_rotated(rot, bits, table)
    qnorms = jnp.linalg.norm(deq, axis=-1)
    packed = pack_4bit(codes) if bits == 4 else pack_2bit(codes)
    return Encoded(
        packed=packed, qnorms=qnorms, seed=seed, metric=metric, bits=bits,
        dim=d, dim_pad=rot.shape[-1], std=std,
    )


def decode(enc: Encoded) -> jnp.ndarray:
    """Dequantize to rotated-space f32 (debug / oracle path)."""
    if enc.bits == 4:
        codes = unpack_4bit(enc.packed)
        return lloydmax.dequantize(codes, 4)
    if enc.bits == 2:
        codes = unpack_2bit(enc.packed)
        return lloydmax.dequantize(codes, 2)
    return decode_mixed(enc)


# ---------------------------------------------------------------------------
# Mixed precision (paper §3.2): water-filling 4-bit / 2-bit split.
# ---------------------------------------------------------------------------

def allocate_bits(dim_pad: int, avg_bits: float) -> int:
    """Number of 4-bit dims n4 such that (4 n4 + 2 (d'-n4)) / d' == avg_bits.

    The paper derives the variance threshold analytically from the desired
    average width; with a two-level {2,4} codebook this reduces to the closed
    form below (clamped, and rounded to a multiple of 4 so both blocks pack).
    """
    n4 = int(round(dim_pad * (avg_bits - 2.0) / 2.0))
    n4 = max(0, min(dim_pad, n4))
    return (n4 // 4) * 4


def variance_permutation(sample_rot: jnp.ndarray) -> np.ndarray:
    """Dims sorted by descending variance over a rotated sample (water-filling).

    Ties broken by index for determinism.
    """
    var = np.asarray(jnp.var(sample_rot, axis=0))
    # np.argsort with kind='stable' on -var: descending variance, index tiebreak.
    return np.argsort(-var, kind="stable").astype(np.int32)


def encode_mixed(
    x: jnp.ndarray,
    *,
    metric: str = COSINE,
    seed: int = 0x6D6F6E61,
    avg_bits: float = 3.0,
    std: Optional[GlobalStd] = None,
    perm: Optional[np.ndarray] = None,
    n4_dims: Optional[int] = None,
) -> Encoded:
    """Mixed 4/2-bit encoding.  If ``perm`` is None the 4-bit block holds the
    LEADING dims (the paper's current implementation, §3.2 'Implementation
    status'); passing a variance permutation enables the v7 persisted-perm mode.
    ``n4_dims`` pins the 4/2 split directly (segment encodes must match the
    base segment's packed layout byte-for-byte) instead of deriving it from
    ``avg_bits``.
    """
    n, d = x.shape
    prepared = prepare(x.astype(jnp.float32), metric, std)
    rot = rhdh_apply(prepared, seed, normalized=False)
    d_pad = rot.shape[-1]
    n4 = allocate_bits(d_pad, avg_bits) if n4_dims is None else n4_dims

    if perm is not None:
        rot = rot[:, jnp.asarray(perm)]

    rot4, rot2 = rot[:, :n4], rot[:, n4:]
    codes4, deq4 = _quantize_rotated(rot4, 4, "lloydmax")
    codes2, deq2 = _quantize_rotated(rot2, 2, "lloydmax")
    qnorms = jnp.sqrt(jnp.sum(deq4 * deq4, axis=-1) + jnp.sum(deq2 * deq2, axis=-1))
    packed = jnp.concatenate([pack_4bit(codes4), pack_2bit(codes2)], axis=-1)
    return Encoded(
        packed=packed, qnorms=qnorms, seed=seed, metric=metric, bits=3,
        dim=d, dim_pad=d_pad, n4_dims=n4, std=std,
        perm=None if perm is None else np.asarray(perm),
    )


def decode_mixed(enc: Encoded) -> jnp.ndarray:
    n4 = enc.n4_dims
    b4 = n4 // 2
    codes4 = unpack_4bit(enc.packed[:, :b4])
    codes2 = unpack_2bit(enc.packed[:, b4:])
    deq = jnp.concatenate(
        [lloydmax.dequantize(codes4, 4), lloydmax.dequantize(codes2, 2)], axis=-1
    )
    if enc.perm is not None:
        inv = np.empty_like(enc.perm)
        inv[enc.perm] = np.arange(len(enc.perm), dtype=enc.perm.dtype)
        deq = deq[:, jnp.asarray(inv)]
    return deq


def encode_query(
    q: jnp.ndarray,
    enc_meta: Encoded,
) -> jnp.ndarray:
    """Query-side preparation: SAME prepare+rotate as the corpus, NO quantization
    (asymmetric scoring keeps the query in f32 — paper §3.3/§5.2)."""
    prepared = prepare(q.astype(jnp.float32), enc_meta.metric, enc_meta.std)
    rot = rhdh_apply(prepared, enc_meta.seed, normalized=False)
    if enc_meta.perm is not None:
        rot = rot[..., jnp.asarray(enc_meta.perm)]
    return rot

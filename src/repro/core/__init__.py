# MonaVec core: the paper's primary contribution in JAX.
#
# Data-oblivious quantization (RHDH + Lloyd-Max), asymmetric scoring, three
# index backends, segmented mutable lifecycle (add/delete/compact), pre-filter
# allowlist, metadata columns + compiled predicates, hybrid BM25+RRF,
# single-file .mvec persistence (v6-v9), and identity-based multi-tenancy.

from .api import MonaVec
from .allowlist import Allowlist
from .bruteforce import BruteForceIndex
from .hnsw import HnswIndex, recommended_m
from .hybrid import HybridIndex
from .ivf import IvfFlatIndex
from .metadata import MetaStore
from .predicate import And, Eq, Ge, Gt, In, Le, Lt, Ne, Not, Or, Predicate
from .segments import SENTINEL_ID, Segment, SegmentedState, derive_segment_seed
from .standardize import COSINE, DOT, L2, GlobalStd
from .tenancy import TenantRegistry

__all__ = [
    "MonaVec", "Allowlist", "BruteForceIndex", "HnswIndex", "HybridIndex",
    "IvfFlatIndex", "TenantRegistry", "GlobalStd", "recommended_m",
    "Segment", "SegmentedState", "SENTINEL_ID", "derive_segment_seed",
    "COSINE", "DOT", "L2",
    "MetaStore", "Predicate",
    "Eq", "Ne", "Lt", "Le", "Gt", "Ge", "In", "And", "Or", "Not",
]

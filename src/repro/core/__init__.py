# MonaVec core: the paper's primary contribution in JAX.
#
# Data-oblivious quantization (RHDH + Lloyd-Max), asymmetric scoring, three
# index backends, pre-filter allowlist, hybrid BM25+RRF, single-file .mvec
# persistence, and identity-based multi-tenancy.

from .api import MonaVec
from .allowlist import Allowlist
from .bruteforce import BruteForceIndex
from .hnsw import HnswIndex, recommended_m
from .hybrid import HybridIndex
from .ivf import IvfFlatIndex
from .standardize import COSINE, DOT, L2, GlobalStd
from .tenancy import TenantRegistry

__all__ = [
    "MonaVec", "Allowlist", "BruteForceIndex", "HnswIndex", "HybridIndex",
    "IvfFlatIndex", "TenantRegistry", "GlobalStd", "recommended_m",
    "COSINE", "DOT", "L2",
]

"""`.mvec` single-file index format, versions 6-10 (paper §3.8 + DESIGN.md §6/§8/§11).

Fixed 56-byte header followed by variable-length blocks.  The embedded SEED
makes load→search reproduce the same top-K on any platform; all payloads are
little-endian, integer code bytes are bit-identical across machines.

Header layout (offsets in bytes, little-endian):
    0   MAGIC       4s   b"MVEC"
    4   VERSION     u32  6 (7 when a mixed-precision permutation block is
                         persisted — our documented extension, DESIGN.md §2;
                         8 when the index is MUTATED: extra segments and/or
                         tombstones — DESIGN.md §6; 9 when per-row METADATA
                         COLUMNS are attached — DESIGN.md §8; 10 when a
                         binarized COARSE CODE block is attached —
                         DESIGN.md §11; 11 when a persisted AUTOTUNE
                         result block is attached — DESIGN.md §12)
    8   DIM         u32  input dimension d
    12  METRIC      u8   0=Cosine 1=Dot 2=L2
    13  BIT_WIDTH   u8   2, 3 (mixed) or 4
    14  INDEX_TYPE  u8   0=BruteForce 1=IvfFlat 2=HNSW
    15  PAD         u8
    16  COUNT       u64  rows in the BASE segment (extras carry their own)
    24  SEED        u64  root rotation seed (ChaCha20 in the paper; threefry
                         here); extra segments persist their derived seeds
    32  N4_DIMS     u32  4-bit dims in mixed mode
    36  INDEX_PARAMS 8B  (u32 nlist / M, u32 param2: HNSW persists
                         ef_construction here so compact() can rebuild the
                         graph with the build-time beam width; previously a
                         reserved-zero field, so pre-existing readers and
                         files are unaffected)
    44  HAS_STD     u8   1 if global standardization block follows
    45  HAS_PERM    u8   v8+ only: 1 if a permutation block follows (v7
                         signals the same through VERSION; always 0 in v6/v7)
    46  COARSE_KIND u8   v10 only: 1=sign 2=crumb (always 0 before v10, so
                         v6-v9 files are byte-identical to their pre-v10
                         serialization)
    47  HAS_META    u8   v10 only: 1 if the metadata column table follows
                         (v9 signals the same through VERSION)
    48  RESERVED    8B   (pads the header to exactly 56 bytes)

Blocks (in order): STD_MEAN [f32 × dim], STD_INV_STD [f32 × dim] (if HAS_STD;
scalar globals replicated per the paper's field spec), PERM [i32 × dim_pad]
(v7, or v8/v9 with HAS_PERM), VECTORS [u8], IDS [u64], NORMS [f32],
INDEX_DATA (backend blob).  Version 8 appends the segment table and tombstone
bitmaps:

    SEG_COUNT  u32               number of EXTRA segments (>= 0)
    per extra segment, in ordinal order:
        SEG_SEED   u64           derived rotation seed
        SEG_VECTORS [u8]         packed codes (base layout: same bytes/vector)
        SEG_IDS     [u64]
        SEG_NORMS   [f32]
    per segment INCLUDING the base, in order:
        TOMBS      [u8]          np.packbits deletion bitmap (bit set = dead)

Version 9 (an index with metadata columns, mutated or not) writes the v8
body — SEG_COUNT may be 0 and the tombstone bitmaps all-zero — then the
metadata column table (DESIGN.md §8):

    COL_COUNT  u32               number of metadata columns (>= 1)
    per column, in schema order:
        NAME       str           u32 byte length + utf-8 bytes
        KIND       u8            0=i64  1=f64  2=str (interned enum)
        VOCAB      (str only)    u32 entry count, then that many strs
                                 (code -> string, index-global per column)
        per segment INCLUDING the base, in order:
            VALUES [i64|f64|i32] the segment's rows (i32 = vocab codes)

Version 10 (an index carrying binarized coarse codes for the cascade —
DESIGN.md §11) writes the v8 segment-table body, then the metadata column
table if HAS_META, then the coarse CODE block:

    per segment INCLUDING the base, in order:
        CODES      [u8]          row-major [n, code_bytes] coarse codes
                                 (code_bytes = dim_pad/8 for sign,
                                 dim_pad/4 for crumb; COARSE_KIND in the
                                 header names the layout)

The codes are a pure function of the packed bytes (``core.binary``), so v10
is a cache, not new information — but persisting it keeps load→search free
of any derivation pass, per the paper's mmap-and-go contract.

Version 11 (an index carrying a persisted AUTOTUNE result — DESIGN.md §12)
writes the v8 body, the metadata table if HAS_META, the coarse CODE blocks
if COARSE_KIND != 0 (unlike v10, a v11 file may omit them), then one
length-prefixed TUNE envelope:

    TUNE_LEN   u64               payload byte length
    payload:
        FORMAT         u32       1
        RECALL_TARGET  f64
        K              u32
        N_QUERIES      u32
        SEED           u64
        MET_TARGET     u8
        KNOBS          u32 count, then per knob (sorted by name):
                       NAME str, CHOSEN i64
        LADDERS        u32 count, then per ladder (sorted by name):
                       NAME str, u32 n_rungs, per rung: VALUE i64, RECALL f64
        HAS_BOOST      u8        if 1: u32 n_points, per point:
                       SELECTIVITY f64, MULT i64, RECALL f64

The tuned knobs become the engine's plan-key DEFAULTS on load; the sweep
ladder and boost curve persist so the choice is auditable offline.  The
TuneResult is a pure function of (corpus bytes, tuning seed), so v11 files
are byte-deterministic like every earlier version.

Every block is length-prefixed and every read is validated against the bytes
actually present — a truncated or garbage-tailed file raises ``ValueError``
naming the short block instead of letting ``np.frombuffer`` misparse it.
"""

from __future__ import annotations

import collections
import dataclasses
import io
import struct
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from . import metadata as md
from . import quantize as qz
from .standardize import COSINE, DOT, L2, GlobalStd

MAGIC = b"MVEC"
HEADER_LEN = 56
_METRIC_CODE = {COSINE: 0, DOT: 1, L2: 2}
_METRIC_NAME = {v: k for k, v in _METRIC_CODE.items()}
INDEX_BRUTEFORCE, INDEX_IVF, INDEX_HNSW = 0, 1, 2
SUPPORTED_VERSIONS = (6, 7, 8, 9, 10, 11)
_META_DTYPE = {md.KIND_I64: np.int64, md.KIND_F64: np.float64,
               md.KIND_STR: np.int32}
_COARSE_CODE = {"sign": 1, "crumb": 2}
_COARSE_NAME = {v: k for k, v in _COARSE_CODE.items()}


def _write_array(buf: io.BytesIO, arr: np.ndarray) -> None:
    """Length-prefixed raw little-endian block."""
    raw = np.ascontiguousarray(arr).astype(arr.dtype.newbyteorder("<")).tobytes()
    buf.write(struct.pack("<Q", len(raw)))
    buf.write(raw)


def _write_str(buf: io.BytesIO, s: str) -> None:
    raw = s.encode("utf-8")
    buf.write(struct.pack("<I", len(raw)))
    buf.write(raw)


class _Reader:
    """Validating block reader: every short read raises ValueError naming the
    block, so truncated/garbage files fail loudly at the exact bad offset."""

    def __init__(self, data: bytes, pos: int = 0):
        self.data = data
        self.pos = pos

    def take(self, nbytes: int, name: str) -> bytes:
        chunk = self.data[self.pos: self.pos + nbytes]
        if len(chunk) != nbytes:
            raise ValueError(
                f".mvec truncated in block {name!r}: need {nbytes} bytes at "
                f"offset {self.pos}, only {len(chunk)} available"
            )
        self.pos += nbytes
        return chunk

    def u32(self, name: str) -> int:
        return struct.unpack("<I", self.take(4, name))[0]

    def u64(self, name: str) -> int:
        return struct.unpack("<Q", self.take(8, name))[0]

    def u8(self, name: str) -> int:
        return self.take(1, name)[0]

    def i64(self, name: str) -> int:
        return struct.unpack("<q", self.take(8, name))[0]

    def f64(self, name: str) -> float:
        return struct.unpack("<d", self.take(8, name))[0]

    def str_(self, name: str) -> str:
        nbytes = self.u32(f"{name} length")
        try:
            return self.take(nbytes, name).decode("utf-8")
        except UnicodeDecodeError as e:
            raise ValueError(
                f".mvec corrupt block {name!r}: invalid utf-8 ({e})"
            ) from None

    def array(self, dtype, name: str, count: Optional[int] = None) -> np.ndarray:
        nbytes = self.u64(f"{name} length")
        dt = np.dtype(dtype).newbyteorder("<")
        if nbytes % dt.itemsize:
            raise ValueError(
                f".mvec corrupt block {name!r}: {nbytes} bytes is not a "
                f"multiple of itemsize {dt.itemsize}"
            )
        arr = np.frombuffer(self.take(nbytes, name), dtype=dt)
        if count is not None and arr.size != count:
            raise ValueError(
                f".mvec corrupt block {name!r}: expected {count} elements, "
                f"found {arr.size}"
            )
        return arr

    def expect_eof(self) -> None:
        extra = len(self.data) - self.pos
        if extra:
            raise ValueError(
                f".mvec garbage tail: {extra} unexpected bytes after the "
                f"final block (offset {self.pos})"
            )


@dataclasses.dataclass
class ExtraSegment:
    """One add()-appended segment as persisted in the v8 segment table."""

    enc: qz.Encoded
    ids: np.ndarray


@dataclasses.dataclass
class MvecFile:
    enc: qz.Encoded
    ids: np.ndarray
    index_type: int
    index_param: int = 0          # nlist (IVF) or M (HNSW)
    index_data: Optional[bytes] = None
    index_param2: int = 0         # HNSW ef_construction (0 = unknown)
    extras: List[ExtraSegment] = dataclasses.field(default_factory=list)
    tombs: Optional[List[np.ndarray]] = None   # [1+len(extras)] bool bitmaps
    meta: Optional[md.MetaStore] = None        # v9: per-row metadata columns
    tune: Optional[object] = None              # v11: repro.tune.TuneResult


def _bytes_per_vector(dim_pad: int, bits: int, n4_dims: int) -> int:
    if bits == 4:
        return dim_pad // 2
    if bits == 2:
        return dim_pad // 4
    return n4_dims // 2 + (dim_pad - n4_dims) // 4   # mixed


def _write_tune(buf: io.BytesIO, tune) -> None:
    """Serialize one TuneResult as the v11 TUNE envelope (module docstring).

    Duck-typed on the TuneResult attribute names so this module never needs
    a ``repro.tune`` import.  Knobs and ladders are written in sorted name
    order, so the bytes are independent of dict construction order.
    """
    body = io.BytesIO()
    body.write(struct.pack("<IdIIQB", 1, float(tune.recall_target),
                           int(tune.k), int(tune.n_queries),
                           int(tune.seed) & 0xFFFFFFFFFFFFFFFF,
                           1 if tune.met_target else 0))
    knobs = dict(tune.knobs)
    body.write(struct.pack("<I", len(knobs)))
    for name in sorted(knobs):
        _write_str(body, name)
        body.write(struct.pack("<q", int(knobs[name])))
    ladder = dict(tune.ladder)
    body.write(struct.pack("<I", len(ladder)))
    for name in sorted(ladder):
        _write_str(body, name)
        rungs = tuple(ladder[name])
        body.write(struct.pack("<I", len(rungs)))
        for r in rungs:
            body.write(struct.pack("<qd", int(r.value), float(r.recall)))
    if tune.boost is None:
        body.write(struct.pack("<B", 0))
    else:
        points = tuple(tune.boost.points)
        body.write(struct.pack("<BI", 1, len(points)))
        for p in points:
            body.write(struct.pack("<dqd", float(p.selectivity),
                                   int(p.mult), float(p.recall)))
    payload = body.getvalue()
    buf.write(struct.pack("<Q", len(payload)))
    buf.write(payload)


def _read_tune(rd: _Reader):
    """Parse the TUNE envelope into a ``repro.tune.TuneResult``."""
    from repro.tune.result import (BoostCurve, BoostPoint, KnobRung,
                                   TuneResult)
    tune_len = rd.u64("tune length")
    sub = _Reader(rd.take(tune_len, "tune"))
    fmt_code = sub.u32("tune format")
    if fmt_code != 1:
        raise ValueError(
            f".mvec corrupt block 'tune': unknown tune format {fmt_code}")
    recall_target = sub.f64("tune recall_target")
    k = sub.u32("tune k")
    n_queries = sub.u32("tune n_queries")
    seed = sub.u64("tune seed")
    met = sub.u8("tune met_target")
    if met not in (0, 1):
        raise ValueError(
            f".mvec corrupt block 'tune': met_target must be 0 or 1, "
            f"got {met}")
    knobs = {}
    for i in range(sub.u32("tune knob count")):
        name = sub.str_(f"tune knob[{i}] name")
        knobs[name] = sub.i64(f"tune knob[{i}] value")
    ladder = {}
    for i in range(sub.u32("tune ladder count")):
        name = sub.str_(f"tune ladder[{i}] name")
        ladder[name] = tuple(
            KnobRung(value=sub.i64(f"tune ladder[{i}] rung[{ri}] value"),
                     recall=sub.f64(f"tune ladder[{i}] rung[{ri}] recall"))
            for ri in range(sub.u32(f"tune ladder[{i}] rung count")))
    boost = None
    if sub.u8("tune has_boost"):
        points = tuple(
            BoostPoint(selectivity=sub.f64(f"tune boost[{pi}] selectivity"),
                       mult=sub.i64(f"tune boost[{pi}] mult"),
                       recall=sub.f64(f"tune boost[{pi}] recall"))
            for pi in range(sub.u32("tune boost point count")))
        try:
            boost = BoostCurve(points=points)
        except ValueError as e:
            raise ValueError(f".mvec corrupt block 'tune': {e}") from None
    sub.expect_eof()
    return TuneResult(recall_target=recall_target, k=k, n_queries=n_queries,
                      seed=seed, met_target=bool(met), knobs=knobs,
                      ladder=ladder, boost=boost)


def save(path: str, f: MvecFile) -> None:
    enc = f.enc
    mutated = bool(f.extras) or (
        f.tombs is not None and any(t.any() for t in f.tombs)
    )
    has_meta = f.meta is not None and bool(f.meta)
    seg_encs = [enc] + [seg.enc for seg in f.extras]
    with_codes = [e.ccodes is not None for e in seg_encs]
    has_codes = any(with_codes)
    if has_codes:
        if not all(with_codes):
            raise ValueError(
                "coarse codes must be attached to every segment or to none "
                f"({sum(with_codes)} of {len(with_codes)} segments have them)"
            )
        if any(e.coarse != enc.coarse for e in seg_encs):
            raise ValueError("segments disagree on the coarse-code kind")
    if f.tune is not None:
        version = 11
    elif has_codes:
        version = 10
    elif has_meta:
        version = 9
    elif mutated:
        version = 8
    else:
        version = 7 if enc.perm is not None else 6
    seg_rows = [int(enc.n)] + [int(seg.ids.shape[0]) for seg in f.extras]
    if has_meta and f.meta.n_rows != sum(seg_rows):
        raise ValueError(
            f"metadata has {f.meta.n_rows} rows but the index has "
            f"{sum(seg_rows)}"
        )
    has_std = enc.std is not None
    has_perm = enc.perm is not None
    header = struct.pack(
        "<4sIIBBBBQQIIIBB10s",
        MAGIC, version, enc.dim,
        _METRIC_CODE[enc.metric], enc.bits, f.index_type, 0,
        enc.n, enc.seed & 0xFFFFFFFFFFFFFFFF,
        enc.n4_dims, f.index_param, f.index_param2,
        1 if has_std else 0,
        1 if (version >= 8 and has_perm) else 0,
        bytes([
            _COARSE_CODE[enc.coarse] if (version >= 10 and has_codes) else 0,
            1 if (version >= 10 and has_meta) else 0,
        ]) + b"\x00" * 8,
    )
    assert len(header) == HEADER_LEN, len(header)
    buf = io.BytesIO()
    buf.write(header)
    if has_std:
        # Scalar globals replicated across dim (format field is [f32 × dim]).
        _write_array(buf, np.full(enc.dim, enc.std.mean, dtype=np.float32))
        _write_array(buf, np.full(enc.dim, enc.std.inv_std, dtype=np.float32))
    if enc.perm is not None:
        _write_array(buf, enc.perm.astype(np.int32))
    _write_array(buf, np.asarray(enc.packed, dtype=np.uint8))
    _write_array(buf, np.asarray(f.ids, dtype=np.uint64))
    _write_array(buf, np.asarray(enc.qnorms, dtype=np.float32))
    blob = f.index_data or b""
    buf.write(struct.pack("<Q", len(blob)))
    buf.write(blob)
    if version >= 8:
        buf.write(struct.pack("<I", len(f.extras)))
        for seg in f.extras:
            buf.write(struct.pack("<Q", seg.enc.seed & 0xFFFFFFFFFFFFFFFF))
            _write_array(buf, np.asarray(seg.enc.packed, dtype=np.uint8))
            _write_array(buf, np.asarray(seg.ids, dtype=np.uint64))
            _write_array(buf, np.asarray(seg.enc.qnorms, dtype=np.float32))
        tombs = f.tombs or [np.zeros(n, dtype=bool) for n in seg_rows]
        for t in tombs:
            _write_array(buf, np.packbits(np.asarray(t, dtype=bool)))
    if has_meta:
        bounds = np.concatenate([[0], np.cumsum(seg_rows)]).tolist()
        buf.write(struct.pack("<I", len(f.meta.columns)))
        for name, col in f.meta.columns.items():
            _write_str(buf, name)
            buf.write(struct.pack("<B", md.kind_code(col.kind)))
            if col.kind == md.KIND_STR:
                buf.write(struct.pack("<I", len(col.vocab)))
                for entry in col.vocab:
                    _write_str(buf, entry)
            for lo, hi in zip(bounds, bounds[1:]):
                _write_array(buf, np.asarray(
                    col.values[lo:hi], dtype=_META_DTYPE[col.kind]))
    if version >= 10 and has_codes:
        for e in seg_encs:
            _write_array(buf, np.asarray(e.ccodes, dtype=np.uint8))
    if version == 11:
        _write_tune(buf, f.tune)
    with open(path, "wb") as fh:
        fh.write(buf.getvalue())


def load(path: str) -> MvecFile:
    with open(path, "rb") as fh:
        data = fh.read()
    if len(data) < HEADER_LEN:
        raise ValueError(
            f".mvec truncated in block 'header': need {HEADER_LEN} bytes, "
            f"only {len(data)} available"
        )
    (
        magic, version, dim, metric_c, bits, index_type, _pad,
        count, seed, n4_dims, index_param, param2, has_std, has_perm, _tail,
    ) = struct.unpack("<4sIIBBBBQQIIIBB10s", data[:HEADER_LEN])
    if magic != MAGIC:
        raise ValueError(f"not a .mvec file (magic={magic!r})")
    # Versions 1-5 predate this header layout entirely — parsing them against
    # the v6 offsets would silently misread every field, so reject anything
    # outside the layouts we actually implement.
    if version not in SUPPORTED_VERSIONS:
        raise ValueError(
            f"unsupported .mvec version {version} (this reader supports "
            f"versions {', '.join(map(str, SUPPORTED_VERSIONS))}; the highest "
            f"supported version is {SUPPORTED_VERSIONS[-1]})"
        )
    coarse_kind = None
    has_meta_flag = False
    if version >= 10:
        # v10 is DEFINED by its coarse codes; v11 (tune block) may carry
        # them or not, COARSE_KIND 0 meaning "no CODE blocks follow".
        if _tail[0] not in _COARSE_NAME and not (version >= 11
                                                 and _tail[0] == 0):
            raise ValueError(
                f".mvec corrupt header: version {version} requires "
                f"COARSE_KIND 1 (sign) or 2 (crumb)"
                f"{' or 0' if version >= 11 else ''}, got {_tail[0]}"
            )
        coarse_kind = _COARSE_NAME.get(_tail[0])
        has_meta_flag = bool(_tail[1])
    rd = _Reader(data, HEADER_LEN)
    std = None
    if has_std:
        mean = rd.array(np.float32, "std_mean", count=dim)
        inv = rd.array(np.float32, "std_inv_std", count=dim)
        std = GlobalStd(mean=float(mean[0]), inv_std=float(inv[0]))
    from .rhdh import next_pow2

    dim_pad = next_pow2(dim)
    perm = None
    if version == 7 or (version >= 8 and has_perm):
        perm = np.asarray(rd.array(np.int32, "perm", count=dim_pad))
    bytes_per = _bytes_per_vector(dim_pad, bits, n4_dims)

    def read_segment(prefix: str, n_rows: Optional[int], seg_seed: int):
        packed = rd.array(np.uint8, f"{prefix}vectors")
        if n_rows is None:
            if packed.size % bytes_per:
                raise ValueError(
                    f".mvec corrupt block '{prefix}vectors': {packed.size} "
                    f"bytes is not a multiple of {bytes_per} bytes/vector"
                )
            n_rows = packed.size // bytes_per
        elif packed.size != n_rows * bytes_per:
            raise ValueError(
                f".mvec corrupt block '{prefix}vectors': expected "
                f"{n_rows * bytes_per} bytes ({n_rows} rows x {bytes_per}), "
                f"found {packed.size}"
            )
        ids = rd.array(np.uint64, f"{prefix}ids", count=n_rows)
        qnorms = rd.array(np.float32, f"{prefix}norms", count=n_rows)
        enc = qz.Encoded(
            packed=jnp.asarray(packed.reshape(n_rows, bytes_per)),
            qnorms=jnp.asarray(qnorms), seed=int(seg_seed),
            metric=_METRIC_NAME[metric_c], bits=int(bits), dim=int(dim),
            dim_pad=dim_pad, n4_dims=int(n4_dims), std=std, perm=perm,
        )
        return enc, np.asarray(ids)

    enc, ids = read_segment("", int(count), int(seed))
    blob_len = rd.u64("index_data length")
    blob = rd.take(blob_len, "index_data") if blob_len else None

    extras: List[ExtraSegment] = []
    tombs: Optional[List[np.ndarray]] = None
    if version >= 8:
        n_extra = rd.u32("segment table")
        for i in range(n_extra):
            seg_seed = rd.u64(f"segment[{i}] seed")
            seg_enc, seg_ids = read_segment(f"segment[{i}] ", None, seg_seed)
            extras.append(ExtraSegment(enc=seg_enc, ids=seg_ids))
        tombs = []
        for i, n_rows in enumerate([int(count)] + [e.ids.shape[0] for e in extras]):
            packed_bits = rd.array(
                np.uint8, f"tombstones[{i}]", count=(n_rows + 7) // 8)
            tombs.append(np.unpackbits(packed_bits)[:n_rows].astype(bool))

    meta: Optional[md.MetaStore] = None
    if version == 9 or has_meta_flag:
        n_cols = rd.u32("metadata column table")
        if n_cols == 0:
            raise ValueError(
                ".mvec corrupt block 'metadata column table': the metadata "
                "column table requires at least one column"
            )
        seg_rows = [int(count)] + [int(e.ids.shape[0]) for e in extras]
        cols: "collections.OrderedDict[str, md.Column]" = (
            collections.OrderedDict())
        for ci in range(n_cols):
            name = rd.str_(f"column[{ci}] name")
            if not name or name in cols:
                raise ValueError(
                    f".mvec corrupt block 'column[{ci}] name': empty or "
                    f"duplicate column name {name!r}"
                )
            kind = md.kind_name(rd.u8(f"column[{ci}] kind"))
            vocab = None
            if kind == md.KIND_STR:
                n_vocab = rd.u32(f"column[{ci}] vocab count")
                vocab = [rd.str_(f"column[{ci}] vocab[{vi}]")
                         for vi in range(n_vocab)]
            blocks = [
                rd.array(_META_DTYPE[kind],
                         f"column[{ci}] segment[{si}] values", count=n)
                for si, n in enumerate(seg_rows)
            ]
            values = np.ascontiguousarray(
                np.concatenate(blocks).astype(_META_DTYPE[kind]))
            if kind == md.KIND_STR and values.size and (
                    values.min() < 0 or values.max() >= len(vocab)):
                raise ValueError(
                    f".mvec corrupt block 'column[{ci}]': code out of "
                    f"vocabulary range (vocab has {len(vocab)} entries)"
                )
            if kind == md.KIND_F64 and np.isnan(values).any():
                raise ValueError(
                    f".mvec corrupt block 'column[{ci}]': NaN in f64 column"
                )
            cols[name] = md.Column(kind=kind, values=values, vocab=vocab)
        meta = md.MetaStore(columns=cols)

    if version >= 10 and coarse_kind is not None:
        from .binary import code_bytes
        cb = code_bytes(dim_pad, coarse_kind)
        seg_ns = [int(count)] + [int(e.ids.shape[0]) for e in extras]
        seg_codes = []
        for i, n_rows in enumerate(seg_ns):
            codes = rd.array(np.uint8, f"coarse codes[{i}]",
                             count=n_rows * cb)
            seg_codes.append(jnp.asarray(codes.reshape(n_rows, cb)))
        enc = dataclasses.replace(enc, coarse=coarse_kind,
                                  ccodes=seg_codes[0])
        for seg, cc in zip(extras, seg_codes[1:]):
            seg.enc = dataclasses.replace(seg.enc, coarse=coarse_kind,
                                          ccodes=cc)
    tune = _read_tune(rd) if version == 11 else None
    rd.expect_eof()

    return MvecFile(
        enc=enc, ids=ids, index_type=int(index_type),
        index_param=int(index_param), index_data=blob,
        index_param2=int(param2),
        extras=extras, tombs=tombs, meta=meta, tune=tune,
    )


# ---------------------------------------------------------------------------
# Backend blobs (INDEX_DATA): length-prefixed numpy arrays.
# ---------------------------------------------------------------------------

def _blob_reader(blob: bytes) -> _Reader:
    return _Reader(blob, 0)


def pack_ivf_blob(centroids: np.ndarray, order: np.ndarray, offsets: np.ndarray) -> bytes:
    buf = io.BytesIO()
    _write_array(buf, centroids.astype(np.float32))
    buf.write(struct.pack("<II", *centroids.shape))
    _write_array(buf, order.astype(np.int64))
    _write_array(buf, offsets.astype(np.int64))
    return buf.getvalue()


def unpack_ivf_blob(blob: bytes):
    rd = _blob_reader(blob)
    cents = rd.array(np.float32, "ivf centroids")
    nlist = rd.u32("ivf nlist")
    d = rd.u32("ivf dim")
    if cents.size != nlist * d:
        raise ValueError(
            f".mvec corrupt block 'ivf centroids': expected {nlist * d} "
            f"elements, found {cents.size}"
        )
    order = rd.array(np.int64, "ivf order")
    offsets = rd.array(np.int64, "ivf offsets")
    rd.expect_eof()
    return cents.reshape(nlist, d), order, offsets


def pack_hnsw_blob(idx) -> bytes:
    buf = io.BytesIO()
    buf.write(struct.pack("<IIIii", idx.neighbors0.shape[0], idx.neighbors0.shape[1],
                          idx.neighbors_hi.shape[0], idx.entry_point, idx.max_level))
    _write_array(buf, idx.neighbors0.astype(np.int32))
    _write_array(buf, idx.neighbors_hi.astype(np.int32))
    _write_array(buf, idx.node_level.astype(np.int8))
    return buf.getvalue()


def unpack_hnsw_blob(blob: bytes):
    rd = _blob_reader(blob)
    n, m0, nhi, entry, max_level = struct.unpack("<IIIii", rd.take(20, "hnsw header"))
    nbr0 = rd.array(np.int32, "hnsw neighbors0", count=n * m0).reshape(n, m0)
    nbr_hi = rd.array(np.int32, "hnsw neighbors_hi", count=nhi * n * (m0 // 2))
    nbr_hi = nbr_hi.reshape(nhi, n, m0 // 2) if nhi else np.zeros((0, n, m0 // 2), np.int32)
    node_level = rd.array(np.int8, "hnsw node_level", count=n)
    rd.expect_eof()
    return nbr0, nbr_hi, node_level, entry, max_level

"""`.mvec` single-file index format, version 6 (paper §3.8).

Fixed 56-byte header followed by variable-length blocks.  The embedded SEED
makes load→search reproduce the same top-K on any platform; all payloads are
little-endian, integer code bytes are bit-identical across machines.

Header layout (offsets in bytes, little-endian):
    0   MAGIC       4s   b"MVEC"
    4   VERSION     u32  6 (7 when a mixed-precision permutation block is
                         persisted — our documented extension, DESIGN.md §2)
    8   DIM         u32  input dimension d
    12  METRIC      u8   0=Cosine 1=Dot 2=L2
    13  BIT_WIDTH   u8   2, 3 (mixed) or 4
    14  INDEX_TYPE  u8   0=BruteForce 1=IvfFlat 2=HNSW
    15  PAD         u8
    16  COUNT       u64
    24  SEED        u64  rotation seed (ChaCha20 in the paper; threefry here)
    32  N4_DIMS     u32  4-bit dims in mixed mode
    36  INDEX_PARAMS 8B  (u32 nlist / M, u32 reserved)
    44  HAS_STD     u8   1 if global standardization block follows
    45  PAD         u8
    46  RESERVED    10B  (pads the header to exactly 56 bytes)

Blocks (in order): STD_MEAN [f32 × dim], STD_INV_STD [f32 × dim] (if HAS_STD;
scalar globals replicated per the paper's field spec), PERM [i32 × dim_pad]
(v7 only), VECTORS [u8], IDS [u64], NORMS [f32], INDEX_DATA (backend blob).
"""

from __future__ import annotations

import dataclasses
import io
import struct
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import quantize as qz
from .standardize import COSINE, DOT, L2, GlobalStd

MAGIC = b"MVEC"
HEADER_LEN = 56
_METRIC_CODE = {COSINE: 0, DOT: 1, L2: 2}
_METRIC_NAME = {v: k for k, v in _METRIC_CODE.items()}
INDEX_BRUTEFORCE, INDEX_IVF, INDEX_HNSW = 0, 1, 2


def _write_array(buf: io.BytesIO, arr: np.ndarray) -> None:
    """Length-prefixed raw little-endian block."""
    raw = np.ascontiguousarray(arr).astype(arr.dtype.newbyteorder("<")).tobytes()
    buf.write(struct.pack("<Q", len(raw)))
    buf.write(raw)


def _read_array(buf: io.BytesIO, dtype: np.dtype, shape=None) -> np.ndarray:
    (nbytes,) = struct.unpack("<Q", buf.read(8))
    arr = np.frombuffer(buf.read(nbytes), dtype=np.dtype(dtype).newbyteorder("<"))
    return arr.reshape(shape) if shape is not None else arr


@dataclasses.dataclass
class MvecFile:
    enc: qz.Encoded
    ids: np.ndarray
    index_type: int
    index_param: int = 0          # nlist (IVF) or M (HNSW)
    index_data: Optional[bytes] = None


def save(path: str, f: MvecFile) -> None:
    enc = f.enc
    version = 7 if enc.perm is not None else 6
    has_std = enc.std is not None
    header = struct.pack(
        "<4sIIBBBBQQIIIBB10s",
        MAGIC, version, enc.dim,
        _METRIC_CODE[enc.metric], enc.bits, f.index_type, 0,
        enc.n, enc.seed & 0xFFFFFFFFFFFFFFFF,
        enc.n4_dims, f.index_param, 0,
        1 if has_std else 0, 0, b"\x00" * 10,
    )
    assert len(header) == HEADER_LEN, len(header)
    buf = io.BytesIO()
    buf.write(header)
    if has_std:
        # Scalar globals replicated across dim (format field is [f32 × dim]).
        _write_array(buf, np.full(enc.dim, enc.std.mean, dtype=np.float32))
        _write_array(buf, np.full(enc.dim, enc.std.inv_std, dtype=np.float32))
    if enc.perm is not None:
        _write_array(buf, enc.perm.astype(np.int32))
    _write_array(buf, np.asarray(enc.packed, dtype=np.uint8))
    _write_array(buf, np.asarray(f.ids, dtype=np.uint64))
    _write_array(buf, np.asarray(enc.qnorms, dtype=np.float32))
    blob = f.index_data or b""
    buf.write(struct.pack("<Q", len(blob)))
    buf.write(blob)
    with open(path, "wb") as fh:
        fh.write(buf.getvalue())


def load(path: str) -> MvecFile:
    with open(path, "rb") as fh:
        data = fh.read()
    (
        magic, version, dim, metric_c, bits, index_type, _pad,
        count, seed, n4_dims, index_param, _res, has_std, _pad2, _tail,
    ) = struct.unpack("<4sIIBBBBQQIIIBB10s", data[:HEADER_LEN])
    if magic != MAGIC:
        raise ValueError(f"not a .mvec file (magic={magic!r})")
    # Versions 1-5 predate this header layout entirely — parsing them against
    # the v6 offsets would silently misread every field, so reject anything
    # outside the two layouts we actually implement.
    if version not in (6, 7):
        raise ValueError(
            f"unsupported .mvec version {version} (this reader supports "
            f"versions 6 and 7)"
        )
    buf = io.BytesIO(data[HEADER_LEN:])
    std = None
    if has_std:
        mean = _read_array(buf, np.float32)
        inv = _read_array(buf, np.float32)
        std = GlobalStd(mean=float(mean[0]), inv_std=float(inv[0]))
    perm = None
    if version >= 7:
        perm = _read_array(buf, np.int32)
    packed = _read_array(buf, np.uint8)
    ids = _read_array(buf, np.uint64)
    qnorms = _read_array(buf, np.float32)
    (blob_len,) = struct.unpack("<Q", buf.read(8))
    blob = buf.read(blob_len) if blob_len else None

    from .rhdh import next_pow2

    dim_pad = next_pow2(dim)
    if bits == 4:
        bytes_per = dim_pad // 2
    elif bits == 2:
        bytes_per = dim_pad // 4
    else:  # mixed
        bytes_per = n4_dims // 2 + (dim_pad - n4_dims) // 4
    packed = packed.reshape(count, bytes_per)
    enc = qz.Encoded(
        packed=jnp.asarray(packed), qnorms=jnp.asarray(qnorms), seed=int(seed),
        metric=_METRIC_NAME[metric_c], bits=int(bits), dim=int(dim),
        dim_pad=dim_pad, n4_dims=int(n4_dims), std=std, perm=perm,
    )
    return MvecFile(
        enc=enc, ids=ids, index_type=int(index_type),
        index_param=int(index_param), index_data=blob,
    )


# ---------------------------------------------------------------------------
# Backend blobs (INDEX_DATA): length-prefixed numpy arrays.
# ---------------------------------------------------------------------------

def pack_ivf_blob(centroids: np.ndarray, order: np.ndarray, offsets: np.ndarray) -> bytes:
    buf = io.BytesIO()
    _write_array(buf, centroids.astype(np.float32))
    buf.write(struct.pack("<II", *centroids.shape))
    _write_array(buf, order.astype(np.int64))
    _write_array(buf, offsets.astype(np.int64))
    return buf.getvalue()


def unpack_ivf_blob(blob: bytes):
    buf = io.BytesIO(blob)
    cents = _read_array(buf, np.float32)
    nlist, d = struct.unpack("<II", buf.read(8))
    return cents.reshape(nlist, d), _read_array(buf, np.int64), _read_array(buf, np.int64)


def pack_hnsw_blob(idx) -> bytes:
    buf = io.BytesIO()
    buf.write(struct.pack("<IIIii", idx.neighbors0.shape[0], idx.neighbors0.shape[1],
                          idx.neighbors_hi.shape[0], idx.entry_point, idx.max_level))
    _write_array(buf, idx.neighbors0.astype(np.int32))
    _write_array(buf, idx.neighbors_hi.astype(np.int32))
    _write_array(buf, idx.node_level.astype(np.int8))
    return buf.getvalue()


def unpack_hnsw_blob(blob: bytes):
    buf = io.BytesIO(blob)
    n, m0, nhi, entry, max_level = struct.unpack("<IIIii", buf.read(20))
    nbr0 = _read_array(buf, np.int32).reshape(n, m0)
    nbr_hi = _read_array(buf, np.int32)
    nbr_hi = nbr_hi.reshape(nhi, n, m0 // 2) if nhi else np.zeros((0, n, m0 // 2), np.int32)
    node_level = _read_array(buf, np.int8)
    return nbr0, nbr_hi, node_level, entry, max_level

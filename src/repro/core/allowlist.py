"""Pre-filter allowlist (paper §3.5, contribution #6).

Applied BEFORE scoring/top-k, never after: post-filtering a selective
allowlist returns fewer than K results; pre-filtering guarantees exactly
min(K, |allowlist|) results at full recall regardless of selectivity.

Two variants, auto-selected like the paper's bitvec/HashSet split:
  * dense  — a boolean mask over row positions (O(1) lookup, cache friendly);
  * sparse — an explicit sorted id array, materialized into a mask on demand.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

# Mask value for disallowed rows: large-negative instead of -inf so that
# score arithmetic never produces NaNs (e.g. -inf + finite adjustments).
NEG = np.float32(-3.0e38)


@dataclasses.dataclass
class Allowlist:
    """Pre-filter over external ids."""

    mask: np.ndarray  # [n] bool over row positions
    n_allowed: int

    @staticmethod
    def from_ids(
        allowed_ids: Sequence[int],
        index_ids: np.ndarray,
        *,
        dense_threshold: float = 0.01,
    ) -> "Allowlist":
        """Build from external ids.  Mirrors the paper's auto-selection: for
        dense selections a bitmap materializes directly; for sparse ones we
        go through a sorted-array membership test (np.isin uses sort/search).
        """
        allowed = np.asarray(list(allowed_ids), dtype=np.int64)
        n = len(index_ids)
        if len(allowed) >= dense_threshold * n:
            # Dense path: bounded-universe bitmap.
            lo, hi = index_ids.min(), index_ids.max()
            bitmap = np.zeros(int(hi - lo + 1), dtype=bool)
            in_range = (allowed >= lo) & (allowed <= hi)
            bitmap[(allowed[in_range] - lo).astype(np.int64)] = True
            mask = bitmap[(index_ids - lo).astype(np.int64)]
        else:
            mask = np.isin(index_ids, allowed)
        return Allowlist(mask=mask, n_allowed=int(mask.sum()))

    def apply(self, scores: jnp.ndarray) -> jnp.ndarray:
        """Mask scores of disallowed rows to NEG (pre-top-k)."""
        return jnp.where(jnp.asarray(self.mask), scores, NEG)


def apply_optional(scores: jnp.ndarray, allow: Optional[Allowlist]) -> jnp.ndarray:
    return scores if allow is None else allow.apply(scores)

"""IvfFlat backend (paper §3.4.2): metric-aware k-means + inverted lists.

The single opt-in TRAINED component (paper Table 1): Lloyd's algorithm over the
corpus.  Metric awareness:
  * cosine — centroids L2-normalized after every mean update (direction is the
    representative, magnitude irrelevant);
  * dot/L2 — raw means.

Clustering runs in ROTATED f32 space: the rotation is orthogonal, so cluster
geometry is identical to input space, and query/centroid scoring then shares
the rotated query with the packed scan.  Deterministic: seeded farthest-point
init, fixed iteration count, stable argmin tie-breaks.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import quantize as qz
from .allowlist import NEG, Allowlist
from .scoring import topk
from .standardize import COSINE, L2, prepare


def _assign(x: jnp.ndarray, cents: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Nearest centroid per row.  argmin/argmax are stable (lowest index)."""
    if metric == L2:
        d2 = (
            jnp.sum(x * x, axis=1, keepdims=True)
            - 2.0 * x @ cents.T
            + jnp.sum(cents * cents, axis=1)[None, :]
        )
        return jnp.argmin(d2, axis=1)
    return jnp.argmax(x @ cents.T, axis=1)


@functools.partial(jax.jit, static_argnames=("n_clusters", "metric", "iters"))
def _kmeans(x: jnp.ndarray, init: jnp.ndarray, *, n_clusters: int, metric: str, iters: int):
    """Fixed-iteration Lloyd's; empty clusters keep their previous centroid."""

    def step(cents, _):
        a = _assign(x, cents, metric)
        one_hot = jax.nn.one_hot(a, n_clusters, dtype=x.dtype)      # [n, k]
        sums = one_hot.T @ x                                        # [k, d]
        counts = jnp.sum(one_hot, axis=0)[:, None]                  # [k, 1]
        means = sums / jnp.maximum(counts, 1.0)
        new = jnp.where(counts > 0, means, cents)
        if metric == COSINE:
            new = new / jnp.maximum(jnp.linalg.norm(new, axis=1, keepdims=True), 1e-12)
        return new, None

    cents, _ = jax.lax.scan(step, init, None, length=iters)
    return cents, _assign(x, cents, metric)


def _seeded_init(x: np.ndarray, k: int, seed: int, metric: str) -> np.ndarray:
    """Deterministic farthest-point (k-means++-style, greedy) initialization."""
    rng = np.random.RandomState(seed & 0x7FFFFFFF)
    n = x.shape[0]
    first = int(rng.randint(n))
    chosen = [first]
    if metric == L2:
        d = np.sum((x - x[first]) ** 2, axis=1)
    else:
        d = 1.0 - x @ x[first] / (np.linalg.norm(x, axis=1) * np.linalg.norm(x[first]) + 1e-12)
    for _ in range(k - 1):
        nxt = int(np.argmax(d))  # deterministic: greedy farthest, stable argmax
        chosen.append(nxt)
        if metric == L2:
            d = np.minimum(d, np.sum((x - x[nxt]) ** 2, axis=1))
        else:
            d = np.minimum(
                d, 1.0 - x @ x[nxt] / (np.linalg.norm(x, axis=1) * np.linalg.norm(x[nxt]) + 1e-12)
            )
    return x[np.asarray(chosen)]


@dataclasses.dataclass
class IvfFlatIndex:
    enc: qz.Encoded
    ids: np.ndarray                 # [n] external ids
    centroids: jnp.ndarray          # [nlist, d'] rotated f32
    order: np.ndarray               # [n] row permutation grouping clusters
    offsets: np.ndarray             # [nlist+1] CSR offsets into ``order``
    nlist: int

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        *,
        ids: Optional[np.ndarray] = None,
        metric: str = COSINE,
        seed: int = 0x6D6F6E61,
        bits: int = 4,
        std=None,
        nlist: int = 64,
        train_iters: int = 25,
    ) -> "IvfFlatIndex":
        n = vectors.shape[0]
        enc = qz.encode(vectors, metric=metric, seed=seed, bits=bits, std=std)
        # Cluster in rotated f32 space (normalized rotation: unit geometry).
        prepared = prepare(jnp.asarray(vectors, jnp.float32), metric, std)
        from .rhdh import rhdh_apply

        rot = rhdh_apply(prepared, seed, normalized=False)
        init = jnp.asarray(_seeded_init(np.asarray(rot), nlist, seed, metric))
        cents, assign = _kmeans(rot, init, n_clusters=nlist, metric=metric, iters=train_iters)
        assign = np.asarray(assign)
        order = np.argsort(assign, kind="stable").astype(np.int64)
        counts = np.bincount(assign, minlength=nlist)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        if ids is None:
            ids = np.arange(n, dtype=np.uint64)
        return IvfFlatIndex(
            enc=enc, ids=np.asarray(ids, dtype=np.uint64), centroids=cents,
            order=order, offsets=offsets, nlist=nlist,
        )

    def search(
        self,
        queries: jnp.ndarray,
        k: int,
        *,
        nprobe: int = 8,
        allow: Optional[Allowlist] = None,
        use_kernel: bool = True,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Probe the nprobe nearest cells, scan their lists with the packed
        kernel.  Candidate sets are padded to a fixed size so the scoring is
        a single fixed-shape jit call per batch."""
        queries = jnp.atleast_2d(queries)
        q_rot = qz.encode_query(queries, self.enc)
        metric = self.enc.metric
        if metric == L2:
            cs = (
                q_rot @ self.centroids.T
                - 0.5 * jnp.sum(self.centroids * self.centroids, axis=1)[None, :]
            )
        else:
            cs = q_rot @ self.centroids.T
        _, probe = topk(cs, min(nprobe, self.nlist))          # [b, nprobe]
        probe = np.asarray(probe)

        counts = self.offsets[1:] - self.offsets[:-1]
        max_cand = int(np.sort(counts)[::-1][: min(nprobe, self.nlist)].sum())
        max_cand = max(max_cand, k)
        b = queries.shape[0]
        cand = np.full((b, max_cand), -1, dtype=np.int64)
        for i in range(b):
            rows = np.concatenate(
                [self.order[self.offsets[c]: self.offsets[c + 1]] for c in probe[i]]
            )
            cand[i, : len(rows)] = rows
        cand_j = jnp.asarray(np.maximum(cand, 0))
        valid = jnp.asarray(cand >= 0)

        # Gather candidate rows and score them (per-query candidate matrices).
        packed_c = jnp.take(self.enc.packed, cand_j, axis=0)   # [b, mc, bytes]
        qn_c = jnp.take(self.enc.qnorms, cand_j, axis=0)       # [b, mc]
        deq = qz.decode(
            dataclasses.replace(self.enc, packed=packed_c.reshape(-1, packed_c.shape[-1]))
        ).reshape(b, max_cand, -1)
        raw = jnp.einsum("bd,bmd->bm", q_rot, deq)
        from .scoring import adjust_scores

        scores = adjust_scores(raw, qn_c, metric)
        if allow is not None:
            scores = jnp.where(jnp.asarray(allow.mask)[cand_j], scores, NEG)
        scores = jnp.where(valid, scores, NEG)
        vals, pos = topk(scores, min(k, max_cand))
        rows = np.take_along_axis(cand, np.asarray(pos), axis=1)
        return np.asarray(vals), self.ids[np.maximum(rows, 0)]

"""IvfFlat backend (paper §3.4.2): metric-aware k-means + inverted lists.

The single opt-in TRAINED component (paper Table 1): Lloyd's algorithm over the
corpus.  Metric awareness:
  * cosine — centroids L2-normalized after every mean update (direction is the
    representative, magnitude irrelevant);
  * dot/L2 — raw means.

Clustering runs in ROTATED f32 space: the rotation is orthogonal, so cluster
geometry is identical to input space, and query/centroid scoring then shares
the rotated query with the packed scan.  Deterministic: seeded farthest-point
init, fixed iteration count, stable argmin tie-breaks.

The probe scan (DESIGN.md §5) runs over PACKED bytes end to end: the CSR
(order, offsets) arrays are staged on device once at build/load, per-query
candidates assemble as a vectorized ragged-concat into a tight fixed-shape
[b, max_cand] matrix (-1 tail), and scoring goes through
``ops.score_gathered`` — compare-select dequant fused into the dot, never a
``[b, max_cand, d']`` f32 materialization.  The allowlist masks scores
before the top-k (§3.5 pre-filter).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import quantize as qz
from .allowlist import NEG, Allowlist
from .scoring import topk
from .standardize import COSINE, L2, prepare


#: repro.analysis coverage hook (DESIGN.md §10): pure plan stages exported
#: here; the determinism auditor's grid must capture each one.
PLAN_STAGES = ("search_stage",)


def _assign(x: jnp.ndarray, cents: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Nearest centroid per row.  argmin/argmax are stable (lowest index)."""
    if metric == L2:
        d2 = (
            jnp.sum(x * x, axis=1, keepdims=True)
            - 2.0 * x @ cents.T
            + jnp.sum(cents * cents, axis=1)[None, :]
        )
        return jnp.argmin(d2, axis=1)
    return jnp.argmax(x @ cents.T, axis=1)


@functools.partial(jax.jit, static_argnames=("n_clusters", "metric", "iters"))
def _kmeans(x: jnp.ndarray, init: jnp.ndarray, *, n_clusters: int, metric: str, iters: int):
    """Fixed-iteration Lloyd's; empty clusters keep their previous centroid."""

    def step(cents, _):
        a = _assign(x, cents, metric)
        one_hot = jax.nn.one_hot(a, n_clusters, dtype=x.dtype)      # [n, k]
        sums = one_hot.T @ x                                        # [k, d]
        counts = jnp.sum(one_hot, axis=0)[:, None]                  # [k, 1]
        means = sums / jnp.maximum(counts, 1.0)
        new = jnp.where(counts > 0, means, cents)
        if metric == COSINE:
            new = new / jnp.maximum(jnp.linalg.norm(new, axis=1, keepdims=True), 1e-12)
        return new, None

    cents, _ = jax.lax.scan(step, init, None, length=iters)
    return cents, _assign(x, cents, metric)


def _seeded_init(x: np.ndarray, k: int, seed: int, metric: str) -> np.ndarray:
    """Deterministic farthest-point (k-means++-style, greedy) initialization."""
    rng = np.random.RandomState(seed & 0x7FFFFFFF)
    n = x.shape[0]
    first = int(rng.randint(n))
    chosen = [first]
    if metric == L2:
        d = np.sum((x - x[first]) ** 2, axis=1)
    else:
        d = 1.0 - x @ x[first] / (np.linalg.norm(x, axis=1) * np.linalg.norm(x[first]) + 1e-12)
    for _ in range(k - 1):
        nxt = int(np.argmax(d))  # deterministic: greedy farthest, stable argmax
        chosen.append(nxt)
        if metric == L2:
            d = np.minimum(d, np.sum((x - x[nxt]) ** 2, axis=1))
        else:
            d = np.minimum(
                d, 1.0 - x @ x[nxt] / (np.linalg.norm(x, axis=1) * np.linalg.norm(x[nxt]) + 1e-12)
            )
    return x[np.asarray(chosen)]


def search_stage(
    q_rot, centroids, order, offsets, packed, qnorms, allow_mask, *,
    k, nprobe, max_cand, metric, bits, n4_dims, use_kernel, interpret,
):
    """Fixed-shape probe + gathered scan + pre-filtered top-k — the jitted
    body exposed as a pure PLAN STAGE (the engine composes it with query
    rotation and the segment merge into one compiled SearchPlan, DESIGN.md
    §7; every array rides in as an argument, never a trace constant).

    Candidate assembly is a vectorized ragged-concat straight off the CSR
    (order, offsets) arrays: output slot j of query b belongs to the probed
    cell whose cumulative length first exceeds j (a searchsorted), at offset
    ``j - cum[cell-1]`` within it.  This fills ``max_cand`` = the sum of the
    nprobe largest cell sizes (the tight per-query bound, valid candidates
    contiguous in probe order, -1 tail) with no per-query host loop and no
    O(nlist * max_cell) padded table — a skewed clustering costs padding
    proportional to the skew of the probed cells only.
    """
    if metric == L2:
        cs = (
            q_rot @ centroids.T
            - 0.5 * jnp.sum(centroids * centroids, axis=1)[None, :]
        )
    else:
        cs = q_rot @ centroids.T
    _, probe = topk(cs, nprobe)                           # [b, nprobe]
    lens = (offsets[1:] - offsets[:-1])[probe]            # [b, nprobe]
    cum = jnp.cumsum(lens, axis=1)                        # [b, nprobe]
    width = max(max_cand, k)   # tiny corpus: keep the [b, k] output contract
    slot = jnp.arange(width, dtype=offsets.dtype)         # [width]
    cell = jax.vmap(
        lambda c: jnp.searchsorted(c, slot, side="right")
    )(cum)                                                # [b, width]
    cell_c = jnp.minimum(cell, nprobe - 1)
    prev = jnp.where(cell_c > 0,
                     jnp.take_along_axis(cum, jnp.maximum(cell_c - 1, 0), axis=1),
                     0)
    src = jnp.take_along_axis(offsets[probe], cell_c, axis=1) + (slot[None] - prev)
    valid = slot[None] < cum[:, -1:]
    cand = jnp.where(valid, order[jnp.minimum(src, order.shape[0] - 1)], -1)
    scores = ops.score_gathered(
        packed, q_rot, cand, bits=bits, n4_dims=n4_dims, qnorms=qnorms,
        metric=metric, allow_mask=allow_mask, use_kernel=use_kernel,
        interpret=interpret,
    )
    vals, pos = topk(scores, min(k, cand.shape[1]))
    rows = jnp.take_along_axis(cand, pos, axis=1)
    # Same no-result contract as HNSW: any NEG slot (padding, or fewer than k
    # allowed candidates) is marked -1, never a real row.
    return vals, jnp.where(vals > NEG, rows, -1)


@dataclasses.dataclass
class IvfFlatIndex:
    enc: qz.Encoded
    ids: np.ndarray                 # [n] external ids
    centroids: jnp.ndarray          # [nlist, d'] rotated f32
    order: np.ndarray               # [n] row permutation grouping clusters
    offsets: np.ndarray             # [nlist+1] CSR offsets into ``order``
    nlist: int
    # CSR staged on device (int32) once per index — build AND load — so the
    # jit'd candidate assembly never re-uploads or loops per search call.
    order_j: jnp.ndarray = dataclasses.field(init=False, repr=False)
    offsets_j: jnp.ndarray = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        self.order_j = jnp.asarray(self.order, jnp.int32)
        self.offsets_j = jnp.asarray(self.offsets, jnp.int32)

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        *,
        ids: Optional[np.ndarray] = None,
        metric: str = COSINE,
        seed: int = 0x6D6F6E61,
        bits: int = 4,
        std=None,
        nlist: int = 64,
        train_iters: int = 25,
    ) -> "IvfFlatIndex":
        n = vectors.shape[0]
        enc = qz.encode(vectors, metric=metric, seed=seed, bits=bits, std=std)
        # Cluster in rotated f32 space (normalized rotation: unit geometry).
        prepared = prepare(jnp.asarray(vectors, jnp.float32), metric, std)
        from .rhdh import rhdh_apply

        rot = rhdh_apply(prepared, seed, normalized=False)
        init = jnp.asarray(_seeded_init(np.asarray(rot), nlist, seed, metric))
        cents, assign = _kmeans(rot, init, n_clusters=nlist, metric=metric, iters=train_iters)
        assign = np.asarray(assign)
        order = np.argsort(assign, kind="stable").astype(np.int64)
        counts = np.bincount(assign, minlength=nlist)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        if ids is None:
            ids = np.arange(n, dtype=np.uint64)
        return IvfFlatIndex(
            enc=enc, ids=np.asarray(ids, dtype=np.uint64), centroids=cents,
            order=order, offsets=offsets, nlist=nlist,
        )

    def max_candidates(self, nprobe: int) -> int:
        """Sum of the ``nprobe`` largest cell sizes — the tight fixed shape
        of the per-query candidate matrix (part of the engine's plan key)."""
        counts = np.asarray(self.offsets[1:] - self.offsets[:-1])
        return int(np.sort(counts)[::-1][:nprobe].sum())

    def search(
        self,
        queries: jnp.ndarray,
        k: int,
        *,
        nprobe: int = 8,
        allow: Optional[Allowlist] = None,
        where_mask=None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Probe the nprobe nearest cells and scan their lists with the packed
        gathered-candidate scan (``ops.score_gathered``): candidates stay
        4/2-bit until the fused dequant-dot, the allowlist masks scores before
        the top-k, and the whole rotate->probe->scan->top-k is one cached
        SearchPlan per (shape bucket, nprobe, k) — repro.engine, DESIGN.md §7.
        ``use_kernel``/``interpret`` dispatch exactly like ``score_packed``
        (None = kernel on TPU, jnp elsewhere).  Always exactly ``k`` columns:
        slots with no admissible candidate come back with id
        0xFFFFFFFFFFFFFFFF and a NEG score (the HNSW sentinel contract).
        """
        from .. import engine
        return engine.search_backend(
            self, None, queries, k, allow=allow, where_mask=where_mask,
            use_kernel=use_kernel, interpret=interpret, nprobe=nprobe,
        )

"""Training-free binarized coarse codes (cascade stage 1; DESIGN.md §11).

The coarse code is a PURE FUNCTION of the packed Lloyd-Max nibbles — no data
pass, no training, no new randomness — because the quantizer boundary tables
straddle zero exactly:

  * ``BOUNDARIES_4BIT[7] == 0.0`` and ``quantize`` counts boundaries <= x, so
    a 4-bit code >= 8 iff the rotated coordinate is >= 0; likewise a 2-bit
    code >= 2.  The **sign** code packs that predicate 8 dims/byte
    (little-endian, ``np.packbits(bitorder="little")`` layout): 32x smaller
    than f32, 4x smaller than the 4-bit nibbles.
  * The **crumb** code keeps the top two bits of the code (``code4 >> 2``;
    a 2-bit block's codes verbatim), stored as TWO SIGN PLANES — the hi
    bit plane then the lo bit plane, each packed 8 dims/byte like the sign
    code — d'/4 bytes total: 16x smaller than f32.  The plane layout is
    what makes the crumb proxy an AND+popcount (kernels/binary_dot.py)
    instead of a per-dim unpack.

Query side, the sign bit is ``q_rot >= 0`` (EXACTLY the corpus predicate —
shared zero boundary) and the crumb planes come from the 2-bit Lloyd-Max
code of the rotated query; both are derived INSIDE the coarse stage from
the same rotated query the rescore uses, so the cascade adds no second
rotation.

Scores are integer proxies (see kernels/binary_dot.py): ``-hamming`` for
sign, the symmetric-level affinity for crumb.  Integer proxies make the
kernel/jnp mirror bit-identical by construction and keep the survivor set
deterministic: ``survivor_topk_stage`` canonicalizes ties by ROW ORDER
(equivalent to a stable top-k followed by an ascending index sort), which
is the admissibility contract the cascade property tests pin against the
brute-force oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from . import lloydmax
from . import quantize as qz

SIGN = "sign"
CRUMB = "crumb"
COARSE_KINDS = (SIGN, CRUMB)

#: Default rescore budget multiplier: the cascade rescores m = mult * k
#: candidates with the full 4-bit kernel (m >= n collapses to the full scan).
DEFAULT_RESCORE_MULT = 32

#: Default static bound on |proxy| when the caller passes no ``vbound``:
#: proxies live in [-9 d', 9 d'], so 2^29 covers any conceivable d' while
#: keeping the bisection endpoints safely inside int32 (lo + hi never
#: overflows).  Callers that know d' pass vbound = 9 * dim_pad and the
#: bisection converges in ~15 passes instead of 31.
VBOUND_MAX = 1 << 29

#: Integer analogue of allowlist.NEG for the int32 proxy domain: the value
#: dead rows carry INSIDE survivor selection.  Real proxies live in
#: [-9 d', 9 d'] — nowhere near the sentinel.
INT_NEG = int(np.iinfo(np.int32).min)

#: Compiled stage coverage contract for the repro.analysis auditor.
PLAN_STAGES = ("coarse_scan_stage", "survivor_topk_stage",
               "gathered_rescore_stage")

_BIT_WEIGHTS = tuple(1 << t for t in range(8))      # little-endian bit weights


def code_bytes(dim_pad: int, kind: str) -> int:
    """Packed coarse-code bytes per vector for a rotated dim d'."""
    if kind == SIGN:
        if dim_pad % 8 != 0:
            raise ValueError(f"sign code requires dim_pad % 8 == 0, got {dim_pad}")
        return dim_pad // 8
    if kind == CRUMB:
        if dim_pad % 8 != 0:
            raise ValueError(f"crumb code requires dim_pad % 8 == 0, got {dim_pad}")
        return dim_pad // 4
    raise ValueError(f"unknown coarse kind {kind!r}; expected one of {COARSE_KINDS}")


def _unpacked_codes(packed: np.ndarray, bits: int, n4_dims: int) -> np.ndarray:
    """Packed corpus bytes -> per-dim crumb codes in [0,4) (numpy, host side).

    4-bit codes coarsen via ``>> 2``; 2-bit codes pass through; mixed mode
    concatenates per block ([4-bit dims | 2-bit dims], the packed layout).
    """
    if bits == 4:
        return np.asarray(qz.unpack_4bit(packed)) >> 2
    if bits == 2:
        return np.asarray(qz.unpack_2bit(packed))
    if bits == 3:
        b4 = n4_dims // 2
        c4 = np.asarray(qz.unpack_4bit(packed[:, :b4])) >> 2
        c2 = np.asarray(qz.unpack_2bit(packed[:, b4:]))
        return np.concatenate([c4, c2], axis=-1)
    raise ValueError(f"unsupported bits={bits}")


def derive_codes(
    packed: jnp.ndarray,     # [n, bytes] packed Lloyd-Max nibbles/crumbs
    *,
    bits: int,
    n4_dims: int,
    dim_pad: int,
    kind: str,
) -> np.ndarray:
    """Derive the packed coarse code [n, code_bytes(dim_pad, kind)] uint8.

    Pure function of the packed codes (the sign bit is code4 >= 8 / code2
    >= 2 — the shared zero boundary; the crumb is the top two code bits,
    stored as the hi bit plane then the lo bit plane, each packbits
    little-endian like the sign code), so add/compact segments re-derive
    byte-identical codes.
    """
    nbytes = code_bytes(dim_pad, kind)               # validates kind + dim_pad
    crumbs = _unpacked_codes(np.asarray(packed), bits, n4_dims)   # [n, d'] in [0,4)
    if kind == SIGN:
        signs = (crumbs >= 2).astype(np.uint8)       # crumb >= 2 iff code >= mid
        out = np.packbits(signs, axis=-1, bitorder="little")
    else:
        hi = np.packbits((crumbs >> 1).astype(np.uint8), axis=-1,
                         bitorder="little")
        lo = np.packbits((crumbs & 1).astype(np.uint8), axis=-1,
                         bitorder="little")
        out = np.concatenate([hi, lo], axis=-1)
    assert out.shape == (crumbs.shape[0], nbytes)
    return out


def attach_coarse(enc: "qz.Encoded", kind: str) -> "qz.Encoded":
    """Return a copy of ``enc`` carrying the derived coarse code.

    Idempotent for a fixed kind; pure derivation means attaching after load
    reproduces the persisted CODE block byte-for-byte.
    """
    ccodes = derive_codes(enc.packed, bits=enc.bits, n4_dims=enc.n4_dims,
                          dim_pad=enc.dim_pad, kind=kind)
    return dataclasses.replace(enc, coarse=kind, ccodes=jnp.asarray(ccodes))


# ---------------------------------------------------------------------------
# Query-side coarse encodings (traced; called inside the coarse stage).
# ---------------------------------------------------------------------------

def query_sign_bits(q_rot: jnp.ndarray) -> jnp.ndarray:
    """[b, d'] rotated f32 -> [b, d'/8] packed sign bytes (little-endian).

    ``q_rot >= 0`` is EXACTLY the corpus sign predicate: quantize counts
    boundaries <= x and the mid boundary is 0.0 in both tables.
    """
    b, d = q_rot.shape
    bits = (q_rot >= 0).astype(jnp.uint8).reshape(b, d // 8, 8)
    weights = jnp.asarray(_BIT_WEIGHTS, jnp.uint8)
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def query_crumb_planes(q_rot: jnp.ndarray) -> jnp.ndarray:
    """[b, d'] rotated f32 -> [b, d'/4] packed crumb planes (hi || lo bytes).

    The 2-bit Lloyd-Max code of the rotated query, split into its hi and
    lo bit planes and packed little-endian — the EXACT corpus layout of
    ``derive_codes(kind="crumb")``, so kernel and corpus bytes line up.
    """
    b, d = q_rot.shape
    c2 = lloydmax.quantize(q_rot, 2).astype(jnp.uint8)
    weights = jnp.asarray(_BIT_WEIGHTS, jnp.uint8)

    def pack(bits):
        return jnp.sum(bits.reshape(b, d // 8, 8) * weights,
                       axis=-1).astype(jnp.uint8)

    return jnp.concatenate([pack(c2 >> 1), pack(c2 & 1)], axis=-1)


# ---------------------------------------------------------------------------
# Cascade plan stages (compiled per-plan by engine/plan.py; the names below
# are the PLAN_STAGES coverage contract).
# ---------------------------------------------------------------------------

def coarse_scan_stage(
    q_rot: jnp.ndarray,      # [b, d'] rotated f32 queries (post-perm)
    ccodes: jnp.ndarray,     # [n, code_bytes] packed coarse codes
    *,
    kind: str,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Integer proxy scores [b, n] (int32), HIGHER = closer for both kinds."""
    if kind == SIGN:
        ham = ops.sign_coarse_raw(ccodes, query_sign_bits(q_rot),
                                  use_kernel=use_kernel, interpret=interpret)
        return -ham
    if kind == CRUMB:
        return ops.crumb_coarse_raw(ccodes, query_crumb_planes(q_rot),
                                    use_kernel=use_kernel, interpret=interpret)
    raise ValueError(f"unknown coarse kind {kind!r}")


def survivor_topk_stage(
    proxy: jnp.ndarray,      # [b, n] int32 proxies, |proxy| <= vbound
    live: jnp.ndarray,       # [n] bool — tombstone & allowlist & predicate mask
    *,
    m: int,
    vbound: Optional[int] = None,
) -> jnp.ndarray:
    """Top-m survivor row indices [b, m] (int32), ROW-ORDER canonical.

    Exact integer top-m WITHOUT ``lax.top_k``: XLA's CPU TopK re-walks the
    whole row per selection (~0.2 s at 45k x m=320 — it would erase the
    coarse pass's entire win).  Integer proxies admit a cheaper exact plan:

      1. bisect t*, the m-th-largest proxy per row, on the integer value
         range (each probe is one compare+reduce pass over [b, n]);
      2. admit every proxy > t*, plus the FIRST ``m - count(> t*)`` rows
         with proxy == t* in row order (a cumsum over the tie mask) — the
         stable-top-k tie rule, ties broken by lowest row;
      3. compact the admitted mask to indices by searchsorted over its
         cumsum — binary-search gathers only, no scatter, no sort.

    Masks are fused BEFORE selection (§3.5: filtered queries must not lose
    candidates to dead rows), dead slots come back as -1 AFTER the real
    survivors, and survivors are emitted ascending — the same canonical
    form as a stable top-k followed by an index sort, which is what the
    cascade property tests pin against the brute-force oracle.
    """
    b, n = proxy.shape
    bound = VBOUND_MAX if vbound is None else int(vbound)
    dead = -bound - 1
    masked = jnp.where(live[None, :], proxy, dead)

    # Invariant: count(>= lo) >= m > count(>= hi); after ceil(log2(hi0-lo0))
    # halvings hi - lo == 1 and lo is t*.  Dead rows sit below every live
    # proxy, so they can surface as t* only when fewer than m rows are live
    # (step 2's `& live` then pads the tail with -1 instead).
    lo0 = jnp.full((b,), dead, jnp.int32)
    hi0 = jnp.full((b,), bound + 1, jnp.int32)
    iters = int(np.ceil(np.log2(2 * bound + 2)))

    def probe(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2
        ok = jnp.sum((masked >= mid[:, None]).astype(jnp.int32), axis=-1) >= m
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    tstar, _ = jax.lax.fori_loop(0, iters, probe, (lo0, hi0))

    above = masked > tstar[:, None]
    ties = masked == tstar[:, None]
    j = m - jnp.sum(above.astype(jnp.int32), axis=-1)     # tie budget (> 0)
    tie_rank = jnp.cumsum(ties.astype(jnp.int32), axis=-1)
    sel = (above | (ties & (tie_rank <= j[:, None]))) & live[None, :]

    rank = jnp.cumsum(sel.astype(jnp.int32), axis=-1)     # 1-based, per row
    targets = jnp.arange(1, m + 1, dtype=jnp.int32)
    pos = jax.vmap(lambda r: jnp.searchsorted(r, targets, side="left"))(rank)
    return jnp.where(pos < n, pos, -1).astype(jnp.int32)


def gathered_rescore_stage(
    q_rot: jnp.ndarray,      # [b, d'] rotated f32 queries
    packed: jnp.ndarray,     # [n, bytes] packed 4/2-bit corpus
    qnorms: jnp.ndarray,     # [n] f32
    cand: jnp.ndarray,       # [b, m] survivor rows, -1 = dead
    *,
    bits: int,
    n4_dims: int,
    metric: str,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Metric-adjusted 4-bit rescores [b, m]; dead survivors come back NEG.

    Delegates to ops.score_gathered — the SAME gathered kernel the IVF probe
    scan and HNSW beam use, so cascade rescores inherit their bit-identity
    and masking contract unchanged.
    """
    return ops.score_gathered(packed, q_rot, cand, bits=bits, n4_dims=n4_dims,
                              qnorms=qnorms, metric=metric,
                              use_kernel=use_kernel, interpret=interpret)

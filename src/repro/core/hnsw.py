"""HNSW backend (paper §3.4.3): fp32-build / 4-bit-search, deterministic.

Paper-faithful properties reproduced here:
  * **Sequential, single-threaded build** (§2.1): insertion order is the data
    order; level assignment is a seeded per-insertion stream -> the same
    vectors always produce the SAME graph (parallel-build libraries cannot
    offer this).
  * **FP32 build** (contribution #5): graph edges are selected with exact f32
    dot products over the rotated vectors; quantization noise (~0.01-0.02)
    exceeds the neighbor score gap (~0.001-0.003) and would corrupt topology.
  * **Metric-aware build scoring** (contribution #3): L2 uses
    ``<q,v> - ||v||^2 / 2`` (monotone in -||q-v||^2); plain dot product gives
    the wrong topology (0.31 -> 0.62 Recall@10 in the paper).
  * **Auto-M** (contribution #4): M=32 below 1e6 vectors, 64 at or above.
  * **4-bit search**: query-time scoring reads the packed Lloyd-Max codes via
    the gathered candidate scan (``ops.score_gathered``, DESIGN.md §5) — the
    same primitive as the IVF probe scan, so every backend interprets packed
    bytes identically; only ranking noise, no structural damage.

The query-time beam search is a fixed-shape ``lax.while_loop`` (jit/TPU
friendly): a single (score, id, expanded) frontier of width ef, a visited
bitmap, and stable top-k merges — deterministic by construction.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as qz
from .allowlist import NEG, Allowlist
from .rhdh import rhdh_apply
from .standardize import COSINE, L2, prepare


#: repro.analysis coverage hook (DESIGN.md §10): pure plan stages exported
#: here; the determinism auditor's grid must capture each one.
PLAN_STAGES = ("search_stage",)


def recommended_m(n: int) -> int:
    """Auto-M policy (paper contribution #4): graph diameter grows with N."""
    return 32 if n < 1_000_000 else 64


def _build_scores(q: np.ndarray, vecs: np.ndarray, metric: str) -> np.ndarray:
    """FP32 build-time scores of q against rows of vecs (higher = closer)."""
    raw = vecs @ q
    if metric == L2:
        return raw - 0.5 * np.sum(vecs * vecs, axis=1)
    return raw


@dataclasses.dataclass
class HnswIndex:
    enc: qz.Encoded
    ids: np.ndarray
    neighbors0: np.ndarray          # [n, 2M] int32, -1 padded (level 0)
    neighbors_hi: np.ndarray        # [max_level, n, M] int32 (levels 1..max)
    node_level: np.ndarray          # [n] int8
    entry_point: int
    max_level: int
    m: int
    # Build-time beam width, persisted in INDEX_PARAMS.param2 so that
    # compact() rebuilds the graph with the same construction parameters the
    # original build used.  None = unknown (file predating the field):
    # compact falls back to the default.
    ef_construction: Optional[int] = None

    # ------------------------------------------------------------------
    # Build.
    # ------------------------------------------------------------------

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        *,
        ids: Optional[np.ndarray] = None,
        metric: str = COSINE,
        seed: int = 0x6D6F6E61,
        bits: int = 4,
        std=None,
        m: Optional[int] = None,
        ef_construction: int = 100,
    ) -> "HnswIndex":
        n = int(vectors.shape[0])
        if m is None:
            m = recommended_m(n)
        enc = qz.encode(vectors, metric=metric, seed=seed, bits=bits, std=std)

        # FP32 build buffer: rotated, quantizer-space vectors (paper keeps the
        # fp32 vectors during construction and drops them afterwards).
        prepared = prepare(jnp.asarray(vectors, jnp.float32), metric, std)
        rot = np.asarray(rhdh_apply(prepared, seed, normalized=False))

        m0 = 2 * m
        ml = 1.0 / math.log(m)
        level_rng = np.random.RandomState(seed & 0x7FFFFFFF)
        levels = np.minimum(
            (-np.log(np.maximum(level_rng.uniform(size=n), 1e-12)) * ml).astype(np.int32),
            31,
        )
        max_level = int(levels.max()) if n else 0

        nbr0 = np.full((n, m0), -1, dtype=np.int32)
        nbr_hi = np.full((max_level, n, m), -1, dtype=np.int32) if max_level else np.zeros(
            (0, n, m), dtype=np.int32
        )

        def neighbors(node: int, level: int) -> np.ndarray:
            arr = nbr0[node] if level == 0 else nbr_hi[level - 1, node]
            return arr[arr >= 0]

        def set_neighbors(node: int, level: int, nbrs: np.ndarray) -> None:
            cap = m0 if level == 0 else m
            arr = np.full(cap, -1, dtype=np.int32)
            arr[: len(nbrs)] = nbrs[:cap]
            if level == 0:
                nbr0[node] = arr
            else:
                nbr_hi[level - 1, node] = arr

        def search_layer(q: np.ndarray, entry: int, ef: int, level: int) -> List[Tuple[float, int]]:
            """Classic ef-beam over one layer; deterministic heap keys (score, id)."""
            s0 = float(_build_scores(q, rot[entry: entry + 1], metric)[0])
            visited = {entry}
            cand = [(-s0, entry)]                 # max-heap by score
            res = [(s0, entry)]                   # min-heap of size ef
            heapq.heapify(cand)
            heapq.heapify(res)
            while cand:
                cs, c = heapq.heappop(cand)
                if -cs < res[0][0] and len(res) >= ef:
                    break
                nbrs = [v for v in neighbors(c, level) if v not in visited]
                if not nbrs:
                    continue
                visited.update(nbrs)
                nb = np.asarray(nbrs, dtype=np.int64)
                ss = _build_scores(q, rot[nb], metric)
                for s, v in zip(ss, nb):
                    if len(res) < ef or s > res[0][0]:
                        heapq.heappush(res, (float(s), int(v)))
                        heapq.heappush(cand, (-float(s), int(v)))
                        if len(res) > ef:
                            heapq.heappop(res)
            return sorted(res, key=lambda t: (-t[0], t[1]))

        entry_point = 0
        cur_max = int(levels[0]) if n else 0
        for i in range(1, n):
            q = rot[i]
            li = int(levels[i])
            ep = entry_point
            # Greedy descent through layers above li.
            for l in range(cur_max, li, -1):
                improved = True
                cur_s = float(_build_scores(q, rot[ep: ep + 1], metric)[0])
                while improved:
                    improved = False
                    nb = neighbors(ep, l)
                    if len(nb) == 0:
                        continue
                    ss = _build_scores(q, rot[nb.astype(np.int64)], metric)
                    j = int(np.argmax(ss))
                    if ss[j] > cur_s:
                        cur_s, ep, improved = float(ss[j]), int(nb[j]), True
            # Insert at layers min(li, cur_max) .. 0.
            for l in range(min(li, cur_max), -1, -1):
                res = search_layer(q, ep, ef_construction, l)
                cap = m0 if l == 0 else m
                sel = np.asarray([v for _, v in res[:m]], dtype=np.int32)
                set_neighbors(i, l, sel)
                # Bidirectional connect with deterministic prune-by-score.
                for v in sel:
                    ex = neighbors(int(v), l)
                    if i not in ex:
                        ex = np.append(ex, i).astype(np.int32)
                    if len(ex) > cap:
                        ss = _build_scores(rot[int(v)], rot[ex.astype(np.int64)], metric)
                        keep = np.lexsort((ex, -ss))[:cap]   # score desc, id asc
                        ex = ex[keep]
                    set_neighbors(int(v), l, ex)
                ep = int(res[0][1])
            if li > cur_max:
                cur_max = li
                entry_point = i

        if ids is None:
            ids = np.arange(n, dtype=np.uint64)
        return HnswIndex(
            enc=enc, ids=np.asarray(ids, dtype=np.uint64),
            neighbors0=nbr0, neighbors_hi=nbr_hi, node_level=levels.astype(np.int8),
            entry_point=entry_point, max_level=cur_max, m=m,
            ef_construction=ef_construction,
        )

    # ------------------------------------------------------------------
    # Search (jitted fixed-shape beam, 4-bit scoring).
    # ------------------------------------------------------------------

    def search(
        self,
        queries: jnp.ndarray,
        k: int,
        *,
        ef: int = 64,
        allow: Optional[Allowlist] = None,
        where_mask=None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Beam-search the graph, scoring packed codes via the gathered scan.

        ``ef`` is the level-0 beam width; because only beam members can enter
        the result set, the beam auto-widens to ``max(ef, k)`` so asking for
        ``k`` results with a narrow default beam never silently truncates to
        ``ef`` rows (a caller-set ``ef`` above ``k`` is kept as given).
        ``use_kernel``/``interpret`` dispatch exactly like ``score_packed``.
        The whole rotate->descend->beam->top-k runs as one cached SearchPlan
        per (shape bucket, ef, k) — repro.engine, DESIGN.md §7.
        """
        from .. import engine
        return engine.search_backend(
            self, None, queries, k, allow=allow, where_mask=where_mask,
            use_kernel=use_kernel, interpret=interpret, ef=ef,
        )


# ---------------------------------------------------------------------------
# The beam-search plan stage.
# ---------------------------------------------------------------------------

def search_stage(
    q_rot, packed, qnorms, nbr0, nbr_hi, allow_mask, *, entry, ef, k, metric,
    bits, n4_dims, max_level, use_kernel, interpret,
):
    """Lock-step batched beam search over the whole query batch — the jitted
    body exposed as a pure PLAN STAGE for the engine (DESIGN.md §7).

    Every scoring step is ONE batched ``ops.score_gathered`` call over the
    ``[b, rows]`` candidate matrix (the same gathered-scan primitive and tile
    decomposition as the IVF probe scan — DESIGN.md §5), instead of a vmapped
    per-query scan.  Queries whose loop has converged are frozen via masked
    state updates, reproducing per-query while-loop semantics exactly.
    """
    from ..kernels import ops

    n = packed.shape[0]
    b = q_rot.shape[0]
    barange = jnp.arange(b)

    def score_rows(rows):
        """Adjusted scores [b, r] of clamped rows for ALL queries (converged
        ones included — freezing happens in the callers' state updates);
        callers mask invalid slots."""
        return ops.score_gathered(
            packed, q_rot, jnp.maximum(rows, 0),
            valid=jnp.ones(rows.shape, bool),
            bits=bits, n4_dims=n4_dims, qnorms=qnorms, metric=metric,
            use_kernel=use_kernel, interpret=interpret,
        )

    # --- Greedy descent over upper layers (ef=1). ---
    ep = jnp.full((b,), entry, jnp.int32)
    for level in range(max_level, 0, -1):
        table = nbr_hi[level - 1]

        def cond(state):
            _, _, improved = state
            return jnp.any(improved)

        def body(state):
            cur, cur_s, improved = state
            nbrs = table[cur]                                  # [b, M]
            ss = jnp.where(nbrs >= 0, score_rows(nbrs), NEG)
            j = jnp.argmax(ss, axis=1)                         # [b]
            best_s = ss[barange, j]
            # A query stops improving once its best neighbor doesn't beat the
            # current score; frozen queries never restart (& improved).
            better = (best_s > cur_s) & improved
            return (
                jnp.where(better, nbrs[barange, j], cur),
                jnp.where(better, best_s, cur_s),
                better,
            )

        s0 = score_rows(ep[:, None])[:, 0]
        ep, _, _ = jax.lax.while_loop(
            cond, body, (ep, s0, jnp.ones((b,), bool))
        )

    # --- Level-0 beam of width ef. ---
    # Pre-filter semantics over a graph: the beam routes over ALL nodes
    # (restricting traversal would disconnect the graph for selective
    # allowlists), but only allowed nodes enter the RESULT set — i.e. the
    # allowlist is applied before ranking, never as a post-filter.
    m0 = nbr0.shape[1]
    s_entry = score_rows(ep[:, None])[:, 0]              # [b]
    scores = jnp.full((b, ef), NEG, jnp.float32).at[:, 0].set(s_entry)
    ids_ = jnp.full((b, ef), -1, jnp.int32).at[:, 0].set(ep)
    expanded = jnp.zeros((b, ef), bool)
    visited = jnp.zeros((b, n), bool).at[barange, ep].set(True)
    allow_ep = allow_mask[ep][:, None]                          # [b, 1]
    r_scores = jnp.where(allow_ep, scores, NEG)                 # results
    r_ids = jnp.where(allow_ep, ids_, -1)

    def cond(state):
        scores, ids_, expanded, visited, r_scores, r_ids = state
        frontier = (~expanded) & (ids_ >= 0)
        return jnp.any(frontier)

    def body(state):
        scores, ids_, expanded, visited, r_scores, r_ids = state
        frontier = (~expanded) & (ids_ >= 0)
        active = jnp.any(frontier, axis=1)                      # [b]
        sel = jnp.argmax(jnp.where(frontier, scores, NEG), axis=1)
        expanded = expanded | (
            jax.nn.one_hot(sel, ef, dtype=bool) & active[:, None]
        )
        nbrs = nbr0[jnp.maximum(ids_[barange, sel], 0)]         # [b, 2M]
        nv = jnp.maximum(nbrs, 0)
        fresh = (
            (nbrs >= 0)
            & (~jnp.take_along_axis(visited, nv, axis=1))
            & active[:, None]
        )
        visited = visited.at[barange[:, None], nv].max(fresh)
        ns_all = score_rows(nbrs)
        ns = jnp.where(fresh, ns_all, NEG)
        # Beam merge: existing beam first, then new candidates (stable).
        all_s = jnp.concatenate([scores, ns], axis=1)
        all_i = jnp.concatenate([ids_, nbrs], axis=1)
        all_e = jnp.concatenate(
            [expanded, jnp.zeros((b, m0), bool)], axis=1
        )
        top_s, pos = jax.lax.top_k(all_s, ef)
        # Result merge: allowed fresh candidates only.
        ns_res = jnp.where(fresh & jnp.take(allow_mask, nv), ns_all, NEG)
        rs = jnp.concatenate([r_scores, ns_res], axis=1)
        ri = jnp.concatenate([r_ids, nbrs], axis=1)
        r_top, r_pos = jax.lax.top_k(rs, ef)
        # Freeze converged queries: their state must not churn (the top_k
        # re-sort above would otherwise reorder equal-score beams).
        keep = active[:, None]
        return (
            jnp.where(keep, top_s, scores),
            jnp.where(keep, jnp.take_along_axis(all_i, pos, axis=1), ids_),
            jnp.where(keep, jnp.take_along_axis(all_e, pos, axis=1), expanded),
            visited,
            jnp.where(keep, r_top, r_scores),
            jnp.where(keep, jnp.take_along_axis(ri, r_pos, axis=1), r_ids),
        )

    scores, ids_, expanded, visited, r_scores, r_ids = jax.lax.while_loop(
        cond, body, (scores, ids_, expanded, visited, r_scores, r_ids)
    )
    r_ids = jnp.where(r_scores > NEG, r_ids, -1)
    top_s, pos = jax.lax.top_k(r_scores, k)
    return top_s, jnp.take_along_axis(r_ids, pos, axis=1)

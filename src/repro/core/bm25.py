"""BM25 sparse index (paper §3.6): term-based, zero-training, offline.

Chosen over SPLADE precisely because it needs no encoder model — consistent
with the training-free design.  Host-side inverted index with numpy postings;
fully deterministic scoring.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Unicode word pattern: any run of word characters minus underscore.  The
# old `[a-z0-9]+` silently dropped every non-ASCII term, so any non-English
# doc got an empty sparse channel; on lowercased ASCII text this pattern
# tokenizes identically (letters+digits runs split at `_`, which the old
# pattern also split at, since `_` matched neither class).
_TOKEN_RE = re.compile(r"[^\W_]+", re.UNICODE)


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


@dataclasses.dataclass
class Bm25Index:
    postings: Dict[str, Tuple[np.ndarray, np.ndarray]]  # term -> (doc rows, tf)
    doc_len: np.ndarray
    avg_len: float
    n_docs: int
    k1: float = 1.2
    b: float = 0.75

    @staticmethod
    def build(docs: Sequence[str], *, k1: float = 1.2, b: float = 0.75) -> "Bm25Index":
        tf_maps: List[Dict[str, int]] = []
        for doc in docs:
            tf: Dict[str, int] = {}
            for tok in tokenize(doc):
                tf[tok] = tf.get(tok, 0) + 1
            tf_maps.append(tf)
        doc_len = np.array([sum(m.values()) for m in tf_maps], dtype=np.float32)
        inv: Dict[str, List[Tuple[int, int]]] = {}
        for row, tf in enumerate(tf_maps):
            for term, c in tf.items():
                inv.setdefault(term, []).append((row, c))
        postings = {
            t: (
                np.array([r for r, _ in ps], dtype=np.int64),
                np.array([c for _, c in ps], dtype=np.float32),
            )
            for t, ps in inv.items()
        }
        return Bm25Index(
            postings=postings,
            doc_len=doc_len,
            avg_len=float(doc_len.mean()) if len(doc_len) else 0.0,
            n_docs=len(docs),
            k1=k1,
            b=b,
        )

    def idf(self, term: str) -> float:
        df = len(self.postings.get(term, ((), ()))[0])
        return math.log((self.n_docs - df + 0.5) / (df + 0.5) + 1.0)

    def score(self, query: str) -> np.ndarray:
        """Dense score vector over all docs (accumulated in doc order)."""
        scores = np.zeros(self.n_docs, dtype=np.float32)
        for term in tokenize(query):
            if term not in self.postings:
                continue
            rows, tf = self.postings[term]
            denom = tf + self.k1 * (1 - self.b + self.b * self.doc_len[rows] / max(self.avg_len, 1e-9))
            scores[rows] += self.idf(term) * tf * (self.k1 + 1) / denom
        return scores

    def search(
        self,
        query: str,
        k: int,
        *,
        allow_mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k rows by BM25 score; deterministic sort by (-score, row).

        ``allow_mask`` is the §3.5 pre-filter: disallowed rows are excluded
        BEFORE the top-k, so a selective allowlist still yields exactly
        min(k, n_allowed) rows — never a post-hoc-trimmed shortlist.
        """
        scores = self.score(query)
        rows = (
            np.arange(self.n_docs)
            if allow_mask is None
            else np.nonzero(allow_mask)[0]
        )
        k = min(k, len(rows))
        sub = scores[rows]
        order = np.lexsort((rows, -sub))[:k]
        return sub[order], rows[order]

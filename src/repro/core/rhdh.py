"""Randomized Hadamard Transform (RHDH) — the paper's data-oblivious rotation.

R = (1/sqrt(d')) * H * D   with D = diag(rademacher signs), H Walsh-Hadamard,
d' = next power of two >= d.  The sign stream is derived from a 64-bit seed
stored in the .mvec header; the paper uses ChaCha20, we use JAX's threefry
counter PRNG which is equally platform-deterministic (documented deviation,
DESIGN.md §2).

TPU adaptation (DESIGN.md §2): instead of the O(d log d) butterfly network —
which is a long chain of serial VPU shuffles on TPU — we exploit the Kronecker
factorization H_{ab} = H_a (x) H_b:   (H_a (x) H_b) vec(X) = vec(H_a X H_b)
for the row-major reshape X of the input.  Two dense matmuls against small
Hadamard factors (<= 256x256) run at MXU rate; for d'=1024 this is
2*d'*(a+b) = 2*1024*64 FLOPs — 64x fewer than a full d'^2 rotation and far
better utilization than log2(d')=10 serial butterfly stages.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def next_pow2(d: int) -> int:
    p = 1
    while p < d:
        p <<= 1
    return p


@functools.lru_cache(maxsize=None)
def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester Walsh-Hadamard matrix H_n (entries ±1), n a power of two."""
    assert n & (n - 1) == 0 and n > 0, f"n={n} must be a power of two"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h.astype(np.float32)


def _split_pow2(dp: int) -> Tuple[int, int]:
    """Split d' = a*b with a, b powers of two, a <= b, both near sqrt(d')."""
    lg = dp.bit_length() - 1
    a = 1 << (lg // 2)
    b = dp // a
    return a, b


def rademacher_signs(seed: int, d_pad: int) -> jnp.ndarray:
    """Deterministic ±1 diagonal from the 64-bit index seed.

    Resolved at TRACE time, always: the jax.random samplers are internally
    jitted, so when this runs under an outer trace (every compiled rotate
    stage) they would otherwise be staged into the program as live PRNG
    primitives instead of folding to the concrete sign vector the seed
    pins.  ensure_compile_time_eval forces the eager path, so the stage
    jaxpr sees only a ±1 constant — same bits, no random_* primitives
    (repro.analysis INV-NO-HOST-IN-TRACE).
    """
    with jax.ensure_compile_time_eval():
        key = jax.random.key(np.uint32(seed & 0xFFFFFFFF))
        key = jax.random.fold_in(key, np.uint32((seed >> 32) & 0xFFFFFFFF))
        return jax.random.rademacher(key, (d_pad,), dtype=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Walsh-Hadamard transform of the last axis (length must be a power of 2).

    Kronecker-factored: reshape (..., a, b), apply H_a on axis -2 and H_b on
    axis -1.  Unnormalized (multiply by 1/sqrt(d') for the orthogonal version).
    """
    d = x.shape[-1]
    a, b = _split_pow2(d)
    ha = jnp.asarray(hadamard_matrix(a))
    hb = jnp.asarray(hadamard_matrix(b))
    xr = x.reshape(x.shape[:-1] + (a, b))
    # H symmetric: H_a X H_b via two einsums (MXU-friendly contractions).
    y = jnp.einsum("ij,...jk->...ik", ha, xr)
    y = jnp.einsum("...ik,kl->...il", y, hb)
    return y.reshape(x.shape)


def pad_to_pow2(x: jnp.ndarray, d_pad: int) -> jnp.ndarray:
    d = x.shape[-1]
    if d == d_pad:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, d_pad - d)]
    return jnp.pad(x, pad)


def rhdh_apply(x: jnp.ndarray, seed: int, *, normalized: bool = True) -> jnp.ndarray:
    """Apply the seeded Hadamard rotation to the last axis; output has d' dims.

    normalized=True  -> R = (1/sqrt(d')) H D: orthogonal, preserves norms and
                        inner products exactly (up to f32 rounding).
    normalized=False -> Z = H D x: the QUANTIZER-SPACE transform.  For a unit
                        input each coordinate is a ±-signed sum of the entries,
                        Var = ||x||^2, i.e. ~N(0,1) on the unit sphere — this is
                        the paper's "after scaling by sqrt(d')" convention that
                        makes the precomputed N(0,1) Lloyd-Max tables valid.
                        All scores pick up a uniform d' factor, which leaves
                        every metric's ranking unchanged.
    """
    d_pad = next_pow2(x.shape[-1])
    signs = rademacher_signs(seed, d_pad)
    xp = pad_to_pow2(x, d_pad) * signs
    y = fwht(xp)
    if normalized:
        y = y * np.float32(1.0 / np.sqrt(d_pad))
    return y


def rhdh_inverse(y: jnp.ndarray, seed: int, d_orig: int) -> jnp.ndarray:
    """Inverse rotation: x = D H y / sqrt(d') truncated to the original dim."""
    d_pad = y.shape[-1]
    signs = rademacher_signs(seed, d_pad)
    x = fwht(y) * (1.0 / np.sqrt(d_pad)).astype(np.float32) * signs
    return x[..., :d_orig]

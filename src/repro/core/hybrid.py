"""Hybrid dense+sparse retrieval (paper §3.6): MonaVec dense + BM25, fused by RRF.

Pipeline (paper):
  1. query embedded (dense) + tokenized (sparse) simultaneously;
  2. dense top-K and BM25 top-K retrieved independently;
  3. RRF combination; 4. final top-K.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .allowlist import Allowlist
from .bm25 import Bm25Index
from .bruteforce import BruteForceIndex
from .rrf import rrf_fuse


@dataclasses.dataclass
class HybridIndex:
    dense: BruteForceIndex
    sparse: Bm25Index

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        docs: Sequence[str],
        *,
        metric: str = "cosine",
        seed: int = 0x6D6F6E61,
        std=None,
    ) -> "HybridIndex":
        assert vectors.shape[0] == len(docs)
        return HybridIndex(
            dense=BruteForceIndex.build(vectors, metric=metric, seed=seed, std=std),
            sparse=Bm25Index.build(docs),
        )

    def search(
        self,
        query_vec: jnp.ndarray,
        query_text: str,
        k: int,
        *,
        fetch_k: Optional[int] = None,
        rrf_k: int = 60,
        allow: Optional[Allowlist] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        fetch_k = fetch_k or max(2 * k, 20)
        _, dense_ids = self.dense.search(query_vec, fetch_k, allow=allow)
        # A selective allowlist can return fewer than fetch_k real rows;
        # SENTINEL_ID slots must not enter the fusion as if they were docs.
        from .segments import SENTINEL_ID
        dense_ids = dense_ids[0]
        dense_ids = dense_ids[dense_ids != SENTINEL_ID]
        # Both channels pre-filter (§3.5): the BM25 top-k runs over allowed
        # rows only, so selective allowlists still surface fetch_k sparse
        # candidates instead of a post-filtered remnant.
        _, sparse_rows = self.sparse.search(
            query_text, fetch_k,
            allow_mask=None if allow is None else allow.mask,
        )
        sparse_ids = self.dense.ids[sparse_rows]
        return rrf_fuse([dense_ids, sparse_ids], k=rrf_k, top_k=k)

"""Hybrid dense+sparse retrieval (paper §3.6): MonaVec dense + BM25, fused by RRF.

Pipeline (paper):
  1. query embedded (dense) + tokenized (sparse) simultaneously;
  2. dense top-K and BM25 top-K retrieved independently;
  3. RRF combination; 4. final top-K.

Since DESIGN.md §8 this is a thin facade over ``repro.engine.fusion``: the
dense channel runs as one compiled, bucketed SearchPlan (predicate mask
stage included), BM25 stays host-side with the same combined
allowlist ∧ predicate pre-filter on its channel, and the RRF merge is the
deterministic host stage.  Batched queries are first-class — ``[b, d]``
vectors with ``b`` texts return ``[b, k]`` results, each row identical to
its single-query run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .allowlist import Allowlist
from .bm25 import Bm25Index
from .bruteforce import BruteForceIndex
from .metadata import MetaStore
from .predicate import Predicate


@dataclasses.dataclass
class HybridIndex:
    dense: BruteForceIndex
    sparse: Bm25Index
    meta: Optional[MetaStore] = None

    @staticmethod
    def build(
        vectors: jnp.ndarray,
        docs: Sequence[str],
        *,
        metric: str = "cosine",
        seed: int = 0x6D6F6E61,
        std=None,
        meta: Optional[dict] = None,
    ) -> "HybridIndex":
        assert vectors.shape[0] == len(docs)
        store = (MetaStore.build(meta, int(vectors.shape[0]))
                 if meta else None)
        return HybridIndex(
            dense=BruteForceIndex.build(vectors, metric=metric, seed=seed, std=std),
            sparse=Bm25Index.build(docs),
            meta=store,
        )

    def search(
        self,
        query_vec: jnp.ndarray,
        query_text: Union[str, Sequence[str]],
        k: int = 10,
        *,
        fetch_k: Optional[int] = None,
        rrf_k: int = 60,
        allow: Optional[Allowlist] = None,
        where: Optional[Predicate] = None,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Hybrid top-k through the engine (``repro.engine.fusion``).

        Single query (1-D vec + str): the classic 1-D ``(scores, ids)``,
        possibly shorter than ``k`` when the fused candidate pool is small.
        Batch ([b, d] vec + b texts): ``[b, k]`` arrays, rows padded with
        id -1 / score 0.0.  ``where=`` filters BOTH channels through the
        index's metadata columns (§3.5 pre-filter semantics).
        """
        from ..engine import fusion
        return fusion.search_hybrid(
            self, query_vec, query_text, k, fetch_k=fetch_k, rrf_k=rrf_k,
            allow=allow, where=where, use_kernel=use_kernel,
            interpret=interpret,
        )

"""Metric-aware input preparation (paper §3.1.1).

- Cosine: unit-normalize.
- L2: optional *global scalar* standardization (x - mu)/sigma with scalar mu,
  sigma over ALL entries of a representative sample — a uniform scaling, so it
  preserves Euclidean ordering exactly (the paper's contribution #2).
- Dot: raw passthrough (magnitude is signal).

Per-dimension whitening is provided only as the ablation baseline: it changes
the metric to Mahalanobis and the paper shows it LOSES to global scaling
(0.53 vs 0.62 Recall@10 on fashion-mnist).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

COSINE = "cosine"
DOT = "dot"
L2 = "l2"
METRICS = (COSINE, DOT, L2)


@dataclasses.dataclass(frozen=True)
class GlobalStd:
    """Scalar (mu, sigma) computed by fit(); persisted in the .mvec v6 block."""

    mean: float
    inv_std: float

    @staticmethod
    def fit(sample: jnp.ndarray, eps: float = 1e-12) -> "GlobalStd":
        """Single pass, summary statistics only (paper Table 1: 'Calibration')."""
        x = np.asarray(sample, dtype=np.float64)
        mu = float(x.mean())
        sigma = float(x.std())
        return GlobalStd(mean=mu, inv_std=1.0 / max(sigma, eps))

    def transform(self, x: jnp.ndarray) -> jnp.ndarray:
        return (x - jnp.float32(self.mean)) * jnp.float32(self.inv_std)


@dataclasses.dataclass(frozen=True)
class PerDimWhiten:
    """Ablation baseline ONLY (Mahalanobis — breaks L2 ordering, paper §3.1.1)."""

    mean: np.ndarray
    inv_std: np.ndarray

    @staticmethod
    def fit(sample: jnp.ndarray, eps: float = 1e-6) -> "PerDimWhiten":
        x = np.asarray(sample, dtype=np.float64)
        mu = x.mean(axis=0)
        sigma = np.maximum(x.std(axis=0), eps)
        return PerDimWhiten(mean=mu.astype(np.float32), inv_std=(1.0 / sigma).astype(np.float32))

    def transform(self, x: jnp.ndarray) -> jnp.ndarray:
        return (x - jnp.asarray(self.mean)) * jnp.asarray(self.inv_std)


def unit_normalize(x: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(n, eps)


def prepare(
    x: jnp.ndarray,
    metric: str,
    std: Optional[GlobalStd] = None,
) -> jnp.ndarray:
    """Metric-aware input preparation stage (Figure 1 of the paper)."""
    if metric == COSINE:
        return unit_normalize(x)
    if metric == L2:
        return std.transform(x) if std is not None else x
    if metric == DOT:
        return x
    raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")

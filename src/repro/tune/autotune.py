"""Recall-targeted, training-free knob autotuning (DESIGN.md §12).

The paper's "one file, one call" pitch leaves nprobe/ef/rescore_mult to the
user; Faiss's autotune and the recall/latency Pareto framing in Foundations
of Vector Retrieval point at the fix: sweep each backend's knob ladder
offline against an exact oracle and persist the cheapest setting meeting a
recall target.  MonaVec's version is deterministic end to end:

  * sample queries are LIVE CORPUS ROWS (strided over the live positions,
    reconstructed from the quantized codes) plus seeded gaussian jitter —
    no held-out data, no training;
  * the oracle is a brute-force full scan over the SAME quantized segments
    (``BruteForceIndex`` wrapped around the backend's own encoding), so
    recall isolates exactly what the knob controls — candidate generation —
    from quantization error;
  * recall is an exact hit-count rational; the chosen rung is the SMALLEST
    one meeting the target (knob ladders are cost-monotone, so smallest ==
    cheapest without measuring wall-clock — QPS never enters the persisted
    result, which is what makes re-tuning byte-deterministic).

The same machinery tunes the selectivity BOOST CURVE: at seeded selectivity
probes (1%, 10%, 50%) it finds the smallest knob multiplier restoring the
target under a filter — the fix for filtered IVF recall collapsing at 1%
selectivity (benchmarks/filtered_bench.py), applied per query by
``engine.plan`` via the exact popcount in ``tune.selectivity``.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import segments as seg
from repro.core.bruteforce import BruteForceIndex

from .result import BoostCurve, BoostPoint, KnobRung, TuneResult

#: Boost-curve selectivity probes and the multiplier ladder swept at each.
BOOST_SELECTIVITIES = (0.01, 0.1, 0.5)
BOOST_MULTS = (1, 2, 4, 8, 16, 32)

_NOISE = 0.15      # query jitter, in units of the sampled rows' std


# ---------------------------------------------------------------------------
# Seeded sample queries + the exact oracle.
# ---------------------------------------------------------------------------

def sample_queries(index: Any, n_queries: int, seed: int) -> np.ndarray:
    """[n_q, dim] f32 — strided live corpus rows + seeded gaussian jitter.

    Strided selection over the live row positions covers every segment and
    every IVF list proportionally; the jitter keeps queries off the exact
    lattice points (a query equal to a stored row is the easy case for any
    candidate generator).  Pure function of (corpus bytes, seed).
    """
    encs = [index.backend.enc] + [s.enc for s in index.mut.extras]
    live = seg.live_mask(index.mut, None, index.backend.enc.n)
    positions = np.flatnonzero(live)
    if positions.size == 0:
        raise ValueError("autotune: the index has no live rows")
    n_q = int(min(n_queries, positions.size))
    sel = positions[np.linspace(0, positions.size - 1, n_q).round()
                    .astype(np.int64)]
    sel = np.unique(sel)

    offsets = np.concatenate([[0], np.cumsum([e.n for e in encs])])
    rows: List[np.ndarray] = []
    for i, enc in enumerate(encs):
        local = sel[(sel >= offsets[i]) & (sel < offsets[i + 1])] - offsets[i]
        if local.size:
            rows.append(seg.reconstruct_rows(enc, local))
    base = np.concatenate(rows).astype(np.float32)
    rng = np.random.RandomState(seed & 0xFFFFFFFF)
    sigma = float(np.std(base)) or 1.0
    noise = (_NOISE * sigma) * rng.randn(*base.shape)
    return (base + noise.astype(np.float32)).astype(np.float32)


def _oracle_backend(index: Any) -> BruteForceIndex:
    """Exact full scan over the backend's OWN quantized encoding."""
    return BruteForceIndex(enc=index.backend.enc, ids=index.backend.ids)


def _engine_state(index: Any) -> Any:
    return None if index.mut.is_static else index.mut


def _search_ids(backend: Any, state: Any, queries: np.ndarray, k: int,
                where_mask: Optional[np.ndarray] = None,
                **kwargs: Any) -> np.ndarray:
    from repro import engine
    _, ids = engine.search_backend(backend, state, queries, k,
                                   where_mask=where_mask, **kwargs)
    return ids


def measure_recall(ids: np.ndarray, oracle_ids: np.ndarray) -> float:
    """Exact recall@k: |pred ∩ oracle| / |oracle|, sentinels excluded.

    Rows where the oracle itself has no admissible result contribute
    nothing to either count; an all-sentinel oracle (empty filter) is
    vacuously 1.0.
    """
    num = den = 0
    sent = int(seg.SENTINEL_ID)
    for row_pred, row_gold in zip(np.asarray(ids), np.asarray(oracle_ids)):
        gold = {int(x) for x in row_gold if int(x) != sent}
        den += len(gold)
        num += len(gold & {int(x) for x in row_pred})
    return 1.0 if den == 0 else num / den


# ---------------------------------------------------------------------------
# Knob ladders (ascending == cheapest first; cost is monotone in each knob).
# ---------------------------------------------------------------------------

def knob_ladder(index: Any, k: int) -> Tuple[Optional[str], Tuple[int, ...]]:
    """(knob name, ascending candidate values) for this backend.

    (None, ()) means the backend has nothing to tune — a plain BruteForce
    full scan is already exact, so its tuned knobs are empty and
    ``met_target`` is trivially True.
    """
    backend = index.backend
    kind = type(backend).__name__
    if kind == "IvfFlatIndex":
        vals = []
        p = 1
        while p < backend.nlist:
            vals.append(p)
            p <<= 1
        vals.append(int(backend.nlist))          # always-safe ceiling
        return "nprobe", tuple(vals)
    if kind == "HnswIndex":
        n = int(backend.enc.n)
        lo, cap = max(k, 8), min(max(n, 8), 1024)
        vals = []
        e = lo
        while e < cap:
            vals.append(e)
            e <<= 1
        vals.append(cap)
        return "ef", tuple(vals)
    # BruteForce: only the cascade has a knob, and only when every segment
    # carries coarse codes.
    encs = [backend.enc] + [s.enc for s in index.mut.extras]
    if any(e.ccodes is None for e in encs):
        return None, ()
    max_n = max(e.n for e in encs)
    vals = []
    rm = 1
    while rm * k < max_n:
        vals.append(rm)
        rm <<= 1
    vals.append(rm)     # collapses to the full scan: recall 1.0 by construction
    return "rescore_mult", tuple(vals)


def _pick(rungs: Sequence[KnobRung], target: float) -> Tuple[KnobRung, bool]:
    """Smallest rung meeting the target, else the best-recall rung (ties to
    the smaller value — rungs are ascending)."""
    for r in rungs:
        if r.recall >= target:
            return r, True
    best = rungs[0]
    for r in rungs[1:]:
        if r.recall > best.recall:
            best = r
    return best, False


# ---------------------------------------------------------------------------
# The tuner.
# ---------------------------------------------------------------------------

def _tune_boost(index: Any, knob: str, chosen: int, queries: np.ndarray,
                k: int, recall_target: float, seed: int) -> Optional[BoostCurve]:
    """Smallest knob multiplier restoring the target at each selectivity
    probe.  Probe masks are seeded Bernoulli draws over ALL rows (the same
    distribution the filtered benchmark sweeps); the oracle is the filtered
    full scan, so recall isolates candidate-generation loss under the mask."""
    backend, state = index.backend, _engine_state(index)
    oracle = _oracle_backend(index)
    n_total = int(index.n_total)
    points = []
    for i, s in enumerate(BOOST_SELECTIVITIES):
        rng = np.random.RandomState((seed * 1000003 + i) % (1 << 32))
        mask = rng.rand(n_total) < s
        if not mask.any():
            continue                      # probe degenerate at this corpus size
        gold = _search_ids(oracle, state, queries, k, where_mask=mask)
        mult, recall = 1, 0.0
        for mult in BOOST_MULTS:
            ids = _search_ids(backend, state, queries, k, where_mask=mask,
                              **{knob: chosen * mult})
            recall = measure_recall(ids, gold)
            if recall >= recall_target:
                break
        points.append(BoostPoint(selectivity=float(s), mult=int(mult),
                                 recall=float(recall)))
    return BoostCurve(points=tuple(points)) if points else None


def autotune(index: Any, *, recall_target: float = 0.95, k: int = 10,
             n_queries: int = 32, seed: int = 0xA07001,
             boost: bool = True) -> TuneResult:
    """Sweep the backend's knob ladder against the exact oracle and return
    the cheapest setting meeting ``recall@k >= recall_target``.

    Pure function of (corpus bytes, arguments): the returned TuneResult —
    and therefore the saved v11 file — is byte-deterministic across runs
    and platforms.  Wall-clock lands only in obs histograms, never in the
    result.
    """
    if not (0.0 < recall_target <= 1.0):
        raise ValueError(
            f"recall_target must be in (0, 1], got {recall_target}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    backend, state = index.backend, _engine_state(index)
    kind = type(backend).__name__
    with obs.timed_span("autotune", histogram="tune.autotune_us",
                        labels={"backend": kind}):
        queries = sample_queries(index, n_queries, seed)
        knob, values = knob_ladder(index, k)
        if knob is None:
            result = TuneResult(
                recall_target=float(recall_target), k=int(k),
                n_queries=int(queries.shape[0]), seed=int(seed),
                met_target=True, knobs={}, ladder={}, boost=None)
        else:
            oracle = _oracle_backend(index)
            gold = _search_ids(oracle, state, queries, k)
            rungs = tuple(
                KnobRung(value=int(v), recall=float(measure_recall(
                    _search_ids(backend, state, queries, k, **{knob: v}),
                    gold)))
                for v in values)
            chosen, met = _pick(rungs, recall_target)
            curve = None
            if boost and kind in ("IvfFlatIndex", "BruteForceIndex"):
                curve = _tune_boost(index, knob, chosen.value, queries, k,
                                    recall_target, seed)
            result = TuneResult(
                recall_target=float(recall_target), k=int(k),
                n_queries=int(queries.shape[0]), seed=int(seed),
                met_target=met, knobs={knob: int(chosen.value)},
                ladder={knob: rungs}, boost=curve)
    obs.inc("tune.runs", **{"backend": kind,
                            "met_target": str(result.met_target)})
    return result


__all__ = ["BOOST_MULTS", "BOOST_SELECTIVITIES", "autotune", "knob_ladder",
           "measure_recall", "sample_queries"]

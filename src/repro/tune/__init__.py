# Training-free autotuning (DESIGN.md §12): recall-targeted knob selection
# persisted in the .mvec (v11 TUNE block), plus exact predicate-selectivity
# estimation driving the engine's filtered candidate-budget boost.
#
# Import shape: result.py is pure data (no repro imports — mvec_format and
# engine.plan both name TuneResult without a cycle); autotune.py drives the
# real engine; selectivity.py exports the popcount PLAN STAGE the analysis
# auditor witnesses.

from .autotune import autotune, knob_ladder, measure_recall, sample_queries
from .result import BoostCurve, BoostPoint, KnobRung, TuneResult
from .selectivity import clear_caches, estimate_matches, make_popcount_fn

__all__ = [
    "BoostCurve", "BoostPoint", "KnobRung", "TuneResult",
    "autotune", "clear_caches", "estimate_matches", "knob_ladder",
    "make_popcount_fn", "measure_recall", "sample_queries",
]

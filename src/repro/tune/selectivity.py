"""Exact predicate-selectivity estimation (DESIGN.md §12).

Filtered IVF recall collapses at low selectivity because candidate lists are
pruned BEFORE the predicate mask; the fix (the boost curve in
``tune.autotune``) needs to know, per query, how selective the predicate is.
"Estimate" here means an EXACT popcount of the compiled predicate mask ANDed
with the live mask — the same ``predicate.build_stage_fn`` lowering the
engine fuses into its plans, reduced to an int32 count instead of consumed
by a scan.  Exactness keeps the boost decision deterministic (cache keys and
plan keys never depend on a sampling RNG) and lets the hypothesis suite pin
the device count against the host ``predicate.evaluate`` oracle bit-for-bit.

Two caches keep this off the per-query hot path:

  * one compiled popcount program per predicate STRUCTURE (same sharing rule
    as the plan cache: constants ride as dynamic operands, so Eq("a", 1) and
    Eq("a", 2) share a trace);
  * an LRU of computed counts keyed by (structure, encoded constants, used
    columns' version tokens, row count, live-mask digest).  Column version
    tokens (``metadata.Column.version``) are minted per construction and
    every mutation path builds new Column objects, so a token mismatch is a
    sound staleness signal without hashing value arrays.

The popcount is a PLAN STAGE for the determinism auditor: invocations are
reported through ``engine.plan``'s stage-observer slot and the analysis grid
witnesses ``selectivity_popcount`` captures (DESIGN.md §10).
"""

from __future__ import annotations

import collections
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import predicate as pred_mod
from repro.core.metadata import MetaStore, encode_constant

#: repro.analysis coverage hook: the popcount program is a compiled stage the
#: auditor must capture from a live grid run (grid point ``tuned+where``).
PLAN_STAGES = ("make_popcount_fn",)

_STAGE_NAME = "selectivity_popcount"

#: structure -> (raw stage fn, jitted stage fn)
_FN_CACHE: Dict[tuple, Tuple[Callable, Callable]] = {}

#: LRU of exact counts; bounded so long-lived servers cannot grow it.
_COUNT_CACHE: "collections.OrderedDict[tuple, int]" = collections.OrderedDict()
_COUNT_CACHE_MAX = 256


def make_popcount_fn(p: "pred_mod.Predicate") -> Callable[..., jnp.ndarray]:
    """Compile ``fn(live, *args) -> int32 count of live & mask``.

    Same argument convention as ``predicate.build_stage_fn`` (whose lowering
    this wraps): constants are dynamic operands, never trace constants.  The
    reduction is integer, so the count is exact under any XLA fusion.
    """
    mask_fn = pred_mod.build_stage_fn(p)

    def popcount(live: jnp.ndarray, *args: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(mask_fn(live, *args).astype(jnp.int32))

    return popcount


def _constants_key(p: "pred_mod.Predicate", store: MetaStore) -> tuple:
    """Encoded constants per leaf, preorder — hashable, exact."""
    out = []
    for leaf in pred_mod._leaves(p):
        col = store[leaf.col]
        vocab = col.vocab_map()
        values = leaf.values if isinstance(leaf, pred_mod.In) else (leaf.value,)
        out.append(tuple(encode_constant(col.kind, v, vocab) for v in values))
    return tuple(out)


def _live_key(live: Optional[np.ndarray]) -> Optional[tuple]:
    if live is None:
        return None
    arr = np.asarray(live)
    return (int(arr.shape[0]), hash(arr.tobytes()))


def estimate_matches(p: "pred_mod.Predicate", store: MetaStore,
                     live: Optional[np.ndarray] = None) -> int:
    """Exact count of rows passing ``p`` (restricted to ``live`` if given).

    Cached per (structure, constants, column versions, rows, live mask);
    misses run the compiled popcount stage on device.
    """
    structure = pred_mod.structure(p, store)
    key = (
        structure,
        _constants_key(p, store),
        tuple(store[c].version for c in pred_mod.used_columns(p)),
        store.n_rows,
        _live_key(live),
    )
    hit = _COUNT_CACHE.get(key)
    if hit is not None:
        _COUNT_CACHE.move_to_end(key)
        obs.inc("tune.selectivity_cache.hits")
        return hit
    obs.inc("tune.selectivity_cache.misses")

    cached = _FN_CACHE.get(structure)
    if cached is None:
        import jax
        raw = make_popcount_fn(p)
        cached = _FN_CACHE[structure] = (raw, jax.jit(raw))
    raw, jitted = cached

    if live is None:
        live_arr = jnp.ones((store.n_rows,), dtype=bool)
    else:
        live_arr = jnp.asarray(np.asarray(live, dtype=bool))
    args = pred_mod.flatten_args(p, store)

    from repro.engine import plan as plan_mod
    if plan_mod._STAGE_OBSERVER is not None:
        plan_mod._STAGE_OBSERVER(
            "SelectivityEstimator", _STAGE_NAME, raw, (live_arr,) + args)

    count = int(jitted(live_arr, *args))
    _COUNT_CACHE[key] = count
    while len(_COUNT_CACHE) > _COUNT_CACHE_MAX:
        _COUNT_CACHE.popitem(last=False)
    return count


def clear_caches() -> None:
    """Drop both caches (tests; never required for correctness)."""
    _FN_CACHE.clear()
    _COUNT_CACHE.clear()


__all__ = ["PLAN_STAGES", "clear_caches", "estimate_matches",
           "make_popcount_fn"]

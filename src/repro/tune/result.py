"""Autotuning result types (DESIGN.md §12).

Plain data, deliberately free of any ``repro`` import: ``TuneResult`` is
persisted in the ``.mvec`` v11 TUNE block (``core.mvec_format``), rides on
``MonaVec.tuned``, and resolves into engine plan-key defaults
(``engine.plan``) — three layers that must all be able to name the type
without an import cycle.

Determinism contract: every field is a pure function of
(corpus bytes, tuning seed, tuning parameters).  Recalls are exact
hit-count ratios (num/den in double precision), never wall-clock-derived;
the chosen knob is the SMALLEST ladder rung whose measured recall meets the
target (cost is structurally monotone in each knob, so "cheapest on the
Pareto front" needs no timing).  Saving the same tuned index twice —
or re-tuning the same corpus under the same seed — yields byte-identical
files (pinned by tests/test_autotune.py and the v11 golden fixture).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class KnobRung:
    """One measured point of a knob ladder sweep."""

    value: int                 # the knob setting (nprobe / ef / rescore_mult)
    recall: float              # exact recall@k vs the full-scan oracle


@dataclasses.dataclass(frozen=True)
class BoostPoint:
    """One tuned step of the selectivity boost curve."""

    selectivity: float         # probe selectivity this step was tuned at
    mult: int                  # knob multiplier chosen for that selectivity
    recall: float              # measured filtered recall@k at (mult, sel)


@dataclasses.dataclass(frozen=True)
class BoostCurve:
    """Step function: query selectivity -> candidate-budget multiplier.

    ``points`` are ascending in selectivity.  A query whose measured
    selectivity ``s`` falls at or below a breakpoint uses that breakpoint's
    multiplier (the curve tuned AT 1% is what a <=1% query needs); queries
    less selective than the largest breakpoint take no boost.
    """

    points: Tuple[BoostPoint, ...]

    def __post_init__(self) -> None:
        sels = [p.selectivity for p in self.points]
        if sels != sorted(sels):
            raise ValueError(
                f"boost curve breakpoints must ascend, got {sels}")

    def multiplier(self, selectivity: float) -> int:
        for p in self.points:
            if selectivity <= p.selectivity:
                return int(p.mult)
        return 1


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """The persisted outcome of one autotune run (``.mvec`` v11 TUNE block).

    ``knobs`` become the engine's plan-key DEFAULTS (precedence: explicit
    per-call kwarg > tuned knob > engine default — DESIGN.md §12);
    ``ladder`` records the full measured sweep so the choice is auditable;
    ``boost`` (optional) is the selectivity-aware candidate-budget curve.
    ``met_target`` is False when no ladder rung reached the target (the
    best-recall rung is chosen instead — HNSW graphs can cap below 1.0).
    """

    recall_target: float
    k: int
    n_queries: int
    seed: int
    met_target: bool
    knobs: Dict[str, int]
    ladder: Dict[str, Tuple[KnobRung, ...]]
    boost: Optional[BoostCurve] = None


__all__ = ["BoostCurve", "BoostPoint", "KnobRung", "TuneResult"]

"""RecSys architectures: DLRM, DIEN (AUGRU), two-tower retrieval, FM.

The embedding LOOKUP is the hot path.  JAX has no native ``nn.EmbeddingBag``;
we implement it as ``jnp.take`` + ``jax.ops.segment_sum`` (taxonomy §RecSys —
this is part of the system, not a gap).  Tables are laid out [V, D] and are
row- or table-sharded over the 'model' mesh axis in the dry-run.

``retrieval_cand`` (two-tower, 1M candidates) is the paper's own setting at
production scale: candidate scoring goes through either an exact f32 matmul
or the MonaVec 4-bit packed scan (``score_candidates_packed``), making the
quantized kernel a first-class serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense, dense_init, mlp, mlp_init


# ---------------------------------------------------------------------------
# EmbeddingBag (take + segment_sum).
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * (1.0 / np.sqrt(dim))).astype(dtype)


def embedding_bag(
    table: jnp.ndarray,          # [V, D]
    indices: jnp.ndarray,        # [n_lookups] flat ids
    bag_ids: jnp.ndarray,        # [n_lookups] which bag each lookup belongs to
    n_bags: int,
    *,
    combiner: str = "sum",
    weights: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Ragged multi-hot bag reduce: rows = take, reduce = segment_sum/max."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if combiner == "max":
        return jax.ops.segment_max(rows, bag_ids, num_segments=n_bags)
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if combiner == "mean":
        counts = jax.ops.segment_sum(jnp.ones_like(indices, jnp.float32), bag_ids,
                                     num_segments=n_bags)
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean binary cross-entropy from logits, f32."""
    lg = logits.astype(jnp.float32).reshape(-1)
    lb = labels.astype(jnp.float32).reshape(-1)
    return jnp.mean(jnp.maximum(lg, 0) - lg * lb + jnp.log1p(jnp.exp(-jnp.abs(lg))))


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091): bottom MLP + embeddings + dot interaction + top MLP.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_sizes: Tuple[int, ...] = tuple([1 << 20] * 26)   # ~1M rows each
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def dlrm_init(cfg: DLRMConfig, key):
    k_bot, k_emb, k_top = jax.random.split(key, 3)
    n_f = cfg.n_sparse + 1
    d_interact = n_f * (n_f - 1) // 2 + cfg.embed_dim
    return {
        "bot": mlp_init(k_bot, (cfg.n_dense,) + cfg.bot_mlp, dtype=cfg.jnp_dtype),
        "tables": [embedding_init(jax.random.fold_in(k_emb, i), v, cfg.embed_dim,
                                  cfg.jnp_dtype)
                   for i, v in enumerate(cfg.vocab_sizes)],
        "top": mlp_init(k_top, (d_interact,) + cfg.top_mlp, dtype=cfg.jnp_dtype),
    }


def dlrm_forward(params, cfg: DLRMConfig, dense_x: jnp.ndarray,
                 sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """dense_x [B, 13]; sparse_ids [B, 26] (single-hot per field) -> logits [B]."""
    b = dense_x.shape[0]
    z = mlp(params["bot"], dense_x, act=jax.nn.relu, final_act=jax.nn.relu)  # [B, D]
    embs = [jnp.take(t, sparse_ids[:, i], axis=0)
            for i, t in enumerate(params["tables"])]                          # 26x[B,D]
    feats = jnp.stack([z] + embs, axis=1)                                     # [B, 27, D]
    # Dot interaction: pairwise inner products, strictly-lower triangle.
    gram = jnp.einsum("bnd,bmd->bnm", feats, feats, preferred_element_type=jnp.float32)
    n_f = cfg.n_sparse + 1
    iu = jnp.tril_indices(n_f, k=-1)
    interactions = gram[:, iu[0], iu[1]]                                      # [B, 351]
    top_in = jnp.concatenate([interactions.astype(z.dtype), z], axis=-1)
    return mlp(params["top"], top_in, act=jax.nn.relu)[:, 0]


def dlrm_loss(params, cfg: DLRMConfig, batch) -> jnp.ndarray:
    logits = dlrm_forward(params, cfg, batch["dense"], batch["sparse"])
    return bce_loss(logits, batch["label"])


# ---------------------------------------------------------------------------
# DIEN (arXiv:1809.03672): GRU interest extraction + AUGRU interest evolution.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: Tuple[int, ...] = (200, 80)
    item_vocab: int = 1 << 20
    cat_vocab: int = 1 << 14
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_in(self) -> int:
        return 2 * self.embed_dim       # item ++ category


def _gru_init(key, d_in: int, d_h: int, dtype):
    k1, k2 = jax.random.split(key)
    s_in, s_h = 1.0 / np.sqrt(d_in), 1.0 / np.sqrt(d_h)
    return {
        "w": (jax.random.normal(k1, (d_in, 3 * d_h)) * s_in).astype(dtype),
        "u": (jax.random.normal(k2, (d_h, 3 * d_h)) * s_h).astype(dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _gru_cell(p, h, x, *, update_gate_scale=None):
    """Standard GRU cell; AUGRU scales the update gate by attention weight."""
    gates = x @ p["w"] + h @ p["u"] + p["b"]
    dh = h.shape[-1]
    r = jax.nn.sigmoid(gates[..., :dh])
    z = jax.nn.sigmoid(gates[..., dh: 2 * dh])
    if update_gate_scale is not None:
        z = z * update_gate_scale[..., None]             # AUGRU: a_t * z_t
    n = jnp.tanh(x @ p["w"][:, 2 * dh:] + r * (h @ p["u"][:, 2 * dh:]) + p["b"][2 * dh:])
    return (1.0 - z) * h + z * n


def dien_init(cfg: DIENConfig, key):
    ks = jax.random.split(key, 6)
    dtype = cfg.jnp_dtype
    return {
        "item_emb": embedding_init(ks[0], cfg.item_vocab, cfg.embed_dim, dtype),
        "cat_emb": embedding_init(ks[1], cfg.cat_vocab, cfg.embed_dim, dtype),
        "gru1": _gru_init(ks[2], cfg.d_in, cfg.gru_dim, dtype),
        "augru": _gru_init(ks[3], cfg.gru_dim, cfg.gru_dim, dtype),
        "att": dense_init(ks[4], cfg.gru_dim + cfg.d_in, 1, bias=True, dtype=dtype),
        "mlp": mlp_init(ks[5], (cfg.gru_dim + 2 * cfg.d_in,) + cfg.mlp + (1,),
                        dtype=dtype),
    }


def dien_forward(params, cfg: DIENConfig, batch, *, unroll: bool = False) -> jnp.ndarray:
    """batch: hist_items/hist_cats [B,S], target_item/target_cat [B] -> logits [B].

    unroll=True python-unrolls the two recurrences (dry-run FLOP accounting:
    XLA counts a while-loop body once regardless of trip count)."""
    hist = jnp.concatenate([
        jnp.take(params["item_emb"], batch["hist_items"], axis=0),
        jnp.take(params["cat_emb"], batch["hist_cats"], axis=0),
    ], axis=-1)                                              # [B, S, 2E]
    target = jnp.concatenate([
        jnp.take(params["item_emb"], batch["target_item"], axis=0),
        jnp.take(params["cat_emb"], batch["target_cat"], axis=0),
    ], axis=-1)                                              # [B, 2E]
    b = hist.shape[0]

    # Interest extraction: GRU over the behaviour sequence (lax.scan over time).
    def step1(h, x_t):
        h = _gru_cell(params["gru1"], h, x_t)
        return h, h
    h0 = jnp.zeros((b, cfg.gru_dim), hist.dtype)
    hist_t = hist.transpose(1, 0, 2)
    if unroll:
        hh, acc = h0, []
        for t in range(cfg.seq_len):
            hh, _ = step1(hh, hist_t[t])
            acc.append(hh)
        interests = jnp.stack(acc)
    else:
        _, interests = jax.lax.scan(step1, h0, hist_t)           # [S, B, H]

    # Attention vs the target ad (concat-MLP scoring), softmax over time.
    tgt = jnp.broadcast_to(target[None], (cfg.seq_len, b, cfg.d_in))
    att_logits = dense(params["att"], jnp.concatenate([interests, tgt], -1))[..., 0]
    att = jax.nn.softmax(att_logits.astype(jnp.float32), axis=0).astype(hist.dtype)

    # Interest evolution: AUGRU (attention scales the update gate).
    def step2(h, inp):
        i_t, a_t = inp
        h = _gru_cell(params["augru"], h, i_t, update_gate_scale=a_t)
        return h, None
    if unroll:
        h_final = h0
        for t in range(cfg.seq_len):
            h_final, _ = step2(h_final, (interests[t], att[t]))
    else:
        h_final, _ = jax.lax.scan(step2, h0, (interests, att))

    hist_mean = jnp.mean(hist, axis=1)
    feats = jnp.concatenate([h_final, target, hist_mean], axis=-1)
    return mlp(params["mlp"], feats, act=jax.nn.sigmoid)[:, 0]


def dien_loss(params, cfg: DIENConfig, batch) -> jnp.ndarray:
    return bce_loss(dien_forward(params, cfg, batch), batch["label"])


# ---------------------------------------------------------------------------
# Two-tower retrieval (RecSys'19): sampled softmax with logQ correction.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    user_vocab: int = 1 << 21
    item_vocab: int = 1 << 21
    n_user_feats: int = 8           # multi-hot history bag size
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def two_tower_init(cfg: TwoTowerConfig, key):
    ks = jax.random.split(key, 4)
    dtype = cfg.jnp_dtype
    return {
        "user_emb": embedding_init(ks[0], cfg.user_vocab, cfg.embed_dim, dtype),
        "item_emb": embedding_init(ks[1], cfg.item_vocab, cfg.embed_dim, dtype),
        "user_tower": mlp_init(ks[2], (cfg.embed_dim,) + cfg.tower_mlp, dtype=dtype),
        "item_tower": mlp_init(ks[3], (cfg.embed_dim,) + cfg.tower_mlp, dtype=dtype),
    }


def user_embedding(params, cfg: TwoTowerConfig, user_hist: jnp.ndarray) -> jnp.ndarray:
    """user_hist [B, n_feats] item-id bags -> L2-normalized user vectors [B, D]."""
    b, n = user_hist.shape
    bag = embedding_bag(params["user_emb"], user_hist.reshape(-1),
                        jnp.repeat(jnp.arange(b), n), b, combiner="mean")
    u = mlp(params["user_tower"], bag, act=jax.nn.relu)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-12)


def item_embedding(params, cfg: TwoTowerConfig, item_ids: jnp.ndarray) -> jnp.ndarray:
    rows = jnp.take(params["item_emb"], item_ids, axis=0)
    v = mlp(params["item_tower"], rows, act=jax.nn.relu)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-12)


def two_tower_loss(params, cfg: TwoTowerConfig, batch,
                   temperature: float = 0.05) -> jnp.ndarray:
    """In-batch sampled softmax with logQ correction (Yi et al., RecSys'19)."""
    u = user_embedding(params, cfg, batch["user_hist"])      # [B, D]
    v = item_embedding(params, cfg, batch["item_id"])        # [B, D]
    logits = (u @ v.T) / temperature                         # [B, B]
    logq = jnp.log(jnp.maximum(batch["item_freq"], 1e-9))    # sampling correction
    logits = logits - logq[None, :]
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def score_candidates_f32(user_vec: jnp.ndarray, cand_vecs: jnp.ndarray) -> jnp.ndarray:
    """Exact retrieval scoring: [B, D] x [N, D] -> [B, N] (baseline path)."""
    return jnp.einsum("bd,nd->bn", user_vec, cand_vecs,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# FM (Rendle, ICDM'10): O(nk) sum-square pairwise interactions.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_sizes: Tuple[int, ...] = tuple([1 << 18] * 39)
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def fm_init(cfg: FMConfig, key):
    k_v, k_w = jax.random.split(key)
    dtype = cfg.jnp_dtype
    return {
        "v": [embedding_init(jax.random.fold_in(k_v, i), s, cfg.embed_dim, dtype)
              for i, s in enumerate(cfg.vocab_sizes)],
        "w": [embedding_init(jax.random.fold_in(k_w, i), s, 1, dtype)
              for i, s in enumerate(cfg.vocab_sizes)],
        "b": jnp.zeros((), dtype),
    }


def fm_forward(params, cfg: FMConfig, sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """sparse_ids [B, 39] -> logits [B].  Pairwise term via the sum-square
    trick: sum_{i<j} <v_i, v_j> = 1/2 [ (sum v_i)^2 - sum v_i^2 ]."""
    vs = jnp.stack([jnp.take(t, sparse_ids[:, i], axis=0)
                    for i, t in enumerate(params["v"])], axis=1)   # [B, F, K]
    lin = sum(jnp.take(t, sparse_ids[:, i], axis=0)[:, 0]
              for i, t in enumerate(params["w"]))                  # [B]
    s = jnp.sum(vs, axis=1)                                        # [B, K]
    pair = 0.5 * jnp.sum(s * s - jnp.sum(vs * vs, axis=1), axis=-1)
    return params["b"] + lin + pair


def fm_loss(params, cfg: FMConfig, batch) -> jnp.ndarray:
    return bce_loss(fm_forward(params, cfg, batch["sparse"]), batch["label"])

"""GIN (Graph Isomorphism Network, arXiv:1810.00826) — segment_sum message passing.

JAX has no CSR/CSC sparse; message passing is implemented over an explicit
edge index (src, dst) with ``jax.ops.segment_sum`` — gather source features,
scatter-add into destinations.  This IS the system's SpMM layer (taxonomy
§GNN), not a stub.

Modes:
  * full-graph node classification (cora-like / ogbn-products-like shapes);
  * sampled minibatch (GraphSAGE-style fanout sampling; the sampler lives in
    repro.data.graphs) — aggregation depth equals len(fanout);
  * batched small graphs with sum-readout graph classification (molecule).

Config (assigned): n_layers=5, d_hidden=64, sum aggregator, learnable eps.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import mlp, mlp_init


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    readout: str = "node"          # "node" | "graph"
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def init_params(cfg: GINConfig, key):
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_feat if i == 0 else cfg.d_hidden
        layers.append({
            "mlp": mlp_init(keys[i], (d_in, cfg.d_hidden, cfg.d_hidden),
                            dtype=cfg.jnp_dtype),
            "eps": jnp.zeros((), cfg.jnp_dtype),       # learnable (GIN-eps)
        })
    return {"layers": layers,
            "head": mlp_init(keys[-1], (cfg.d_hidden, cfg.n_classes),
                             dtype=cfg.jnp_dtype)}


def gin_layer(lp, x: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
              n_nodes: int) -> jnp.ndarray:
    """h'_i = MLP((1+eps) h_i + sum_{j in N(i)} h_j)  via gather + segment_sum."""
    msgs = jnp.take(x, src, axis=0)                           # [E, D] gather
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    h = (1.0 + lp["eps"]) * x + agg
    return mlp(lp["mlp"], h, act=jax.nn.relu, final_act=jax.nn.relu)


def forward_full(params, cfg: GINConfig, x: jnp.ndarray,
                 edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                 graph_ids: Optional[jnp.ndarray] = None,
                 n_graphs: int = 1) -> jnp.ndarray:
    """Full-graph forward.  x [N, F]; edges as index arrays.

    Returns node logits [N, C] (readout="node") or graph logits [G, C].
    """
    n = x.shape[0]
    for lp in params["layers"]:
        x = gin_layer(lp, x, edge_src, edge_dst, n)
    if cfg.readout == "graph":
        assert graph_ids is not None
        pooled = jax.ops.segment_sum(x, graph_ids, num_segments=n_graphs)
        return mlp(params["head"], pooled)
    return mlp(params["head"], x)


def forward_sampled(params, cfg: GINConfig, feats: jnp.ndarray,
                    blocks: Tuple[Tuple[jnp.ndarray, jnp.ndarray, int], ...]) -> jnp.ndarray:
    """Minibatch forward over fanout-sampled blocks (DGL-style nested frontiers).

    ``feats`` are input features of the OUTERMOST frontier.  Frontiers nest:
    the first ``n_dst`` rows of each frontier are the next (smaller) frontier,
    with the seed nodes first.  ``blocks[l] = (src, dst, n_dst)``: block l's
    edges index into the current frontier (src) and the child frontier (dst).
    Aggregation depth = len(blocks) (the assigned fanout 15-10 gives 2 hops;
    DESIGN.md §Arch-applicability notes the reduced depth for sampled mode).
    """
    h = jnp.asarray(feats)
    for l, (src, dst, n_dst) in enumerate(blocks):
        layer = params["layers"][l]
        msgs = jnp.take(h, src, axis=0)
        agg = jax.ops.segment_sum(msgs, dst, num_segments=n_dst)
        hh = (1.0 + layer["eps"]) * h[:n_dst] + agg
        h = mlp(layer["mlp"], hh, act=jax.nn.relu, final_act=jax.nn.relu)
    return mlp(params["head"], h)


def nll_loss(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.mean(ll)

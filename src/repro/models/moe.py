"""Mixture-of-Experts FFN (DeepSeek-V3 / OLMoE style) — TPU-idiomatic dispatch.

Routing:
  * ``softmax`` (OLMoE): top-k over softmax probs, renormalized.
  * ``sigmoid`` (DeepSeek-V3 aux-loss-free): top-k over sigmoid scores plus a
    per-expert bias buffer (updated out-of-band, not by gradient); combine
    weights are the normalized *unbiased* scores.

Dispatch is sort-based with a static per-expert capacity: tokens are ranked
within their expert by a stable sort of expert ids, scattered into an
[E, C, D] buffer (NO [T, E, C] one-hot einsum — that intermediate is what
blows up memory in naive GShard dispatch), processed by a batched expert
einsum, and combined by gather + weighted scatter-add.  Compiled FLOPs are
within capacity_factor of the active-expert ideal, which keeps the roofline
table honest for MoE cells.

Sharding: expert weight tensors are laid out [E, D, F]; the dry-run shards E
over the 'model' mesh axis (expert parallelism) or F (tensor parallelism)
per config — see repro.dist.sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, swiglu, swiglu_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    router: str = "softmax"          # "softmax" | "sigmoid" (aux-free)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # leading dense-FFN layers (DeepSeek-V3: 3)
    router_aux_weight: float = 0.01  # load-balance aux loss (softmax router)
    dp_axes: Optional[Tuple[str, ...]] = None  # dispatch-buffer batch sharding
    ep_axis: Optional[str] = None              # expert-parallel mesh axis


def moe_init(key, d_model: int, mcfg: MoEConfig, dtype=jnp.float32):
    k_r, k_e, k_s = jax.random.split(key, 3)
    e, f = mcfg.n_experts, mcfg.d_ff_expert
    scale = 1.0 / np.sqrt(d_model)
    p = {
        "router": dense_init(k_r, d_model, e, dtype=jnp.float32),
        "router_bias": jnp.zeros((e,), jnp.float32),   # aux-free bias buffer
        "w_gate": (jax.random.normal(k_e, (e, d_model, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(jax.random.fold_in(k_e, 1), (e, d_model, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(jax.random.fold_in(k_e, 2), (e, f, d_model)) * (1.0 / np.sqrt(f))).astype(dtype),
    }
    if mcfg.n_shared:
        p["shared"] = swiglu_init(k_s, d_model, mcfg.d_ff_expert * mcfg.n_shared, dtype=dtype)
    return p


def route(
    x: jnp.ndarray,               # [T, D]
    p,
    mcfg: MoEConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (top_idx [T,k] i32, weights [T,k] f32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"]["w"])
    if mcfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel_scores = scores + p["router_bias"][None, :]       # bias only selects
        _, top_idx = jax.lax.top_k(sel_scores, mcfg.top_k)
        picked = jnp.take_along_axis(scores, top_idx, axis=1)
        weights = picked / jnp.maximum(picked.sum(axis=1, keepdims=True), 1e-9)
        aux = jnp.float32(0.0)                                # aux-loss-free
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        _, top_idx = jax.lax.top_k(probs, mcfg.top_k)
        picked = jnp.take_along_axis(probs, top_idx, axis=1)
        weights = picked / jnp.maximum(picked.sum(axis=1, keepdims=True), 1e-9)
        # Switch-style load-balance loss: E * sum_e f_e * p_e.
        t = x.shape[0]
        e = mcfg.n_experts
        counts = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
        f_e = counts / jnp.float32(t * mcfg.top_k)
        p_e = probs.mean(axis=0)
        aux = mcfg.router_aux_weight * e * jnp.sum(f_e * p_e)
    return top_idx, weights, aux


def moe_ffn(p, x: jnp.ndarray, mcfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux_loss).

    GROUP-WISE sort-based dispatch: each batch row (sequence) slots its own
    tokens, so under SPMD the argsort/scatter stay local to the data shard
    (no global million-token sort, no cross-shard dispatch traffic) and the
    [B, E, C, D] buffer shards over both the data (B) and model (E) axes.
    Per-group capacity C = ceil(S * top_k / E * capacity_factor).
    """
    b, s, d = x.shape
    e, k = mcfg.n_experts, mcfg.top_k
    cap = max(1, int(np.ceil(s * k / e * mcfg.capacity_factor)))

    top_idx, weights, aux = route(x.reshape(b * s, d), p, mcfg)
    top_idx = top_idx.reshape(b, s * k)                            # [B, S*k]
    weights = weights.reshape(b, s * k)

    # --- Per-group slotting (deterministic: stable argsort per row). ---
    order = jnp.argsort(top_idx, axis=1, stable=True)              # [B, S*k]
    sorted_e = jnp.take_along_axis(top_idx, order, axis=1)
    counts = jax.vmap(lambda te: jnp.zeros((e,), jnp.int32).at[te].add(1))(top_idx)
    starts = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    ranks = jnp.arange(s * k, dtype=jnp.int32)[None, :]
    slot = ranks - jnp.take_along_axis(starts, sorted_e, axis=1)   # rank in expert
    keep = slot < cap
    token_of = order // k                                          # [B, S*k]

    # --- Dispatch: per-group scatter into [B, E, C, D]. ---
    gathered_x = jnp.take_along_axis(
        x, token_of[..., None], axis=1)                            # [B, S*k, D]
    gathered_x = jnp.where(keep[..., None], gathered_x, 0)

    def scatter_group(sorted_e_g, slot_g, vals_g):
        buf = jnp.zeros((e, cap, d), x.dtype)
        return buf.at[sorted_e_g, jnp.minimum(slot_g, cap - 1)].add(vals_g)

    xd = jax.vmap(scatter_group)(sorted_e, slot, gathered_x)       # [B, E, C, D]
    if mcfg.dp_axes:
        from .layers import wsc
        xd = wsc(xd, mcfg.dp_axes, mcfg.ep_axis, None, None)

    # --- Expert compute (batched einsum; gated SwiGLU). ---
    from .layers import _acc
    acc = _acc(x.dtype)
    gate = jnp.einsum("gecd,edf->gecf", xd, p["w_gate"], preferred_element_type=acc)
    up = jnp.einsum("gecd,edf->gecf", xd, p["w_up"], preferred_element_type=acc)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"], preferred_element_type=acc)

    # --- Combine: gather back per group, weighted scatter-add over tokens. ---
    def combine_group(y_g, sorted_e_g, slot_g, keep_g, w_g, tok_g):
        vals = y_g[sorted_e_g, jnp.minimum(slot_g, cap - 1)]       # [S*k, D]
        vals = vals * jnp.where(keep_g, w_g, 0.0)[:, None]
        return jnp.zeros((s, d), jnp.float32).at[tok_g].add(vals)

    w_sorted = jnp.take_along_axis(weights, order, axis=1)
    out = jax.vmap(combine_group)(y, sorted_e, slot, keep, w_sorted, token_of)

    if mcfg.n_shared:
        out = out + swiglu(p["shared"], x.reshape(b * s, d)).reshape(b, s, d).astype(jnp.float32)
    return out.astype(x.dtype), aux

"""Shared neural-net layers (pure functions over param pytrees).

Conventions: params are nested dicts of jnp arrays; init functions take a
jax.random key and return the pytree; forward functions are pure.  All matmuls
accumulate in f32 (`preferred_element_type`) regardless of param dtype.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


def wsc(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint against the ambient mesh (no-op spec-free).

    Pinning activation layouts at layer boundaries is what makes XLA's SPMD
    partitioner implement FSDP as per-layer weight all-gathers instead of
    contracting-dim partial sums all-reduced over the data axis (measured:
    9.2x FLOP inflation and ~0.5 TB/step of spurious all-reduce without it).
    """
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


# Matmul output dtype policy: None -> f32 accumulation materialized (safe
# default); a dtype -> matmul outputs stay in that dtype (the MXU still
# accumulates f32 internally; this halves HLO bytes-accessed by not
# round-tripping f32 intermediates).  Set inside traced step functions via
# save/restore (python trace-time side effect).
_MATMUL_OUT = [None]


def push_matmul_out(dtype):
    prev = _MATMUL_OUT[0]
    _MATMUL_OUT[0] = dtype
    return prev


def pop_matmul_out(prev):
    _MATMUL_OUT[0] = prev


def _acc(x_dtype):
    out = _MATMUL_OUT[0]
    if out is not None and x_dtype == out:
        return out
    return jnp.float32


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...i,io->...o", x, p["w"], preferred_element_type=_acc(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y.astype(x.dtype)


def mlp_init(key, dims: Tuple[int, ...], *, bias: bool = True, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
            for i, k in enumerate(keys)]


def mlp(params, x: jnp.ndarray, *, act=jax.nn.relu, final_act=None) -> jnp.ndarray:
    for i, p in enumerate(params):
        x = dense(p, x)
        if i < len(params) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [...,] -> (sin, cos) of shape [..., head_dim/2], f32."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x [..., S, H, dh]; sin/cos [..., S, dh/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]  # add head axis
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention core (shared by prefill/train; decode lives in serve/kvcache).
# ---------------------------------------------------------------------------

def attention_scores_mask(
    q_pos: jnp.ndarray,          # [Sq] query positions
    k_pos: jnp.ndarray,          # [Sk] key positions
    window: jnp.ndarray | int,   # 0 => full causal; w>0 => sliding window
) -> jnp.ndarray:
    """Boolean [Sq, Sk] mask: causal, optionally windowed.  `window` may be a
    traced scalar (per-layer pattern inside a scan)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    w = jnp.asarray(window)
    in_window = (q_pos[:, None] - k_pos[None, :]) < jnp.where(w > 0, w, jnp.int32(2**30))
    return causal & in_window


def _gqa_core(q, k, v, mask, scale, attn_softcap, logits_spec):
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    if logits_spec is not None:
        logits = wsc(logits, *logits_spec)                 # [B, KV, G, Sq, Skv]
    logits = logits * scale
    if attn_softcap > 0:
        logits = softcap(logits, attn_softcap)
    if mask.ndim == 2:
        m = mask[None, None, None, :, :]
    else:
        m = mask[:, None, None, :, :]
    logits = jnp.where(m, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def gqa_attention(
    q: jnp.ndarray,              # [B, Sq, H, dh]
    k: jnp.ndarray,              # [B, Sk, KV, dh]
    v: jnp.ndarray,              # [B, Sk, KV, dh]
    mask: jnp.ndarray,           # [Sq, Sk] or [B, Sq, Sk] bool
    *,
    scale: float,
    attn_softcap: float = 0.0,
    logits_spec=None,            # sharding for [B, KV, G, Sq, Skv] logits
    q_chunks: int = 1,
) -> jnp.ndarray:
    """Grouped-query attention; returns [B, Sq, H, dh].  Softmax in f32.

    q_chunks > 1 runs a python-unrolled loop over query blocks with per-block
    remat: peak logits memory drops by q_chunks (vs the naive [B,H,Sq,Skv]
    materialization) while keeping FLOP accounting exact in the compiled HLO
    (a kv-block scan would hide trip-count FLOPs — see TransformerConfig).
    logits_spec shards the score tile: KV heads over 'model' when divisible,
    else the key-sequence axis.
    """
    sq = q.shape[1]
    if q_chunks <= 1 or sq % q_chunks != 0 or sq == 1:
        return _gqa_core(q, k, v, mask, scale, attn_softcap, logits_spec)
    core = jax.checkpoint(
        lambda qi, mi: _gqa_core(qi, k, v, mi, scale, attn_softcap, logits_spec))
    qc = sq // q_chunks
    outs = []
    for i in range(q_chunks):
        mi = mask[..., i * qc:(i + 1) * qc, :]
        outs.append(core(q[:, i * qc:(i + 1) * qc], mi))
    return jnp.concatenate(outs, axis=1)


def swiglu(p, x: jnp.ndarray) -> jnp.ndarray:
    """Gated FFN: silu(x W_g) * (x W_u) W_d (LLaMA/Gemma/Qwen style)."""
    gate = dense(p["gate"], x)
    up = dense(p["up"], x)
    return dense(p["down"], jax.nn.silu(gate) * up)


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }

"""KV caches for decode, including the MonaVec-quantized variant.

The quantized cache applies the paper's own pipeline to attention KV state —
the adaptation the paper itself points at via TurboQuant (§5.3): a seeded
Hadamard rotation conditions each head vector, a per-vector scale normalizes
it to ~N(0,1) coordinates, and the frozen 4-bit Lloyd-Max table quantizes.
Asymmetric scoring carries over verbatim: the query stays f32/bf16, only the
cached side is 4-bit.  HBM traffic for cache reads drops 4x vs bf16 — decode
is memory-bound, so this moves the dominant roofline term directly.

Scoring math: with z = H D k (unnormalized FWHT), <H D q, H D k> = d' <q, k>,
so logits are computed in rotated space and scaled by 1/d'.  The value path
accumulates in rotated space and applies the inverse rotation ONCE per output
token (linearity), not per cached vector.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lloydmax
from repro.core.quantize import pack_4bit, unpack_4bit
from repro.core.rhdh import fwht, next_pow2, rademacher_signs


@dataclasses.dataclass(frozen=True)
class KVSpec:
    batch: int
    max_len: int
    n_kv_heads: int
    head_dim: int
    quantized: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    seed: int = 0x6B76            # "kv": rotation seed (deterministic)


def init_cache(n_layers: int, spec: KVSpec):
    """Stacked-over-layers cache pytree."""
    b, s, kv, dh = spec.batch, spec.max_len, spec.n_kv_heads, spec.head_dim
    if not spec.quantized:
        # Distinct arrays: k/v must not alias (donation donates buffers).
        return {"k": jnp.zeros((n_layers, b, s, kv, dh), spec.dtype),
                "v": jnp.zeros((n_layers, b, s, kv, dh), spec.dtype)}
    dp = next_pow2(dh)
    return {
        "k_codes": jnp.zeros((n_layers, b, s, kv, dp // 2), jnp.uint8),
        "v_codes": jnp.zeros((n_layers, b, s, kv, dp // 2), jnp.uint8),
        "k_scale": jnp.zeros((n_layers, b, s, kv), jnp.float32),
        "v_scale": jnp.zeros((n_layers, b, s, kv), jnp.float32),
    }


def _rotate(x: jnp.ndarray, spec: KVSpec) -> jnp.ndarray:
    """Unnormalized seeded Hadamard rotation over the head dim."""
    dp = next_pow2(spec.head_dim)
    signs = rademacher_signs(spec.seed, dp)
    if dp != spec.head_dim:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, dp - spec.head_dim)])
    return fwht(x.astype(jnp.float32) * signs)


def _unrotate(z: jnp.ndarray, spec: KVSpec) -> jnp.ndarray:
    dp = z.shape[-1]
    signs = rademacher_signs(spec.seed, dp)
    x = fwht(z) * (1.0 / dp) * signs
    return x[..., : spec.head_dim]


def quantize_kv(x: jnp.ndarray, spec: KVSpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[..., dh] -> (codes [..., d'/2] u8, scale [...]) — MonaVec 4-bit."""
    z = _rotate(x, spec)
    dp = z.shape[-1]
    scale = jnp.linalg.norm(z, axis=-1) / np.sqrt(dp)          # unit-variance coords
    zn = z / jnp.maximum(scale[..., None], 1e-12)
    codes = lloydmax.quantize(zn, 4)
    return pack_4bit(codes), scale


def dequantize_k_rotated(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """codes -> rotated-space f32 keys (for logits; no unrotation needed)."""
    deq = lloydmax.dequantize(unpack_4bit(codes), 4)
    return deq * scale[..., None]


def quant_attention_decode(
    q: jnp.ndarray,                # [B, 1, H, dh] f32/bf16 (full precision)
    k_codes: jnp.ndarray,          # [B, S, KV, d'/2] u8
    v_codes: jnp.ndarray,
    k_scale: jnp.ndarray,          # [B, S, KV]
    v_scale: jnp.ndarray,
    mask: jnp.ndarray,             # [1, S] or [B, 1, S] bool
    spec: KVSpec,
    *,
    scale: float,
    attn_softcap: float = 0.0,
) -> jnp.ndarray:
    """Asymmetric decode attention against the 4-bit cache -> [B, 1, H, dh]."""
    b, _, h, dh = q.shape
    kv = k_codes.shape[2]
    g = h // kv
    dp = next_pow2(dh)

    q_rot = _rotate(q, spec)                                    # [B,1,H,d']
    k_deq = dequantize_k_rotated(k_codes, k_scale)              # [B,S,KV,d']
    qg = q_rot.reshape(b, 1, kv, g, dp)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k_deq,
                        preferred_element_type=jnp.float32)
    logits *= scale / dp                                        # undo d' factor
    if attn_softcap > 0:
        logits = attn_softcap * jnp.tanh(logits / attn_softcap)
    m = mask[:, None, None] if mask.ndim == 2 else mask[:, None, None, 0][..., None, :]
    logits = jnp.where(m[:, :, :, None, :] if m.ndim == 4 else m, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)

    v_deq = dequantize_k_rotated(v_codes, v_scale)              # rotated values
    out_rot = jnp.einsum("bkgst,btkd->bskgd", probs.astype(jnp.float32), v_deq,
                         preferred_element_type=jnp.float32)
    out = _unrotate(out_rot, spec)                              # one unrotation
    return out.reshape(b, 1, h, dh).astype(q.dtype)

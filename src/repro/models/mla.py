"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are produced through low-rank latents:
    q:  x -> W_dq [D, r_q] -> norm -> W_uq [r_q, H*(d_nope + d_rope)]
    kv: x -> W_dkv [D, r_kv + d_rope]; the r_kv latent is normed and expanded
        by W_uk (keys) / W_uv (values); the d_rope slice is a single shared
        rope key across heads.

The decode path uses the ABSORBED formulation: W_uk is folded into the query
(q_nope @ W_uk^T per head) so attention runs directly against the cached
latent c_kv [B, S, r_kv] — the latent IS the KV cache (r_kv + d_rope = 576
floats/token vs H*dh*2 = 32768 for naive MHA at deepseek-v3 scale).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, dense, dense_init, rms_norm, rope_angles, softcap


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    @property
    def cache_dim(self) -> int:
        return self.kv_lora_rank + self.qk_rope_dim


def mla_init(key, d_model: int, n_heads: int, mla: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    h = n_heads
    return {
        "w_dq": dense_init(ks[0], d_model, mla.q_lora_rank, dtype=dtype),
        "q_ln": jnp.zeros((mla.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], mla.q_lora_rank, h * (mla.qk_nope_dim + mla.qk_rope_dim), dtype=dtype),
        "w_dkv": dense_init(ks[2], d_model, mla.kv_lora_rank + mla.qk_rope_dim, dtype=dtype),
        "kv_ln": jnp.zeros((mla.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[3], mla.kv_lora_rank, h * mla.qk_nope_dim, dtype=dtype),
        "w_uv": dense_init(ks[4], mla.kv_lora_rank, h * mla.v_head_dim, dtype=dtype),
        "w_o": dense_init(ks[5], h * mla.v_head_dim, d_model, dtype=dtype),
    }


def _project_q(p, x, n_heads: int, mla: MLAConfig, sin, cos):
    """x [B,S,D] -> (q_nope [B,S,H,dn], q_rope [B,S,H,dr])."""
    b, s, _ = x.shape
    q_lat = rms_norm(dense(p["w_dq"], x), p["q_ln"])
    q = dense(p["w_uq"], q_lat).reshape(b, s, n_heads, mla.qk_nope_dim + mla.qk_rope_dim)
    q_nope, q_rope = q[..., : mla.qk_nope_dim], q[..., mla.qk_nope_dim:]
    return q_nope, apply_rope(q_rope, sin, cos)


def _project_kv_latent(p, x, mla: MLAConfig, sin, cos):
    """x [B,S,D] -> latent cache slice [B,S,r_kv + d_rope] (normed + roped)."""
    lat = dense(p["w_dkv"], x)
    c_kv = rms_norm(lat[..., : mla.kv_lora_rank], p["kv_ln"])
    k_rope = lat[..., mla.kv_lora_rank:][:, :, None, :]          # [B,S,1,dr]
    k_rope = apply_rope(k_rope, sin, cos)[:, :, 0, :]
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def mla_attention(
    p,
    x: jnp.ndarray,               # [B, S, D]
    positions: jnp.ndarray,       # [S]
    mask: jnp.ndarray,            # [S, S] bool
    *,
    n_heads: int,
    mla: MLAConfig,
    rope_theta: float,
    attn_softcap: float = 0.0,
) -> jnp.ndarray:
    """Full-sequence (train/prefill) MLA; absorbed scoring against the latent."""
    b, s, _ = x.shape
    sin, cos = rope_angles(positions, mla.qk_rope_dim, rope_theta)
    q_nope, q_rope = _project_q(p, x, n_heads, mla, sin, cos)
    cache = _project_kv_latent(p, x, mla, sin, cos)              # [B,S,r+dr]
    return mla_attend(p, q_nope, q_rope, cache, mask, n_heads=n_heads, mla=mla,
                      attn_softcap=attn_softcap).astype(x.dtype)


def mla_attend(
    p,
    q_nope: jnp.ndarray,          # [B, Sq, H, dn]
    q_rope: jnp.ndarray,          # [B, Sq, H, dr]
    cache: jnp.ndarray,           # [B, Sk, r_kv + dr] latent
    mask: jnp.ndarray,            # [Sq, Sk] or [B, Sq, Sk]
    *,
    n_heads: int,
    mla: MLAConfig,
    attn_softcap: float = 0.0,
    logits_spec=None,             # sharding for [B, H, Sq, Sk] logits
    q_chunks: int = 1,
) -> jnp.ndarray:
    """Absorbed-matmul attention against the latent cache -> [B, Sq, H*dv].

    q_chunks > 1: python-unrolled query blocks with per-block remat (see
    gqa_attention); the latent cache is shared across blocks."""
    sq = q_nope.shape[1]
    if q_chunks > 1 and sq % q_chunks == 0 and sq > 1:
        core = jax.checkpoint(
            lambda qn, qr, mi: _mla_attend_core(
                p, qn, qr, cache, mi, n_heads=n_heads, mla=mla,
                attn_softcap=attn_softcap, logits_spec=logits_spec))
        qc = sq // q_chunks
        outs = []
        for i in range(q_chunks):
            mi = mask[..., i * qc:(i + 1) * qc, :]
            outs.append(core(q_nope[:, i * qc:(i + 1) * qc],
                             q_rope[:, i * qc:(i + 1) * qc], mi))
        return jnp.concatenate(outs, axis=1)
    return _mla_attend_core(p, q_nope, q_rope, cache, mask, n_heads=n_heads,
                            mla=mla, attn_softcap=attn_softcap,
                            logits_spec=logits_spec)


def _mla_attend_core(
    p, q_nope, q_rope, cache, mask, *, n_heads, mla, attn_softcap=0.0,
    logits_spec=None,
) -> jnp.ndarray:
    r = mla.kv_lora_rank
    c_kv, k_rope = cache[..., :r], cache[..., r:]
    b, sq, h, dn = q_nope.shape

    # Absorb W_uk into the query: q_lat[b,s,h,r] = q_nope . W_uk_head^T
    w_uk = p["w_uk"]["w"].reshape(r, h, dn)                       # [r, H, dn]
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk, preferred_element_type=jnp.float32)

    logits = jnp.einsum("bshr,btr->bhst", q_lat, c_kv, preferred_element_type=jnp.float32)
    logits += jnp.einsum("bshd,btd->bhst", q_rope, k_rope, preferred_element_type=jnp.float32)
    if logits_spec is not None:
        from .layers import wsc
        logits = wsc(logits, *logits_spec)                 # [B, H, Sq, Sk]
    logits *= 1.0 / np.sqrt(mla.qk_nope_dim + mla.qk_rope_dim)
    if attn_softcap > 0:
        logits = softcap(logits, attn_softcap)
    m = mask[None, None] if mask.ndim == 2 else mask[:, None]
    logits = jnp.where(m, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)

    # Attend in latent space, then expand with W_uv (absorbed on the output).
    lat_out = jnp.einsum("bhst,btr->bshr", probs.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)      # [B,Sq,H,r]
    w_uv = p["w_uv"]["w"].reshape(r, h, mla.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", lat_out.astype(c_kv.dtype), w_uv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, sq, h * mla.v_head_dim)
    return dense(p["w_o"], out.astype(c_kv.dtype))

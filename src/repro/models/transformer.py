"""Decoder-only transformer family covering the assigned LM architectures.

One config dataclass spans: dense GQA (llama3, qwen1.5 with QKV bias), local+
global alternating attention with logit softcaps (gemma2), MoE FFN stacks
(olmoe), and MLA attention + shared/routed experts + MTP (deepseek-v3).

Layers are scanned (`jax.lax.scan`) over stacked per-layer params — this keeps
the traced HLO size O(1) in depth, which matters both for multi-pod dry-run
compile times and for XLA's ability to overlap collectives with compute in
the backward pass.  Heterogeneous stacks (DeepSeek's 3 dense + 58 MoE layers)
are expressed as consecutive homogeneous "blocks", each with its own scan.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kvcache import KVSpec, init_cache, quant_attention_decode, quantize_kv
from .layers import (apply_rope, attention_scores_mask, dense, dense_init,
                     gqa_attention, rms_norm, rope_angles, softcap, swiglu,
                     swiglu_init, wsc)
from .mla import MLAConfig, mla_attend, mla_init, _project_kv_latent, _project_q
from .moe import MoEConfig, moe_ffn, moe_init


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False                 # qwen1.5
    attn_softcap: float = 0.0              # gemma2: 50
    final_softcap: float = 0.0             # gemma2: 30
    window: int = 0                        # sliding-window size for local layers
    window_pattern: str = "none"           # "none" | "alternate" (gemma2)
    post_norms: bool = False               # gemma2 post-attn/post-ffn norms
    embed_scale: bool = False              # gemma2 multiplies embeds by sqrt(D)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mtp: bool = False                      # deepseek multi-token prediction
    mtp_weight: float = 0.3
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"             # "full" | "dots" (save matmul
                                           # outputs: ~no fwd recompute in bwd,
                                           # costs activation memory)
    loss_chunk: int = 0                    # >0: chunked CE (never materializes
                                           # the full [B,S,V] f32 logits)
    unroll: bool = False                   # python-unroll the layer stack.
    # Dry-run cells unroll: XLA cost_analysis counts a while-loop body ONCE
    # regardless of trip count, so scanned stacks under-report FLOPs by ~L x.
    # Training keeps scan (compact HLO, better collective overlap).
    dp_axes: Optional[Tuple[str, ...]] = None  # activation batch-sharding axes;
    # set by the distributed cell builder (layers.wsc at layer boundaries).
    act_shard: Optional[str] = None        # ALSO shard layer-boundary
    # activations' model dim (ZeRO-style): scan-carried remat residuals are
    # [B_local, S, D] per layer — at deepseek scale 61 x 940 MB/chip unless
    # d_model is sharded too (costs an all-gather per layer use).
    bf16_matmul: bool = False              # matmul outputs stay bf16 (layers._acc)
    attn_q_chunks: int = 1                 # query-block chunking (memory)
    attn_kv_shard: Optional[str] = None    # shard KV heads (GQA) / heads (MLA)
    attn_seq_shard: Optional[str] = None   # shard a sequence axis of the tile
    attn_seq_axis: str = "kv"              # which axis: "kv" (keys) | "q"
    vocab_shard: Optional[str] = None      # shard [.., V] logits (loss/serve)

    def logits_spec(self):
        """Sharding for attention score tiles (None = unconstrained)."""
        if not (self.dp_axes or self.attn_kv_shard or self.attn_seq_shard):
            return None
        s_sh = self.attn_seq_shard if self.attn_seq_axis == "q" else None
        t_sh = self.attn_seq_shard if self.attn_seq_axis == "kv" else None
        if self.mla:   # [B, H, Sq, Sk]
            return (self.dp_axes, self.attn_kv_shard, s_sh, t_sh)
        return (self.dp_axes, self.attn_kv_shard, None, s_sh, t_sh)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def block_layout(self) -> List[Tuple[str, int]]:
        """Consecutive homogeneous (ffn_kind, n_layers) blocks."""
        if self.moe and self.moe.first_dense_layers:
            return [("dense", self.moe.first_dense_layers),
                    ("moe", self.n_layers - self.moe.first_dense_layers)]
        return [("moe" if self.moe else "dense", self.n_layers)]

    def layer_windows(self) -> np.ndarray:
        """Per-layer sliding-window sizes (0 = full attention)."""
        w = np.zeros(self.n_layers, dtype=np.int32)
        if self.window_pattern == "alternate":
            w[0::2] = self.window                 # even layers local (gemma2)
        elif self.window_pattern == "all":
            w[:] = self.window
        return w

    def param_count(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6*N*D roofline terms)."""
        leaves = jax.eval_shape(lambda k: init_params(self, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(leaves))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        total = self.param_count()
        if not self.moe:
            return total
        m = self.moe
        n_moe_layers = self.n_layers - m.first_dense_layers
        per_expert = 3 * self.d_model * m.d_ff_expert
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: TransformerConfig, dtype):
    if cfg.mla:
        p = {"ln": jnp.zeros((cfg.d_model,), dtype),
             "mla": mla_init(key, cfg.d_model, cfg.n_heads, cfg.mla, dtype=dtype)}
    else:
        ks = jax.random.split(key, 4)
        h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        p = {
            "ln": jnp.zeros((cfg.d_model,), dtype),
            "q": dense_init(ks[0], cfg.d_model, h * dh, bias=cfg.qkv_bias, dtype=dtype),
            "k": dense_init(ks[1], cfg.d_model, kv * dh, bias=cfg.qkv_bias, dtype=dtype),
            "v": dense_init(ks[2], cfg.d_model, kv * dh, bias=cfg.qkv_bias, dtype=dtype),
            "o": dense_init(ks[3], h * dh, cfg.d_model, dtype=dtype),
        }
    if cfg.post_norms:
        p["post_ln"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _layer_init(key, cfg: TransformerConfig, kind: str):
    dtype = cfg.jnp_dtype
    k_attn, k_ffn = jax.random.split(key)
    p = {"attn": _attn_init(k_attn, cfg, dtype),
         "ffn_ln": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "moe":
        p["ffn"] = moe_init(k_ffn, cfg.d_model, cfg.moe, dtype=dtype)
    else:
        p["ffn"] = swiglu_init(k_ffn, cfg.d_model, cfg.d_ff, dtype=dtype)
    if cfg.post_norms:
        p["post_ffn_ln"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(cfg: TransformerConfig, key):
    dtype = cfg.jnp_dtype
    k_embed, k_blocks, k_head, k_mtp = jax.random.split(key, 4)
    params: dict = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model))
                  * (1.0 / np.sqrt(cfg.d_model))).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "blocks": [],
    }
    for bi, (kind, n) in enumerate(cfg.block_layout()):
        keys = jax.random.split(jax.random.fold_in(k_blocks, bi), n)
        params["blocks"].append(jax.vmap(lambda k: _layer_init(k, cfg, kind))(keys))
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dtype=dtype)
    if cfg.mtp:
        km1, km2 = jax.random.split(k_mtp)
        params["mtp"] = {
            "proj": dense_init(km1, 2 * cfg.d_model, cfg.d_model, dtype=dtype),
            "layer": _layer_init(km2, cfg, "dense"),
            "ln": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Blocks (shared by train/prefill/decode).
# ---------------------------------------------------------------------------

def _attn_full(lp, x, positions, window, cfg: TransformerConfig):
    """Full-sequence self-attention sublayer (train / prefill).

    Returns (out, kv_for_cache) where kv is (k, v) [B,S,KV,dh] for GQA or the
    latent [B,S,r+dr] for MLA (prefill cache write-out).
    """
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    mask = attention_scores_mask(positions, positions, window)
    if cfg.mla:
        b, s, _ = h.shape
        sin, cos = rope_angles(positions, cfg.mla.qk_rope_dim, cfg.rope_theta)
        q_nope, q_rope = _project_q(lp["mla"], h, cfg.n_heads, cfg.mla, sin, cos)
        latent = _project_kv_latent(lp["mla"], h, cfg.mla, sin, cos)
        out = mla_attend(lp["mla"], q_nope, q_rope, latent, mask,
                         n_heads=cfg.n_heads, mla=cfg.mla,
                         attn_softcap=cfg.attn_softcap,
                         logits_spec=cfg.logits_spec(),
                         q_chunks=cfg.attn_q_chunks).astype(x.dtype)
        kv = latent
    else:
        b, s, _ = h.shape
        hh, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        sin, cos = rope_angles(positions, dh, cfg.rope_theta)
        q = dense(lp["q"], h).reshape(b, s, hh, dh)
        k = dense(lp["k"], h).reshape(b, s, kvh, dh)
        v = dense(lp["v"], h).reshape(b, s, kvh, dh)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        out = gqa_attention(q, k, v, mask, scale=dh ** -0.5,
                            attn_softcap=cfg.attn_softcap,
                            logits_spec=cfg.logits_spec(),
                            q_chunks=cfg.attn_q_chunks)
        out = dense(lp["o"], out.reshape(b, s, hh * dh))
        kv = (k, v)
    if cfg.post_norms:
        out = rms_norm(out, lp["post_ln"], cfg.norm_eps)
    return out, kv


def _ffn_sublayer(lp, x, kind: str, cfg: TransformerConfig):
    h = rms_norm(x, lp["ffn_ln"], cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_ffn(lp["ffn"], h, cfg.moe)
    else:
        y, aux = swiglu(lp["ffn"], h), jnp.float32(0.0)
    if cfg.post_norms:
        y = rms_norm(y, lp["post_ffn_ln"], cfg.norm_eps)
    return y, aux


def _layer_full(lp, x, positions, window, kind: str, cfg: TransformerConfig):
    a, kv = _attn_full(lp["attn"], x, positions, window, cfg)
    x = x + a
    if cfg.dp_axes:
        x = wsc(x, cfg.dp_axes, None, None)
    f, aux = _ffn_sublayer(lp, x, kind, cfg)
    x = x + f
    if cfg.dp_axes:
        x = wsc(x, cfg.dp_axes, None, cfg.act_shard)
    return x, aux, kv


# ---------------------------------------------------------------------------
# Train / prefill forward.
# ---------------------------------------------------------------------------

def forward(params, cfg: TransformerConfig, tokens: jnp.ndarray,
            *, collect_cache: bool = False, skip_head: bool = False):
    """tokens [B, S] -> (logits [B,S,V] f32 | None, h_final, aux, cache|None)."""
    from .layers import pop_matmul_out, push_matmul_out
    _prev = push_matmul_out(cfg.jnp_dtype if cfg.bf16_matmul else None)
    try:
        return _forward_inner(params, cfg, tokens, collect_cache=collect_cache,
                              skip_head=skip_head)
    finally:
        pop_matmul_out(_prev)


def _forward_inner(params, cfg: TransformerConfig, tokens: jnp.ndarray,
                   *, collect_cache: bool = False, skip_head: bool = False):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.dp_axes:
        x = wsc(x, cfg.dp_axes, None, None)
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows())

    aux_total = jnp.float32(0.0)
    caches = []
    offset = 0
    for (kind, n), bp in zip(cfg.block_layout(), params["blocks"]):
        w_block = jax.lax.dynamic_slice_in_dim(windows, offset, n)
        offset += n

        def layer_fn(carry, inp, _kind=kind):
            lp, w = inp
            y, aux, kv = _layer_full(lp, carry, positions, w, _kind, cfg)
            ys = kv if collect_cache else None
            return y, (aux, ys)

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            f = jax.checkpoint(layer_fn, policy=policy)
        else:
            f = layer_fn
        if cfg.unroll:
            kv_list = []
            for i in range(n):
                lp_i = jax.tree.map(lambda a: a[i], bp)
                x, (aux_i, kv_i) = f(x, (lp_i, w_block[i]))
                aux_total = aux_total + aux_i
                if collect_cache:
                    kv_list.append(kv_i)
            if collect_cache:
                kvs = jax.tree.map(lambda *ls: jnp.stack(ls), *kv_list)
                caches.append(kvs)
        else:
            x, (auxs, kvs) = jax.lax.scan(f, x, (bp, w_block))
            aux_total = aux_total + jnp.sum(auxs)
            if collect_cache:
                caches.append(kvs)

    h_final = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = None if skip_head else _lm_head(params, cfg, h_final)
    return logits, h_final, aux_total, (caches if collect_cache else None)


def _lm_head(params, cfg: TransformerConfig, h):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["lm_head"], h).astype(jnp.float32)
    if cfg.vocab_shard or cfg.dp_axes:
        # Keep logits vocab-sharded: at 128k-256k vocabs an all-gathered
        # [B, chunk, V] f32 buffer is the single biggest allocation in the
        # whole train step (measured 93 GiB/device unsharded on qwen).
        logits = wsc(logits, cfg.dp_axes, None, cfg.vocab_shard)
    if cfg.final_softcap > 0:
        logits = softcap(logits, cfg.final_softcap)
    return logits


def _xent(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy, f32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _xent_from_hidden(params, cfg: TransformerConfig, h: jnp.ndarray,
                      targets: jnp.ndarray) -> jnp.ndarray:
    """CE from final hidden states.  With cfg.loss_chunk > 0 the [B,S,V] f32
    logits are never materialized: a remat'd scan recomputes each sequence
    chunk's logits in both fwd and bwd (peak activation B*chunk*V instead of
    B*S*V — the difference between fitting and OOM at 128k-256k vocabs)."""
    s = h.shape[1]
    chunk = cfg.loss_chunk
    if chunk <= 0 or s <= chunk:
        return _xent(_lm_head(params, cfg, h), targets)

    n_chunks = s // chunk
    main = n_chunks * chunk
    h_c = h[:, :main].reshape(h.shape[0], n_chunks, chunk, -1).transpose(1, 0, 2, 3)
    t_c = targets[:, :main].reshape(targets.shape[0], n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, xt):
        hc, tc = xt
        return carry + _xent(_lm_head(params, cfg, hc), tc) * chunk, None

    if cfg.unroll:
        total = jnp.float32(0.0)
        for i in range(n_chunks):
            total, _ = chunk_loss(total, (h_c[i], t_c[i]))
    else:
        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (h_c, t_c))
    if main < s:  # remainder chunk (e.g. MTP's S-2 tail)
        total = total + _xent(_lm_head(params, cfg, h[:, main:]),
                              targets[:, main:]) * (s - main)
    return total / s


def lm_loss(params, cfg: TransformerConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Causal LM loss (+ MoE aux, + MTP head for deepseek)."""
    use_chunked = cfg.loss_chunk > 0
    if use_chunked:
        # Skip the head inside forward(); compute CE chunkwise from hiddens.
        _, h_final, aux, _ = forward(params, cfg, tokens, skip_head=True)
        loss = _xent_from_hidden(params, cfg, h_final[:, :-1], tokens[:, 1:]) + aux
    else:
        logits, h_final, aux, _ = forward(params, cfg, tokens)
        loss = _xent(logits[:, :-1], tokens[:, 1:]) + aux
    if cfg.mtp:
        # Predict token t+2 from (h_t, embed(token_{t+1})) through one extra
        # layer sharing embeddings and the LM head (DeepSeek-V3 MTP, depth 1).
        emb_next = jnp.take(params["embed"], tokens[:, 1:-1], axis=0)
        h_in = jnp.concatenate([h_final[:, :-2], emb_next], axis=-1)
        h = dense(params["mtp"]["proj"], h_in)
        s = h.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)
        h, _, _ = _layer_full(params["mtp"]["layer"], h, pos, 0, "dense", cfg)
        h = rms_norm(h, params["mtp"]["ln"], cfg.norm_eps)
        if use_chunked:
            mtp_xent = _xent_from_hidden(params, cfg, h, tokens[:, 2:])
        else:
            mtp_xent = _xent(_lm_head(params, cfg, h), tokens[:, 2:])
        loss = loss + cfg.mtp_weight * mtp_xent
    return loss


# ---------------------------------------------------------------------------
# Decode (one token against a KV cache).
# ---------------------------------------------------------------------------

def kv_spec(cfg: TransformerConfig, batch: int, max_len: int,
            quantized: bool = False) -> KVSpec:
    if cfg.mla:
        # Latent cache: one "head" of cache_dim per token.
        return KVSpec(batch=batch, max_len=max_len, n_kv_heads=1,
                      head_dim=cfg.mla.cache_dim, quantized=quantized,
                      dtype=cfg.jnp_dtype)
    return KVSpec(batch=batch, max_len=max_len, n_kv_heads=cfg.n_kv_heads,
                  head_dim=cfg.head_dim, quantized=quantized,
                  dtype=cfg.jnp_dtype)


def init_decode_cache(cfg: TransformerConfig, batch: int, max_len: int,
                      *, quantized: bool = False):
    spec = kv_spec(cfg, batch, max_len, quantized)
    if cfg.mla:
        return [
            {"latent": jnp.zeros((n, batch, max_len, cfg.mla.cache_dim), cfg.jnp_dtype)}
            for _, n in cfg.block_layout()
        ]
    return [init_cache(n, spec) for _, n in cfg.block_layout()]


def _attn_decode(lp, x, cache_layer, cur_len, window, cfg: TransformerConfig,
                 spec: KVSpec):
    """One-token attention; returns (out, updated cache_layer)."""
    b = x.shape[0]
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    pos = jnp.full((1,), cur_len, jnp.int32)
    kpos = jnp.arange(spec.max_len, dtype=jnp.int32)
    valid = kpos[None, :] <= cur_len                     # [1, S]
    w = jnp.asarray(window)                              # traced per-layer value
    in_w = (cur_len - kpos[None, :]) < jnp.where(w > 0, w, jnp.int32(2**30))
    mask = valid & in_w

    if cfg.mla:
        sin, cos = rope_angles(pos, cfg.mla.qk_rope_dim, cfg.rope_theta)
        q_nope, q_rope = _project_q(lp["mla"], h, cfg.n_heads, cfg.mla, sin, cos)
        new_lat = _project_kv_latent(lp["mla"], h, cfg.mla, sin, cos)  # [B,1,C]
        lat = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["latent"], new_lat.astype(cache_layer["latent"].dtype), cur_len, axis=1)
        out = mla_attend(lp["mla"], q_nope, q_rope, lat, mask,
                         n_heads=cfg.n_heads, mla=cfg.mla,
                         attn_softcap=cfg.attn_softcap,
                         logits_spec=cfg.logits_spec()).astype(x.dtype)
        new_cache = {"latent": lat}
    else:
        hh, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        sin, cos = rope_angles(pos, dh, cfg.rope_theta)
        q = apply_rope(dense(lp["q"], h).reshape(b, 1, hh, dh), sin, cos)
        k = apply_rope(dense(lp["k"], h).reshape(b, 1, kvh, dh), sin, cos)
        v = dense(lp["v"], h).reshape(b, 1, kvh, dh)
        if spec.quantized:
            kc, ks = quantize_kv(k, spec)
            vc, vs = quantize_kv(v, spec)
            new_cache = {
                "k_codes": jax.lax.dynamic_update_slice_in_dim(cache_layer["k_codes"], kc, cur_len, axis=1),
                "v_codes": jax.lax.dynamic_update_slice_in_dim(cache_layer["v_codes"], vc, cur_len, axis=1),
                "k_scale": jax.lax.dynamic_update_slice_in_dim(cache_layer["k_scale"], ks, cur_len, axis=1),
                "v_scale": jax.lax.dynamic_update_slice_in_dim(cache_layer["v_scale"], vs, cur_len, axis=1),
            }
            out = quant_attention_decode(
                q, new_cache["k_codes"], new_cache["v_codes"],
                new_cache["k_scale"], new_cache["v_scale"], mask, spec,
                scale=dh ** -0.5, attn_softcap=cfg.attn_softcap)
        else:
            kf = jax.lax.dynamic_update_slice_in_dim(
                cache_layer["k"], k.astype(cache_layer["k"].dtype), cur_len, axis=1)
            vf = jax.lax.dynamic_update_slice_in_dim(
                cache_layer["v"], v.astype(cache_layer["v"].dtype), cur_len, axis=1)
            out = gqa_attention(q, kf, vf, mask, scale=dh ** -0.5,
                                attn_softcap=cfg.attn_softcap,
                                logits_spec=cfg.logits_spec())
            new_cache = {"k": kf, "v": vf}
        out = dense(lp["o"], out.reshape(b, 1, hh * dh))
    if cfg.post_norms:
        out = rms_norm(out, lp["post_ln"], cfg.norm_eps)
    return out, new_cache


def decode_step(params, cfg: TransformerConfig, cache, tokens: jnp.ndarray,
                cur_len: jnp.ndarray, *, quantized: bool = False):
    """tokens [B, 1] + cache at length cur_len -> (logits [B, V], new cache)."""
    b = tokens.shape[0]
    spec = kv_spec(cfg, b, _cache_len(cache), quantized)
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    windows = jnp.asarray(cfg.layer_windows())

    new_cache = []
    offset = 0
    for (kind, n), bp, cb in zip(cfg.block_layout(), params["blocks"], cache):
        w_block = jax.lax.dynamic_slice_in_dim(windows, offset, n)
        offset += n

        def layer_fn(carry, inp, _kind=kind):
            lp, layer_cache, w = inp
            a, nc = _attn_decode(lp["attn"], carry, layer_cache, cur_len, w, cfg, spec)
            y = carry + a
            f, _ = _ffn_sublayer(lp, y, _kind, cfg)
            out = y + f
            if cfg.dp_axes:
                out = wsc(out, cfg.dp_axes, None, None)
            return out, nc

        if cfg.unroll:
            nc_list = []
            for i in range(n):
                lp_i = jax.tree.map(lambda a: a[i], bp)
                cb_i = jax.tree.map(lambda a: a[i], cb)
                x, nc_i = layer_fn(x, (lp_i, cb_i, w_block[i]))
                nc_list.append(nc_i)
            nc = jax.tree.map(lambda *ls: jnp.stack(ls), *nc_list)
        else:
            x, nc = jax.lax.scan(layer_fn, x, (bp, cb, w_block))
        new_cache.append(nc)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(params, cfg, h)[:, 0]
    return logits, new_cache


def _cache_len(cache) -> int:
    leaf = jax.tree.leaves(cache[0])[0]
    return leaf.shape[2]           # [L, B, S, ...]


def prefill(params, cfg: TransformerConfig, tokens: jnp.ndarray,
            *, last_only: bool = False):
    """Full forward that also returns the per-block KV caches (bf16/latent).

    last_only=True returns only the final position's logits [B, V] — the
    serving-realistic prefill output (avoids the [B,S,V] materialization)."""
    logits, h_final, _, caches = forward(params, cfg, tokens,
                                         collect_cache=True, skip_head=last_only)
    if last_only:
        logits = _lm_head(params, cfg, h_final[:, -1:])[:, 0]
    out = []
    for (kind, n), kv in zip(cfg.block_layout(), caches):
        if cfg.mla:
            out.append({"latent": kv})                       # [L,B,S,C]
        else:
            k, v = kv
            out.append({"k": k, "v": v})                     # [L,B,S,KV,dh]
    return logits, out

from .transformer import TransformerConfig, init_params, forward, lm_loss  # noqa: F401
from .gnn import GINConfig  # noqa: F401
from .recsys import DIENConfig, DLRMConfig, FMConfig, TwoTowerConfig  # noqa: F401

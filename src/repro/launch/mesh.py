"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh over whatever devices exist — smoke tests / CPU examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' extends DP across pods)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def has_pod(mesh) -> bool:
    return "pod" in mesh.axis_names

"""Training launcher: ``python -m repro.launch.train --arch <id> [--steps N]``.

Runs a REDUCED (smoke) config end to end on local devices — the full configs
are exercised via the dry-run (this container is CPU-only).  The same Cell
machinery drives real-mesh launches on TPU fleets.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import repro.configs as C
    from repro.data import synthetic as syn
    from repro.train.checkpoint import CheckpointManager
    from repro.train.loop import train
    from repro.train.optimizer import AdamWConfig

    arch = C.get(args.arch)
    cfg = arch.make_smoke()
    key = jax.random.key(args.seed)

    if arch.family == "lm":
        from repro.models import transformer as tf
        loss_fn = lambda p, b: tf.lm_loss(p, cfg, b["tokens"])
        init_fn = lambda: tf.init_params(cfg, key)
        batch_fn = lambda step: syn.lm_batch(args.seed, step, args.batch,
                                             args.seq_len, cfg.vocab)
    elif arch.family == "gnn":
        from repro.models import gnn as g
        graph = syn.random_graph(args.seed, 500, 2500, cfg.d_feat, cfg.n_classes)
        loss_fn = lambda p, b: g.nll_loss(
            g.forward_full(p, cfg, b["x"], b["src"], b["dst"]), b["labels"])
        init_fn = lambda: g.init_params(cfg, key)
        batch_fn = lambda step: graph
    elif arch.family == "recsys":
        from repro.dist.steps import _RS_INIT, _RS_LOSS
        init = _RS_INIT[args.arch]
        loss = _RS_LOSS[args.arch]
        loss_fn = lambda p, b: loss(p, cfg, b)
        init_fn = lambda: init(cfg, key)
        batch_fn = lambda step: syn.recsys_batch(args.seed, step, args.arch,
                                                 cfg, args.batch)
    else:
        raise SystemExit(f"--arch {args.arch}: use examples/retrieval scripts")

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    res = train(loss_fn=loss_fn, init_params_fn=init_fn, batch_fn=batch_fn,
                n_steps=args.steps, opt_cfg=AdamWConfig(lr=1e-3), ckpt=ckpt)
    first, last = res.losses[0], float(np.mean(res.losses[-5:]))
    print(f"[train] {args.arch}: steps {res.start_step}->{res.end_step} "
          f"loss {first:.4f} -> {last:.4f} stragglers={len(res.straggler_steps)}")


if __name__ == "__main__":
    main()

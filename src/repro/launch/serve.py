"""Retrieval serving launcher: the service layer as a batched offline loop.

The paper ships FastAPI/REST; in this offline runtime the same contract is a
pure function: token -> namespace -> collection -> top-k.  This CLI builds
(or loads) a .mvec index and serves deterministic batched query traffic
through the query-execution engine (DESIGN.md §7): the serving loop holds a
bound handle

    search = reg.searcher(token, "default", k=10)   # == index.searcher(k=10)
    search.warmup(batch_size)      # compile the plan OUTSIDE the timed window
    scores, ids = search(queries)  # every call: plan-cache hit, zero retrace

so each phase runs one untimed warm-up batch (jit trace + compile) before
the measured batches, and reports the engine's plan-cache hits/misses/
retraces alongside QPS — the measured number is serving throughput, not
compile time.

    PYTHONPATH=src python -m repro.launch.serve --n 50000 [--index hnsw]
    PYTHONPATH=src python -m repro.launch.serve --load corpus.mvec
    PYTHONPATH=src python -m repro.launch.serve --n 200000 --shard
    PYTHONPATH=src python -m repro.launch.serve --n 20000 --mutate --compact
    PYTHONPATH=src python -m repro.launch.serve --n 20000 --micro-batch 8
    PYTHONPATH=src python -m repro.launch.serve --n 50000 --index ivf \
        --autotune --recall-target 0.95

--autotune runs the training-free autotuner (DESIGN.md §12) after build or
load: seeded sample queries drawn from the corpus are swept against an exact
full-scan oracle over the SAME quantized segments, and the cheapest knob
rung meeting --recall-target becomes the serving default (every phase report
prints the resolved knobs).  With --save the tuned knobs persist as the
.mvec v11 TUNE block and reload as defaults.

--shard serves the BruteForce scan through repro.dist: the corpus is split
over every local device and each batch runs the shard_map scan + cross-shard
merge (identical results to the single-device path, by construction).

--mutate exercises the segmented lifecycle endpoints (DESIGN.md §6) through
the tenant registry — the offline analogue of the paper's POST /add,
DELETE /ids, POST /compact routes: after the initial query phase it add()s
a delta batch, delete()s a stride of ids, re-serves (scans now cover base +
extra segments with tombstones masked pre-top-k), and with --compact
rewrites the live rows into one segment and serves a final phase.

--micro-batch R splits every batch into R separate requests and serves them
through the engine's MicroBatcher: requests are coalesced per (namespace,
collection, k, where, hybrid?) group and executed as ONE bucketed plan call
— the multi-tenant serving shape, with bit-identical per-request results.

--filter-every N attaches a ``bucket = row % N`` metadata column at build
time and serves an extra phase with ``where=Eq("bucket", 0)`` (selectivity
1/N) through the compiled predicate stage (DESIGN.md §8): the report shows
the filtered phase hitting the SAME plan cache — the predicate mask is a
fused stage, not a separate pass, so repeat filtered batches are zero-
retrace just like unfiltered ones.

Observability (DESIGN.md §9): every phase report is read back out of the
process-wide metrics registry (plan-cache counters, per-stage latency
histograms, per-namespace request counts) rather than ad-hoc counters;

--metrics-json PATH   write the full registry snapshot (counters, gauges,
                      per-stage latency histograms with their deterministic
                      bucket edges) as JSON on exit;
--metrics-prom PATH   the same snapshot in Prometheus text exposition;
--trace-sample N      trace every Nth served batch end to end (plan lookup
                      -> per-stage dispatch -> merge/top-k -> batcher
                      scatter-back) and dump the span trees per phase.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro import engine, obs
from repro.core import Eq, MonaVec, TenantRegistry
from repro.data.synthetic import embedding_corpus, queries_from_corpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--index", default="bruteforce",
                    choices=["bruteforce", "ivf", "hnsw"])
    ap.add_argument("--load", default=None, help="serve an existing .mvec file")
    ap.add_argument("--save", default=None)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--token", default=None, help="tenant token (standalone mode)")
    ap.add_argument("--mutate", action="store_true",
                    help="run the add/delete/compact lifecycle phases after "
                         "the initial query phase (DESIGN.md §6)")
    ap.add_argument("--add-n", type=int, default=None,
                    help="rows to add() in the mutation phase "
                         "(default: 10%% of the corpus)")
    ap.add_argument("--delete-every", type=int, default=17,
                    help="delete() every Nth id in the mutation phase")
    ap.add_argument("--compact", action="store_true",
                    help="compact() after the mutation phase and re-serve")
    ap.add_argument("--shard", action="store_true",
                    help="shard the corpus over all local devices (bruteforce)")
    ap.add_argument("--filter-every", type=int, default=0, metavar="N",
                    help="attach a bucket=row%%N metadata column and serve a "
                         "filtered phase with where=Eq('bucket', 0) — "
                         "selectivity 1/N through the compiled predicate "
                         "stage (DESIGN.md §8)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot (DESIGN.md §9) "
                         "as JSON on exit")
    ap.add_argument("--metrics-prom", default=None, metavar="PATH",
                    help="write the metrics snapshot in Prometheus text "
                         "exposition format on exit")
    ap.add_argument("--trace-sample", type=int, default=0, metavar="N",
                    help="trace every Nth served batch and dump its span "
                         "tree (0 = off)")
    ap.add_argument("--micro-batch", type=int, default=0, metavar="R",
                    help="serve each batch as R coalesced requests through "
                         "the engine MicroBatcher (0 = direct searcher)")
    ap.add_argument("--coarse", default="off", choices=["off", "sign", "crumb"],
                    help="attach a binarized coarse code at build time "
                         "(DESIGN.md §11; persisted as .mvec v10 with --save; "
                         "with --load, derives codes for a pre-v10 file) — "
                         "unlocks --rescore-mult")
    ap.add_argument("--rescore-mult", type=int, default=0, metavar="R",
                    help="serve through the binarized cascade: coarse-scan "
                         "all rows, rescore only the top R*k survivors with "
                         "the 4-bit kernel (0 = full scan; requires --coarse "
                         "or a v10 .mvec)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the training-free autotuner (DESIGN.md §12) "
                         "after build/load: seeded sample queries vs an "
                         "exact oracle pick the cheapest backend knob "
                         "meeting --recall-target; the tuned knobs become "
                         "the serving defaults (persisted with --save as "
                         ".mvec v11)")
    ap.add_argument("--recall-target", type=float, default=0.95,
                    metavar="R", help="autotune recall@k target (default "
                    "0.95; requires --autotune)")
    ap.add_argument("--use-kernel", default="auto", choices=["auto", "on", "off"],
                    help="scoring dispatch: auto = Pallas kernel on TPU / "
                         "pure-jnp elsewhere; on/off force it (all backends)")
    ap.add_argument("--interpret", action="store_true",
                    help="run the Pallas kernel in interpret mode (validation)")
    args = ap.parse_args()
    use_kernel = {"auto": None, "on": True, "off": False}[args.use_kernel]
    interpret = True if args.interpret else None
    if args.interpret and use_kernel is None:
        use_kernel = True   # interpret mode validates the KERNEL body; off-TPU
                            # dispatch would otherwise skip it silently
    if args.interpret and use_kernel is False:
        raise SystemExit("--interpret requires the kernel path "
                         "(drop --use-kernel off)")
    if args.use_kernel == "on" and not args.interpret:
        import jax
        if jax.default_backend() != "tpu":
            # resolve_dispatch will fill interpret=True off-TPU: say so
            # instead of reporting per-grid-cell emulation QPS as kernel QPS.
            print("[serve] WARNING: no TPU backend — forced kernel runs in "
                  "interpret mode (validation speed, not production)")

    if args.shard and not args.load and args.index != "bruteforce":
        # Fail before the (possibly minutes-long) index build, not after.
        raise SystemExit("--shard requires --index bruteforce "
                         "(or a bruteforce .mvec via --load)")
    if args.shard and (use_kernel is not None or interpret is not None):
        # The shard_map scan carries its own dispatch; don't pretend to
        # force a path we would silently ignore.
        raise SystemExit("--use-kernel/--interpret do not apply to --shard")
    if args.shard and args.mutate:
        # ShardedMonaVec is a static row partition; mutate on the unsharded
        # index, compact, then shard the result.
        raise SystemExit("--mutate does not apply to --shard (compact first)")
    if args.coarse != "off" and not args.load and args.index != "bruteforce":
        raise SystemExit("--coarse requires --index bruteforce")
    if args.rescore_mult and args.coarse == "off" and not args.load:
        raise SystemExit("--rescore-mult requires --coarse sign|crumb "
                         "(or a v10 .mvec via --load)")
    if args.rescore_mult and args.micro_batch:
        # MicroBatcher groups by (namespace, collection, k, where); per-
        # request knobs would split its coalescing contract.
        raise SystemExit("--rescore-mult does not apply to --micro-batch")

    if args.load:
        index = MonaVec.load(args.load)
        corpus = None
        print(f"[serve] loaded {args.load}: n={index.backend.enc.n} "
              f"metric={index.backend.enc.metric}")
        if args.filter_every and (index.meta is None or "bucket" not in
                                  getattr(index.meta, "columns", {})):
            raise SystemExit("--filter-every needs a 'bucket' metadata "
                             "column; the loaded .mvec has none (build one "
                             "with --filter-every --save)")
        if args.coarse != "off":
            try:
                index.enable_coarse(args.coarse)   # no-op on a v10 file
            except TypeError as e:
                raise SystemExit(f"--coarse: {e}")
            print(f"[serve] coarse codes attached (kind={args.coarse})")
        if args.rescore_mult and index.backend.enc.ccodes is None:
            raise SystemExit("--rescore-mult: the loaded .mvec carries no "
                             "coarse codes; add --coarse sign|crumb to "
                             "derive them at load time")
    else:
        corpus = embedding_corpus(0, args.n, args.dim)
        kw = {"nlist": 128} if args.index == "ivf" else (
            {"m": 16, "ef_construction": 64} if args.index == "hnsw" else {})
        meta = ({"bucket": np.arange(args.n, dtype=np.int64)
                 % args.filter_every}
                if args.filter_every else None)
        t0 = time.time()
        coarse = None if args.coarse == "off" else args.coarse
        index = MonaVec.build(corpus, metric="cosine", index=args.index,
                              meta=meta, coarse=coarse, **kw)
        print(f"[serve] built {args.index} over {args.n}x{args.dim} "
              f"in {time.time() - t0:.1f}s"
              + (f" (+ bucket metadata column, {args.filter_every} values)"
                 if meta else "")
              + (f" (+ {coarse} coarse codes)" if coarse else ""))

    if args.autotune:
        # Training-free knob selection (DESIGN.md §12): seeded corpus-drawn
        # sample queries vs an exact full-scan oracle over the SAME
        # quantized segments; the chosen knobs ride on index.tuned and
        # become the defaults for every phase below.
        t0 = time.time()
        index.autotune(recall_target=args.recall_target, k=args.k)
        tr = index.tuned
        print(f"[serve] autotune: knobs={tr.knobs or '{} (full scan)'} "
              f"met_target={tr.met_target} "
              f"(recall@{tr.k} >= {tr.recall_target}, "
              f"{tr.n_queries} sample queries, {time.time() - t0:.1f}s)"
              + (f"; boost curve over {len(tr.boost.points)} selectivity "
                 f"breakpoints" if tr.boost is not None else ""))

    if args.save and (not args.load or args.autotune):
        # A loaded index is only re-saved when --autotune gave it new knobs
        # to persist (the v11 TUNE block); --mutate saves again at the end.
        index.save(args.save)
        print(f"[serve] saved {args.save}")

    if args.shard:
        import jax
        try:
            index = index.shard()
        except TypeError as e:
            raise SystemExit(f"--shard: {e}")
        print(f"[serve] sharded {index.n} rows over {jax.device_count()} "
              f"local device(s) (shard_map scan + cross-shard merge)")
        dim = index.enc.dim
    else:
        dim = index.backend.enc.dim

    reg = TenantRegistry()
    ns = reg.put(args.token, "default", index)
    print(f"[serve] namespace={ns!r}")

    batcher = (engine.MicroBatcher(reg, use_kernel=use_kernel,
                                   interpret=interpret)
               if args.micro_batch else None)
    tracer = obs.Tracer(sample_every=args.trace_sample)

    def phase_queries(b: int) -> np.ndarray:
        if corpus is not None:
            return queries_from_corpus(corpus, 100 + b, args.batch_size)
        rng = np.random.RandomState(100 + b)
        return rng.randn(args.batch_size, dim).astype(np.float32)

    def serve_batch(search, q: np.ndarray, where=None) -> None:
        if batcher is not None:
            # Split the batch into R requests and let the engine coalesce
            # them back into one bucketed plan execution per group.
            parts = np.array_split(q, min(args.micro_batch, len(q)))
            tickets = [batcher.submit(args.token, "default", p, k=args.k,
                                      where=where)
                       for p in parts]
            batcher.flush()
            for t in tickets:
                t.result()
        else:
            search(q)

    def run_phase(label: str, where=None) -> None:
        # The serving loop holds ONE bound searcher per phase; mutation
        # phases pick up the index's new segment signature automatically.
        knobs = ({"rescore_mult": args.rescore_mult}
                 if args.rescore_mult else {})
        if args.shard:   # sharded scan has its own shard_map dispatch
            search = reg.get(args.token, "default").searcher(k=args.k,
                                                             where=where,
                                                             **knobs)
        else:
            search = reg.searcher(args.token, "default", k=args.k,
                                  where=where,
                                  use_kernel=use_kernel, interpret=interpret,
                                  **knobs)
        live_idx = reg.get(args.token, "default")
        if hasattr(live_idx, "resolved_knobs"):
            # The exact knobs this phase runs with, after tuned-default
            # resolution and the engine's clamps (DESIGN.md §12) — sharded
            # indexes carry tuned defaults but resolve per call instead.
            resolved = live_idx.resolved_knobs(args.k, **knobs)
            print(f"[serve] {label}: knobs={resolved or '{} (full scan)'}"
                  + (" (tuned)" if getattr(live_idx, "tuned", None) is not None
                     else ""))
        # Untimed warm-up: the first batch of a phase pays jit trace +
        # compile; measured QPS must not include it (at small --batches the
        # old numbers were dominated by compile time).
        serve_batch(search, phase_queries(0), where)
        # The phase report reads the shared metrics registry (DESIGN.md §9):
        # plan-cache counters and batcher coalescing, diffed over the
        # measured window — the same numbers --metrics-json exports.
        before = obs.registry().snapshot()
        total, t0 = 0, time.time()
        for b in range(args.batches):
            q = phase_queries(b)
            with tracer.maybe(f"batch:{label}", phase=label, batch=b,
                              rows=len(q)):
                serve_batch(search, q, where)
            total += len(q)
        dt = time.time() - t0
        d = obs.counter_deltas(obs.registry().snapshot(), before)
        print(f"[serve] {label}: {total} queries in {dt:.2f}s -> "
              f"{total / dt:.0f} QPS "
              f"(deterministic: rerun reproduces identical ids)")
        line = (f"[serve] {label}: plan cache "
                f"hits={obs.counter_total(d, 'plan_cache.hits')} "
                f"misses={obs.counter_total(d, 'plan_cache.misses')} "
                f"retraces={obs.counter_total(d, 'plan_cache.traces')} "
                f"evictions={obs.counter_total(d, 'plan_cache.evictions')} "
                f"(measured window, post-warm-up)")
        if batcher is not None:
            line += (f"; micro-batch: "
                     f"{obs.counter_total(d, 'batcher.requests')} requests "
                     f"-> {obs.counter_total(d, 'batcher.executions')} "
                     f"plan executions")
        print(line)
        for tr in tracer.drain():
            print(f"[trace] sampled span tree ({label}):")
            for ln in tr.render().splitlines():
                print(f"[trace]   {ln}")

    run_phase("static")

    if args.filter_every:
        # Filtered serving phase (DESIGN.md §8): same plan cache, the
        # predicate compiles in as a fused mask stage — the report's
        # retrace count shows the filter costs ONE extra trace total,
        # not one per batch.
        live = reg.get(args.token, "default")
        frac = float(np.mean(live.meta["bucket"].values == 0))
        print(f"[serve] filter: where=Eq('bucket', 0) selects "
              f"~{100.0 * frac:.1f}% of rows")
        run_phase("filtered", where=Eq("bucket", 0))

    if args.mutate:
        # The paper's service-layer mutation routes, as registry calls.
        live = reg.get(args.token, "default")
        add_n = args.add_n if args.add_n is not None else max(1, live.n_total // 10)
        rng = np.random.RandomState(7)
        delta = rng.randn(add_n, dim).astype(np.float32)
        delta_meta = ({"bucket": np.arange(add_n, dtype=np.int64)
                       % args.filter_every}
                      if args.filter_every else None)
        t0 = time.time()
        new_ids = reg.add(args.token, "default", delta, meta=delta_meta)
        print(f"[serve] add: {len(new_ids)} rows quantized into segment "
              f"ordinal {live.mut.next_ordinal - 1} in {time.time() - t0:.2f}s")
        victims = live.ids[::args.delete_every]
        n_del = reg.delete(args.token, "default", victims)
        print(f"[serve] delete: {n_del} rows tombstoned "
              f"(live {live.n_live}/{live.n_total})")
        run_phase("mutated")
        if args.compact:
            t0 = time.time()
            reclaimed = reg.compact(args.token, "default")
            print(f"[serve] compact: reclaimed {reclaimed} rows into one "
                  f"segment in {time.time() - t0:.2f}s")
            run_phase("compacted")
        if args.save:
            live.save(args.save)
            print(f"[serve] saved mutated index to {args.save} "
                  f"(multi-segment layout)" if not live.mut.is_static
                  else f"[serve] saved {args.save}")

    # Final observability export (DESIGN.md §9): the whole run's registry —
    # per-stage latency histograms with their deterministic bucket edges,
    # plan-cache hit/miss/trace/eviction counters, per-namespace request
    # counts, batcher coalescing — as JSON and/or Prometheus text.
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(obs.registry().snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[serve] wrote metrics snapshot to {args.metrics_json}")
    if args.metrics_prom:
        with open(args.metrics_prom, "w") as f:
            f.write(obs.registry().to_prometheus())
        print(f"[serve] wrote Prometheus exposition to {args.metrics_prom}")


if __name__ == "__main__":
    main()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) — the
first two lines below pin 512 placeholder host devices BEFORE any jax import.
Do NOT import this module from tests/benches (they must see 1 device).

Per cell this records: compile success, memory_analysis (bytes per device),
cost_analysis (HLO FLOPs / bytes), and the collective schedule parsed from
the optimized HLO (op kind, result bytes, replica-group size, estimated wire
bytes per device) — the inputs to EXPERIMENTS.md §Roofline.
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

import repro.configs as configs                    # noqa: E402
from repro.dist.steps import build_cell            # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"(pred|[suf]\d+|bf16|f16|c64|c128)\[([\d,]*)\]")


def _array_bytes(text: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo: str, default_group: int):
    """Sum collective result bytes + estimate wire bytes/device from HLO."""
    stats = {}
    details = []
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rest = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", rest):
                kind = c
                break
        if kind is None:
            continue
        # Result type is everything before the op name.
        result_part = rest.split(kind)[0]
        rbytes = _array_bytes(result_part)
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm2 = re.search(r"replica_groups=\[\d+,(\d+)\]", rest)
            g = int(gm2.group(1)) if gm2 else default_group
        g = max(g, 1)
        if kind == "all-gather":
            wire = rbytes * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2.0 * rbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = rbytes * (g - 1)          # result is 1/g of the operand
        elif kind == "all-to-all":
            wire = rbytes * (g - 1) / g
        else:                                 # collective-permute
            wire = float(rbytes)
        s = stats.setdefault(kind, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        s["count"] += 1
        s["result_bytes"] += rbytes
        s["wire_bytes"] += wire
        details.append({"kind": kind, "bytes": rbytes, "group": g, "wire": wire})
    details.sort(key=lambda d: -d["wire"])
    return stats, details[:20]


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, variant: str,
             out_dir: Path, hlo_dir=None) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    arch = configs.get(arch_id)
    shape = next(s for s in arch.shapes if s.name == shape_name)
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "ok": False,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
    try:
        cell = build_cell(arch, shape, mesh, variant)
        rec["step"] = cell.step_name
        rec["model_flops"] = cell.model_flops
        jitted = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        with mesh:
            lowered = jitted.lower(*cell.args)
            t_low = time.time()
            compiled = lowered.compile()
            t_comp = time.time()
        rec["lower_s"] = round(t_low - t0, 1)
        rec["compile_s"] = round(t_comp - t_low, 1)

        mem = compiled.memory_analysis()
        for field in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            rec[field] = int(getattr(mem, field, 0) or 0)

        # Memory twin: the production (scan) form — XLA:CPU's scheduler keeps
        # far more live in the unrolled FLOP-accounting form than a real
        # TPU job (which runs the scan) would; see Cell.fn_mem.
        if cell.fn_mem is not None:
            jit_mem = jax.jit(cell.fn_mem, out_shardings=cell.out_shardings_mem,
                              donate_argnums=cell.donate_mem)
            with mesh:
                comp_mem = jit_mem.lower(*cell.args_mem).compile()
            mm = comp_mem.memory_analysis()
            rec["temp_size_unrolled"] = rec["temp_size_in_bytes"]
            rec["temp_size_in_bytes"] = int(mm.temp_size_in_bytes or 0)
            rec["argument_size_in_bytes"] = int(mm.argument_size_in_bytes or 0)
            rec["output_size_in_bytes"] = int(mm.output_size_in_bytes or 0)
            del comp_mem

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_transcendentals"] = float(cost.get("transcendentals", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))

        hlo = compiled.as_text()
        rec["hlo_len"] = len(hlo)
        stats, top = parse_collectives(hlo, default_group=rec["n_devices"])
        rec["collectives"] = stats
        rec["top_collectives"] = top
        rec["collective_wire_bytes"] = sum(s["wire_bytes"] for s in stats.values())
        if hlo_dir is not None:
            (hlo_dir / f"{arch_id}__{shape_name}__{mesh_kind}__{variant}.hlo.txt").write_text(hlo)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)

    out_dir.mkdir(parents=True, exist_ok=True)
    out = out_dir / f"{arch_id}__{shape_name}__{mesh_kind}__{variant}.json"
    out.write_text(json.dumps(rec, indent=1))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')[:120]})"
    print(f"[dryrun] {arch_id} x {shape_name} x {mesh_kind} x {variant}: "
          f"{status} in {rec['total_s']}s", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true", help="run every registered cell")
    ap.add_argument("--include-extra", action="store_true",
                    help="include the monavec-scan supplementary cells")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    hlo_dir = Path(args.out) / "hlo" if args.save_hlo else None
    if hlo_dir:
        hlo_dir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    # Archs whose full-depth unrolled FLOP-accounting compile is intractable
    # on this 1-core container: compile the production scan form (the required
    # artifact + memory) plus two reduced-depth unrolled probes; per-layer
    # costs extrapolate linearly to full depth (benchmarks.roofline).
    heavy = {
        "deepseek-v3-671b": ["scan", "probe5", "probe9"],
        "gemma2-2b": ["scan", "probe4", "probe8"],   # windows alternate: even probes
        "llama3.2-3b": ["scan", "probe5", "probe9"],
    }

    if args.all:
        todo = []
        for mk in meshes:               # finish single-pod table first
            for arch, shape in configs.cells():
                if arch.family == "retrieval" and not args.include_extra:
                    continue
                variants = heavy.get(arch.arch_id, [args.variant]) \
                    if arch.family == "lm" else [args.variant]
                for v in variants:
                    todo.append((arch.arch_id, shape.name, mk, v))
        print(f"[dryrun] {len(todo)} cells queued", flush=True)
        n_fail = 0
        for arch_id, shape_name, mk, v in todo:
            f = out_dir / f"{arch_id}__{shape_name}__{mk}__{v}.json"
            if args.skip_existing and f.exists() and json.loads(f.read_text()).get("ok"):
                print(f"[dryrun] skip existing {f.name}", flush=True)
                continue
            rec = run_cell(arch_id, shape_name, mk, v, out_dir, hlo_dir)
            n_fail += 0 if rec["ok"] else 1
        print(f"[dryrun] done; {n_fail} failures", flush=True)
        raise SystemExit(1 if n_fail else 0)

    assert args.arch and args.shape, "--arch/--shape required without --all"
    recs = [run_cell(args.arch, args.shape, mk, args.variant, out_dir, hlo_dir)
            for mk in meshes]
    raise SystemExit(0 if all(r["ok"] for r in recs) else 1)


if __name__ == "__main__":
    main()

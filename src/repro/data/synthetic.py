"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step) — counter-based, stateless.
This is the property that makes checkpoint/restart EXACT: a restored job at
step k regenerates precisely the batches a non-failed run would have seen
(no stateful iterator to replay), and elastic re-sharding of the data axis
is a pure re-slice of the same global batch.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def _rng(seed: int, step: int, stream: int = 0) -> np.random.Generator:
    # Philox: counter-based, cheap to construct per (seed, step, stream).
    return np.random.Generator(np.random.Philox(key=seed, counter=[step, stream, 0, 0]))


# -- token streams (LM) ------------------------------------------------------

def lm_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int):
    """Zipfian token stream with per-sequence drift (non-degenerate loss)."""
    g = _rng(seed, step, 1)
    z = g.zipf(1.3, size=(batch, seq_len)).astype(np.int64)
    return {"tokens": (z % vocab).astype(np.int32)}


# -- embedding corpora (MonaVec) ---------------------------------------------

def embedding_corpus(seed: int, n: int, dim: int, *, n_clusters: int = 64,
                     noise: float = 0.25) -> np.ndarray:
    """Clustered unit vectors — semantic-embedding-like geometry (AG News
    surrogate: clusters = topics).  Per-document noise scales are drawn from
    U(0.3, 1.5)x so within-cluster similarities are GRADED (real embedding
    neighbourhoods are not iid near-ties).  Deterministic in (seed, n, dim)."""
    g = _rng(seed, 0, 2)
    centers = g.standard_normal((n_clusters, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = g.integers(0, n_clusters, size=n)
    scale = (noise * (0.3 + 1.2 * g.random(n))).astype(np.float32)
    x = centers[assign] + scale[:, None] * g.standard_normal((n, dim)).astype(np.float32)
    return x.astype(np.float32)


def pixel_corpus(seed: int, n: int, dim: int) -> np.ndarray:
    """Raw-magnitude, non-Gaussian data (fashion-mnist surrogate): sparse
    positive 'pixels' with block structure — the setting where fit() matters."""
    g = _rng(seed, 0, 3)
    base = g.random((n, dim)).astype(np.float32) * 255.0
    mask = g.random((n, dim)) < 0.55                  # many near-zero pixels
    out = np.where(mask, 0.0, base)
    prototypes = g.random((10, dim)).astype(np.float32) * 128.0
    out += prototypes[g.integers(0, 10, size=n)]
    return out.astype(np.float32)


def queries_from_corpus(corpus: np.ndarray, seed: int, n_q: int,
                        noise: float = 0.15) -> np.ndarray:
    g = _rng(seed, 1, 4)
    idx = g.integers(0, len(corpus), size=n_q)
    q = corpus[idx] + noise * g.standard_normal((n_q, corpus.shape[1])).astype(np.float32)
    return q.astype(np.float32)


# -- graphs -------------------------------------------------------------------

def random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int):
    """Degree-skewed random graph with community-correlated features/labels."""
    g = _rng(seed, 0, 5)
    n_comm = max(2, n_classes)
    comm = g.integers(0, n_comm, size=n_nodes)
    src = g.integers(0, n_nodes, size=n_edges)
    # 70% of edges stay within the community (homophily).
    intra = g.random(n_edges) < 0.7
    dst_any = g.integers(0, n_nodes, size=n_edges)
    perm = g.permutation(n_nodes)
    comm_members: dict = {}
    for node in range(n_nodes):
        comm_members.setdefault(comm[node], []).append(node)
    dst_intra = np.array(
        [comm_members[comm[s]][g.integers(0, len(comm_members[comm[s]]))]
         for s in src], dtype=np.int64)
    dst = np.where(intra, dst_intra, dst_any)
    feat_centers = g.standard_normal((n_comm, d_feat)).astype(np.float32)
    x = feat_centers[comm] + 0.5 * g.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = comm % n_classes
    return {"x": x.astype(np.float32), "src": src.astype(np.int32),
            "dst": dst.astype(np.int32), "labels": labels.astype(np.int32)}


def neighbor_sample(seed: int, step: int, csr_indptr: np.ndarray,
                    csr_indices: np.ndarray, seeds: np.ndarray,
                    fanouts: Tuple[int, ...]):
    """GraphSAGE-style fanout sampler -> nested-frontier blocks (gnn.forward_sampled).

    Frontiers nest: the first len(parent) rows of each frontier ARE the child
    frontier.  Returns (node_ids of outermost frontier, blocks) where
    blocks[l] = (src_idx, dst_idx, n_dst) index into the running frontier.
    """
    g = _rng(seed, step, 6)
    frontier = np.asarray(seeds, dtype=np.int64)
    blocks = []
    for fanout in fanouts:
        pos = {int(n): i for i, n in enumerate(frontier)}
        src_idx, dst_idx, new_nodes = [], [], []
        for di, node in enumerate(frontier):
            lo, hi = csr_indptr[node], csr_indptr[node + 1]
            if hi > lo:
                picks = csr_indices[lo + g.integers(0, hi - lo, size=fanout)]
                for nb in picks:
                    nb = int(nb)
                    if nb not in pos:
                        pos[nb] = len(frontier) + len(new_nodes)
                        new_nodes.append(nb)
                    src_idx.append(pos[nb])
                    dst_idx.append(di)
        blocks.append((np.asarray(src_idx, np.int32), np.asarray(dst_idx, np.int32),
                       len(frontier)))
        frontier = np.concatenate([frontier, np.asarray(new_nodes, np.int64)])
    # Invert: aggregation runs outermost-first.
    return frontier, blocks[::-1]


# -- recsys -------------------------------------------------------------------

def recsys_batch(seed: int, step: int, arch_id: str, cfg, batch: int):
    """Labels are a deterministic function of the features (learnable signal),
    not coin flips — training tests assert the loss actually decreases."""
    g = _rng(seed, step, 7)
    if arch_id == "dlrm-rm2":
        sparse = g.integers(0, np.asarray(cfg.vocab_sizes),
                            size=(batch, cfg.n_sparse)).astype(np.int32)
        dense = g.standard_normal((batch, cfg.n_dense)).astype(np.float32)
        label = ((sparse[:, 0] + sparse[:, 1]) % 2).astype(np.int32)
        return {"dense": dense, "sparse": sparse, "label": label}
    if arch_id == "dien":
        target_item = g.integers(0, cfg.item_vocab, size=batch).astype(np.int32)
        return {
            "hist_items": g.integers(0, cfg.item_vocab, size=(batch, cfg.seq_len)).astype(np.int32),
            "hist_cats": g.integers(0, cfg.cat_vocab, size=(batch, cfg.seq_len)).astype(np.int32),
            "target_item": target_item,
            "target_cat": g.integers(0, cfg.cat_vocab, size=batch).astype(np.int32),
            "label": (target_item % 2).astype(np.int32),
        }
    if arch_id == "fm":
        sparse = g.integers(0, np.asarray(cfg.vocab_sizes),
                            size=(batch, cfg.n_sparse)).astype(np.int32)
        return {"sparse": sparse,
                "label": ((sparse[:, 0] + sparse[:, 1]) % 2).astype(np.int32)}
    if arch_id == "two-tower-retrieval":
        return {
            "user_hist": g.integers(0, cfg.user_vocab,
                                    size=(batch, cfg.n_user_feats)).astype(np.int32),
            "item_id": g.integers(0, cfg.item_vocab, size=batch).astype(np.int32),
            "item_freq": (g.random(batch).astype(np.float32) * 0.01 + 1e-4),
        }
    raise ValueError(arch_id)

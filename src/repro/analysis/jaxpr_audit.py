"""Jaxpr-level determinism auditor (DESIGN.md §10).

Walks ``ClosedJaxpr`` s of captured SearchPlan stages — the exact functions
``engine/plan.py`` compiles, captured through its stage observer with their
real operands — and flags determinism hazards:

* ``const-array``      — closure-captured arrays baked into the trace
                         (INV-ARGS-NOT-CONSTS): XLA constant-folds them and
                         folded float arithmetic need not match the runtime
                         op sequence bit-for-bit.  Exemptions (documented in
                         invariants.py): scalars/tiny consts, uniform fills,
                         integer iotas, seeded ±1/0 factors (RHDH signs and
                         Hadamard blocks), and ≤16-entry float tables (the
                         Lloyd-Max codebooks).
* ``full-scan-dot``    — a query×corpus f32 dot executed OUTSIDE the fixed
                         8-row-chunk + optimization_barrier structure of
                         ``kernels/ref.py`` (or the Pallas kernel's fixed
                         tiling): the last ulp then varies with batch shape.
* ``full-reduce``      — a corpus-length float reduction outside that
                         structure (same re-association hazard).
* ``x64-leak``         — float64/int64/uint64 avals inside a stage (JAX
                         runs x64-disabled; predicate keys are (hi, lo)
                         uint32 planes precisely to keep it that way).
* ``callback-prim`` /
  ``rng-prim``         — pure/io/debug callbacks or live PRNG primitives
                         inside a compiled stage (host state or key streams
                         inside the traced program).

Checks are structural: they recurse through every sub-jaxpr (pjit, scan,
while, cond branches, shard_map, custom_jvp/vjp bodies) carrying ancestor
context, so "this dot is inside the barriered 8-row chunk scan" is decided
from the program, not from naming conventions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .findings import Finding
from .invariants import annotate

#: The pinned query-chunk granularity of every full-scan dot
#: (kernels/ref.py _ROW_CHUNK == the Pallas kernels' block_q grain).
ROW_CHUNK = 8

#: Size above which an integer/bool constant counts as corpus-scale.
INT_CONST_LIMIT = 1024
#: Size above which a non-exempt float constant is a hazard.  16 admits the
#: 4-bit Lloyd-Max codebook; anything larger must be ±1/0 (RHDH factors).
FLOAT_CONST_LIMIT = 16

_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
})
_RNG_PRIMS = frozenset({
    "threefry2x32", "random_seed", "random_bits", "random_wrap",
    "random_fold_in", "random_unwrap", "random_gamma", "rng_bit_generator",
})
_X64_DTYPES = frozenset({"float64", "int64", "uint64", "complex128"})

CHECKS = (
    "const-array", "full-scan-dot", "full-reduce", "x64-leak",
    "callback-prim", "rng-prim",
)


@dataclasses.dataclass
class StageCapture:
    """One stage invocation captured from the engine's observer hook."""

    backend: str                  # plan backend kind (or "SelfTest")
    stage: str                    # plan stage name ("rotate", "scan", ...)
    fn: Callable[..., Any]        # the UN-jitted stage callable
    args: Tuple[Any, ...]         # the concrete operands it was called with
    context: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # context keys used by checks:
    #   n_corpus  — smallest per-segment row count of the grid index; any
    #               rank-2 float dot with a free dim >= n_corpus is treated
    #               as a full-corpus scan.
    #   label     — human grid-point label for reports.

    @property
    def site(self) -> str:
        return f"{self.backend}/{self.stage}"


# ---------------------------------------------------------------------------
# Jaxpr walking.
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: Dict[str, Any]) -> Iterable[Any]:
    """Every ClosedJaxpr reachable from one eqn's params (scan/while/cond/
    pjit/shard_map/custom_* all stash theirs under different keys)."""
    from jax.extend import core as jex_core  # type: ignore[import-not-found]
    closed = getattr(jex_core, "ClosedJaxpr", None) or jax.core.ClosedJaxpr
    for value in params.values():
        if isinstance(value, closed):
            yield value
        elif isinstance(value, (tuple, list)):
            for item in value:
                if isinstance(item, closed):
                    yield item


def _walk(
    closed: Any,
    visit: Callable[[Any, Tuple[str, ...], bool], None],
    ancestors: Tuple[str, ...] = (),
    barrier_seen: bool = False,
) -> None:
    """Depth-first over eqns; ``visit(eqn, ancestors, barrier_seen)`` gets
    the enclosing primitive chain and whether any enclosing level (this one
    included) contains an optimization_barrier."""
    jaxpr = closed.jaxpr
    level_barrier = barrier_seen or any(
        eqn.primitive.name == "optimization_barrier" for eqn in jaxpr.eqns)
    for eqn in jaxpr.eqns:
        visit(eqn, ancestors, level_barrier)
        for sub in _sub_jaxprs(eqn.params):
            _walk(sub, visit, ancestors + (eqn.primitive.name,),
                  level_barrier)


def _all_consts(closed: Any) -> List[Any]:
    """Constants at every nesting level of a ClosedJaxpr."""
    out = list(closed.consts)
    seen = {id(closed)}

    def rec(c: Any) -> None:
        for eqn in c.jaxpr.eqns:
            for sub in _sub_jaxprs(eqn.params):
                if id(sub) in seen:
                    continue
                seen.add(id(sub))
                out.extend(sub.consts)
                rec(sub)

    rec(closed)
    return out


# ---------------------------------------------------------------------------
# Checks.
# ---------------------------------------------------------------------------

def _classify_const(value: Any) -> Optional[str]:
    """None = exempt; otherwise a stable hazard class string."""
    arr = np.asarray(value)
    if arr.ndim == 0 or arr.size <= 8:
        return None                                   # scalar / tiny
    flat = arr.reshape(-1)
    first = flat[0]
    if bool(np.all(flat == first)):
        return None                                   # uniform fill
    if arr.dtype.kind in "iub":
        if arr.ndim == 1 and bool(np.all(np.diff(flat.astype(np.int64)) == 1)):
            return None                               # iota / arange
        if arr.size <= INT_CONST_LIMIT:
            return None
        return f"int-array[{arr.dtype}]"
    if arr.dtype.kind == "f":
        if bool(np.all(np.isin(flat, (-1.0, 0.0, 1.0)))):
            return None                               # seeded ±1/0 factor
        if arr.size <= FLOAT_CONST_LIMIT:
            return None                               # Lloyd-Max table
        return f"float-array[{arr.dtype}]"
    return f"array[{arr.dtype}]"


def _check_consts(closed: Any, cap: StageCapture) -> List[Finding]:
    found: List[Finding] = []
    for const in _all_consts(closed):
        cls = _classify_const(const)
        if cls is None:
            continue
        arr = np.asarray(const)
        found.append(Finding(
            check="const-array",
            site=cap.site,
            detail=(
                f"stage closes over a {cls} constant (ndim={arr.ndim}): "
                f"arrays must ride as stage ARGUMENTS — XLA constant-folds "
                f"captured arrays and folded arithmetic is not bit-stable"),
            signature=("const-array", cls, f"ndim={arr.ndim}"),
        ))
    return found


def _dot_free_dims(eqn: Any) -> Optional[Tuple[int, int, int]]:
    """(lhs_free, rhs_free, n_batch) row/col products of a dot_general."""
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = (v.aval for v in eqn.invars[:2])
    lf = int(np.prod([d for i, d in enumerate(lhs.shape)
                      if i not in lc and i not in lb] or [1]))
    rf = int(np.prod([d for i, d in enumerate(rhs.shape)
                      if i not in rc and i not in rb] or [1]))
    return lf, rf, len(lb)


def _chunk_safe(ancestors: Tuple[str, ...], barrier_seen: bool,
                lhs_free: int) -> bool:
    if "pallas_call" in ancestors:
        return True                        # kernel: fixed tiling by build
    looped = any(a in ("scan", "while") for a in ancestors)
    return looped and barrier_seen and lhs_free == ROW_CHUNK


def _check_program(closed: Any, cap: StageCapture) -> List[Finding]:
    found: List[Finding] = []
    n_corpus = int(cap.context.get("n_corpus", 0))

    def visit(eqn: Any, ancestors: Tuple[str, ...], barrier: bool) -> None:
        name = eqn.primitive.name
        if name in _CALLBACK_PRIMS:
            found.append(Finding(
                check="callback-prim", site=cap.site,
                detail=f"host callback primitive '{name}' inside a compiled "
                       f"stage",
                signature=("callback-prim", name)))
        elif name in _RNG_PRIMS:
            found.append(Finding(
                check="rng-prim", site=cap.site,
                detail=f"PRNG primitive '{name}' inside a compiled stage "
                       f"(key streams must resolve at trace time from the "
                       f"fingerprinted seed)",
                signature=("rng-prim", name)))
        elif name == "dot_general" and n_corpus:
            out_dtype = eqn.outvars[0].aval.dtype
            lhs, rhs = (v.aval for v in eqn.invars[:2])
            if (np.issubdtype(out_dtype, np.floating)
                    and lhs.ndim == 2 and rhs.ndim == 2):
                dims = _dot_free_dims(eqn)
                if dims is not None:
                    lf, rf, nb = dims
                    if (nb == 0 and rf >= n_corpus
                            and not _chunk_safe(ancestors, barrier, lf)):
                        found.append(Finding(
                            check="full-scan-dot", site=cap.site,
                            detail=(
                                f"[{lf} x d] @ [d x {rf}] full-corpus float "
                                f"dot outside the fixed {ROW_CHUNK}-row "
                                f"chunk + optimization_barrier structure "
                                f"(kernels/ref.py): last ulp varies with "
                                f"batch shape"),
                            signature=("full-scan-dot", str(out_dtype))))
        elif name in ("reduce_sum", "reduce_prod", "cumsum") and n_corpus:
            aval = eqn.invars[0].aval
            if np.issubdtype(aval.dtype, np.floating):
                axes = eqn.params.get("axes", eqn.params.get("axis", ()))
                axes = (axes,) if isinstance(axes, int) else axes
                reduced = int(np.prod([aval.shape[a] for a in axes] or [1]))
                if (reduced >= n_corpus
                        and not _chunk_safe(ancestors, barrier, ROW_CHUNK)):
                    found.append(Finding(
                        check="full-reduce", site=cap.site,
                        detail=(
                            f"float reduction over {reduced} elements "
                            f"(corpus-scale) outside the pinned chunk "
                            f"structure: reduction order is shape-dependent"),
                        signature=("full-reduce", str(aval.dtype))))
        for var in tuple(eqn.invars) + tuple(eqn.outvars):
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and str(dtype) in _X64_DTYPES:
                found.append(Finding(
                    check="x64-leak", site=cap.site,
                    detail=f"64-bit aval ({dtype}) in primitive '{name}': "
                           f"stages must stay in 32-bit space (u64 keys are "
                           f"split into uint32 planes)",
                    signature=("x64-leak", str(dtype), name)))

    _walk(closed, visit)
    return found


def audit_jaxpr(closed: Any, cap: StageCapture) -> List[Finding]:
    """All findings for one stage's ClosedJaxpr (deduplicated, annotated
    with the invariant each check enforces)."""
    raw = _check_consts(closed, cap) + _check_program(closed, cap)
    seen: Dict[str, Finding] = {}
    for f in raw:
        seen.setdefault(f.fingerprint(), f)
    return [annotate(f) for f in seen.values()]


def audit_captures(captures: Sequence[StageCapture]) -> List[Finding]:
    """make_jaxpr every capture and audit it; findings deduplicate across
    the whole grid by fingerprint (one entry per structural hazard)."""
    out: Dict[str, Finding] = {}
    for cap in captures:
        try:
            closed = jax.make_jaxpr(cap.fn)(*cap.args)
        except Exception as exc:   # a stage that cannot re-trace is itself
            f = annotate(Finding(   # a hazard: plans must be pure functions
                check="tracer-leak", site=cap.site,
                detail=f"stage failed to re-trace standalone: {exc}",
                signature=("retrace-failure", type(exc).__name__)))
            out.setdefault(f.fingerprint(), f)
            continue
        for f in audit_jaxpr(closed, cap):
            out.setdefault(f.fingerprint(), f)
    return list(out.values())

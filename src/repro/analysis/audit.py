"""``python -m repro.analysis.audit`` — the determinism audit CLI (CI gate).

One run = four passes, one report, one exit code:

1. **grid**     — drive the real engine over the backend × metric × bits ×
   lifecycle grid (analysis/grid.py), capture every compiled stage through
   the plan observer, and audit each ClosedJaxpr (analysis/jaxpr_audit.py);
2. **coverage** — every PLAN_STAGES export must have been witnessed;
3. **retrace**  — rebuild a small plan under ``jax.checking_leaks`` and
   replay the same bucket: any stage retrace on a warm cache (or a leaked
   tracer) is a finding (INV-ZERO-RETRACE);
4. **lint**     — the AST source rules (analysis/lint.py).

Findings are matched against the committed allowlist
(``src/repro/analysis/allowlist.json``); the report (AUDIT_REPORT.json)
lists active, allowlisted, and STALE entries — a stale entry fails the run,
so the allowlist cannot rot and tampering with it breaks CI.

``--inject-hazard`` swaps the grid for one deliberately broken synthetic
stage (closure-captured corpus + unbarriered full-scan dot) and must exit
non-zero naming BOTH hazards — CI runs it to prove the gate can fail.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Sequence

import numpy as np

from .findings import (Allowlist, Finding, load_allowlist, render_report)
from .invariants import annotate
from .jaxpr_audit import StageCapture, audit_captures

DEFAULT_ALLOWLIST = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "allowlist.json")


def inject_hazard_capture() -> StageCapture:
    """A stage written exactly the way stages must NOT be written: the
    corpus rides in the closure (const-array) and the scoring dot runs over
    the whole corpus with no chunk/barrier structure (full-scan-dot)."""
    import jax.numpy as jnp

    rng = np.random.RandomState(0xBAD)
    bad_corpus = jnp.asarray(rng.randn(64, 16).astype(np.float32))

    def hazardous_stage(q: "jnp.ndarray") -> "jnp.ndarray":
        return q @ bad_corpus.T

    q = jnp.asarray(rng.randn(12, 16).astype(np.float32))
    return StageCapture(
        backend="SelfTest", stage="injected_hazard",
        fn=hazardous_stage, args=(q,),
        context={"n_corpus": 64, "label": "self-test/injected",
                 "labels": ["self-test/injected"]})


def retrace_findings() -> List[Finding]:
    """INV-ZERO-RETRACE: build + warm a plan under jax.checking_leaks, then
    replay the same shape bucket — the plan cache's trace counter must not
    move, and no tracer may escape a stage."""
    import jax

    from repro.core.api import MonaVec
    from repro.engine import plan as plan_mod

    rng = np.random.RandomState(99)
    vecs = rng.randn(40, 16).astype(np.float32)
    q = rng.randn(3, 16).astype(np.float32)
    out: List[Finding] = []
    try:
        with jax.checking_leaks():
            idx = MonaVec.build(vecs, metric="cosine", bits=4, seed=0xA11CE)
            idx.search(q, k=4)                       # cold: traces here
            before = plan_mod.plan_cache().stats.traces
            for step in range(3):
                idx.search(q + np.float32(0.0), k=4)  # warm, same bucket
            after = plan_mod.plan_cache().stats.traces
    except Exception as exc:
        out.append(annotate(Finding(
            check="tracer-leak", site="engine/plan",
            detail=f"jax.checking_leaks raised during plan replay: {exc}",
            signature=("tracer-leak", type(exc).__name__))))
        return out
    if after != before:
        out.append(annotate(Finding(
            check="unexpected-retrace", site="engine/plan",
            detail=(f"{after - before} stage trace(s) on warm same-bucket "
                    f"searches — the plan cache key is unstable"),
            signature=("unexpected-retrace", "warm-bucket"))))
    return out


def run_audit(
    *,
    inject_hazard: bool = False,
    skip_retrace: bool = False,
    skip_lint: bool = False,
    allowlist_path: str = DEFAULT_ALLOWLIST,
    progress: bool = False,
) -> dict:
    """Execute the full audit; returns the report dict (see render_report)."""
    say = (lambda msg: print(msg, file=sys.stderr, flush=True)) if progress \
        else (lambda msg: None)

    findings: List[Finding] = []
    extra = {"mode": "inject-hazard" if inject_hazard else "full"}

    if inject_hazard:
        say("auditing injected hazardous stage (gate self-test)")
        findings.extend(audit_captures([inject_hazard_capture()]))
    else:
        from . import grid as grid_mod

        say("collecting stage captures over the audit grid")
        captures = grid_mod.collect_captures(
            progress=(lambda label: say(f"  grid point: {label}")))
        say(f"auditing {len(captures)} captured stages")
        findings.extend(audit_captures(captures))
        findings.extend(grid_mod.coverage_findings(captures))
        extra["captures"] = len(captures)
        extra["grid_points"] = len(grid_mod.default_grid())
        if not skip_retrace:
            say("retrace / tracer-leak pass (jax.checking_leaks)")
            findings.extend(retrace_findings())
        if not skip_lint:
            from .lint import lint_tree

            say("AST lint pass")
            findings.extend(lint_tree())

    allow = (load_allowlist(allowlist_path)
             if os.path.exists(allowlist_path) else Allowlist())
    # The injected-hazard mode audits ONE synthetic stage; the allowlist
    # still applies (so a tampered allowlist cannot mask the self-test) but
    # its real entries are necessarily stale there — ignore staleness.
    report = render_report(findings, allow,
                           stale_is_error=not inject_hazard, extra=extra)
    try:
        import jax
        report["environment"] = {"jax": jax.__version__,
                                 "backend": jax.default_backend()}
    except Exception:
        pass
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="jaxpr-level determinism audit over the stage grid")
    parser.add_argument("--report", default="AUDIT_REPORT.json",
                        help="path for the JSON report ('-' for stdout only)")
    parser.add_argument("--allowlist", default=DEFAULT_ALLOWLIST)
    parser.add_argument("--inject-hazard", action="store_true",
                        help="audit a deliberately hazardous synthetic stage "
                             "instead of the grid; MUST exit non-zero")
    parser.add_argument("--skip-retrace", action="store_true")
    parser.add_argument("--skip-lint", action="store_true")
    parser.add_argument("--quiet", action="store_true")
    args = parser.parse_args(argv)

    report = run_audit(
        inject_hazard=args.inject_hazard,
        skip_retrace=args.skip_retrace,
        skip_lint=args.skip_lint,
        allowlist_path=args.allowlist,
        progress=not args.quiet,
    )

    if args.report != "-":
        with open(args.report, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")

    for f in report["findings"]:
        mark = "ALLOWED" if f["allowlisted"] else "ERROR  "
        print(f"{mark} {f['check']:26s} {f['site']}  [{f['invariant']}]")
        print(f"        {f['detail']}")
    for fp in report["stale_allowlist_entries"]:
        print(f"STALE   allowlist entry {fp} matched no finding — remove it "
              f"(or the audit was tampered with)")
    counts = report["counts"]
    verdict = "OK" if report["ok"] else "FAIL"
    print(f"{verdict}: {counts['active']} active, "
          f"{counts['allowlisted']} allowlisted, "
          f"{counts['stale_allowlist']} stale allowlist entries")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

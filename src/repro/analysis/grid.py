"""The audit grid: drive the REAL engine over tiny indexes and capture
every compiled stage through ``engine.plan.set_stage_observer``.

The auditor never re-implements stage construction — it installs the
observer hook, runs ordinary ``MonaVec.search`` / ``ShardedMonaVec.search``
/ ``HybridIndex.search`` calls over a backend × metric × bits × lifecycle
grid (plus predicate, mixed-precision, sharded and hybrid points), and
audits exactly the functions and operands the plan cache compiled.  Two
batch sizes straddle a bucket boundary (b=3 → bucket 8, b=12 → bucket 16)
so a full-scan dot that merely COINCIDES with the 8-row chunk at the small
bucket cannot pass.

Coverage is closed-loop (INV-STAGE-COVERAGE): every stage factory a module
exports through ``PLAN_STAGES`` must be witnessed by at least one capture,
otherwise the audit emits an ``uncovered-stage`` finding — a new stage
cannot ship outside the auditor's view.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding
from .invariants import annotate
from .jaxpr_audit import StageCapture

#: Tiny but structurally honest corpora: n is corpus-scale relative to every
#: structural dimension in play (d_pad=16, nlist=8, k=4 all < N_EXTRA), so
#: the full-scan-dot threshold (min per-segment rows) never collides with a
#: legitimate small dot.
N_BASE = 48
N_EXTRA = 24
DIM = 16
K = 4
BATCHES = (3, 12)          # buckets 8 and 16

#: module -> PLAN_STAGES factory -> predicate over that module's captures.
#: (filled in _coverage_witnesses; listed here for the docstring's benefit)


@dataclasses.dataclass(frozen=True)
class GridPoint:
    label: str
    index: str = "bruteforce"          # bruteforce | ivf | hnsw
    metric: str = "cosine"
    bits: int = 4
    lifecycle: str = "static"          # static | mutated
    where: bool = False                # compile a predicate mask stage
    sharded: bool = False
    hybrid: bool = False
    avg_bits: Optional[float] = None   # BF mixed-precision point
    coarse: Optional[str] = None       # sign | crumb: attach coarse codes
    rescore_mult: Optional[int] = None  # cascade rescore budget (r*k)
    tuned: bool = False                # autotune first; searches run tuned


def default_grid() -> Tuple[GridPoint, ...]:
    pts: List[GridPoint] = []
    for index in ("bruteforce", "ivf", "hnsw"):
        for metric, bits in (("cosine", 4), ("l2", 2), ("dot", 4)):
            pts.append(GridPoint(
                label=f"{index}/{metric}/b{bits}/static",
                index=index, metric=metric, bits=bits))
        pts.append(GridPoint(
            label=f"{index}/cosine/b4/mutated",
            index=index, lifecycle="mutated"))
    pts.append(GridPoint(label="bruteforce/cosine/mixed3.0/static",
                         avg_bits=3.0))
    pts.append(GridPoint(label="bruteforce/cosine/b4/static+where",
                         where=True))
    pts.append(GridPoint(label="ivf/l2/b4/mutated+where", index="ivf",
                         metric="l2", lifecycle="mutated", where=True))
    pts.append(GridPoint(label="sharded/cosine/b4/static", sharded=True))
    pts.append(GridPoint(label="hybrid/cosine/b4/static+where",
                         hybrid=True, where=True))
    # Binarized-cascade points (DESIGN.md §11): r*k=16 < every segment size
    # (48 base / 24 extra), so the rescore_mult knob survives normalization
    # and the coarse_scan/survivor_topk/gathered_rescore stages compile.
    pts.append(GridPoint(label="cascade-sign/cosine/b4/static",
                         coarse="sign", rescore_mult=4))
    pts.append(GridPoint(label="cascade-crumb/l2/b4/mutated+where",
                         coarse="crumb", rescore_mult=4,
                         lifecycle="mutated", where=True))
    pts.append(GridPoint(label="cascade-sign/sharded/cosine/b4/static",
                         coarse="sign", rescore_mult=4, sharded=True))
    # Autotuned point (DESIGN.md §12): the tuned boost curve makes every
    # filtered search consult the selectivity popcount stage, so the
    # selectivity_popcount capture is witnessed from a live tuned search.
    pts.append(GridPoint(label="ivf/cosine/b4/static+where+tuned",
                         index="ivf", where=True, tuned=True))
    return tuple(pts)


# ---------------------------------------------------------------------------
# Index construction (seeded; np.random.RandomState is the repo idiom).
# ---------------------------------------------------------------------------

def _vectors(n: int, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    return rng.randn(n, DIM).astype(np.float32)


def _meta(n: int, seed: int) -> dict:
    rng = np.random.RandomState(seed)
    return {
        "cat": np.array(["red", "green", "blue"])[rng.randint(0, 3, n)],
        "price": rng.randint(0, 100, n).astype(np.int64),
    }


def _predicate() -> object:
    from repro.core import predicate as pred
    return pred.And(pred.Ge("price", 10), pred.Ne("cat", "green"))


def _build_index(point: GridPoint) -> object:
    from repro.core.api import MonaVec
    kwargs: Dict[str, object] = {}
    if point.index == "ivf":
        kwargs = {"nlist": 8}
    elif point.index == "hnsw":
        kwargs = {"m": 4, "ef_construction": 16}
    if point.avg_bits is not None:
        kwargs["avg_bits"] = point.avg_bits
    meta = _meta(N_BASE, seed=7) if point.where else None
    idx = MonaVec.build(
        _vectors(N_BASE, seed=3), metric=point.metric, index=point.index,
        bits=point.bits, meta=meta, coarse=point.coarse, **kwargs)
    if point.lifecycle == "mutated":
        add_meta = _meta(N_EXTRA, seed=8) if point.where else None
        idx.add(_vectors(N_EXTRA, seed=4), meta=add_meta)
        idx.delete(list(idx.ids[2:10:2]))
    return idx


def _min_segment_rows(idx: object) -> int:
    rows = [int(idx.backend.enc.n)] + [int(s.n) for s in idx.mut.extras]
    return min(rows)


# ---------------------------------------------------------------------------
# Capture collection.
# ---------------------------------------------------------------------------

def _capture_key(cap: StageCapture) -> tuple:
    shapes = tuple(
        (tuple(getattr(a, "shape", ())), str(getattr(a, "dtype", type(a).__name__)))
        for a in cap.args)
    return (cap.backend, cap.stage, shapes, cap.context.get("n_corpus"))


def collect_captures(
    points: Optional[Sequence[GridPoint]] = None,
    progress: Optional[Callable[[str], None]] = None,
) -> List[StageCapture]:
    """Run the grid under the stage observer; returns deduplicated captures
    (one per distinct backend/stage/operand-signature)."""
    from repro.engine import plan as plan_mod

    points = tuple(points if points is not None else default_grid())
    captures: List[StageCapture] = []
    current: Dict[str, object] = {}

    by_key: Dict[tuple, StageCapture] = {}

    def observer(kind: str, stage: str, fn: Callable[..., object],
                 args: Tuple[object, ...]) -> None:
        ctx = dict(current)
        label = str(ctx.get("label", ""))
        cap = StageCapture(backend=kind, stage=stage, fn=fn,
                           args=tuple(args), context=ctx)
        key = _capture_key(cap)
        prior = by_key.get(key)
        if prior is None:
            cap.context["labels"] = [label]
            by_key[key] = cap
            captures.append(cap)
        else:
            # Deduplicated, but keep every grid point that witnessed this
            # capture — coverage witnesses (e.g. the hybrid point) need it.
            labels = prior.context.setdefault("labels", [])
            if label not in labels:
                labels.append(label)

    prev = plan_mod.set_stage_observer(observer)
    try:
        for point in points:
            if progress:
                progress(point.label)
            current.clear()
            current["label"] = point.label
            _run_point(point, current)
    finally:
        plan_mod.set_stage_observer(prev)
    return captures


def _run_point(point: GridPoint, current: Dict[str, object]) -> None:
    where = _predicate() if point.where else None
    if point.hybrid:
        from repro.core.hybrid import HybridIndex
        docs = [f"doc {i} alpha beta gamma"[: 12 + (i % 9)]
                for i in range(N_BASE)]
        hy = HybridIndex.build(
            _vectors(N_BASE, seed=3), docs,
            meta=_meta(N_BASE, seed=7) if point.where else None)
        current["n_corpus"] = int(hy.dense.enc.n)
        for b in BATCHES:
            q = _vectors(b, seed=11)
            hy.search(q, [f"alpha {i}" for i in range(b)], k=K, where=where)
        return

    idx = _build_index(point)
    current["n_corpus"] = _min_segment_rows(idx)
    if point.tuned:
        # Real autotune under the observer (its ladder-sweep searches are
        # ordinary plan executions over the same corpus); the count cache is
        # dropped first so the selectivity_popcount stage re-fires even when
        # the grid runs twice in one process.
        from repro.tune import clear_caches
        clear_caches()
        idx.autotune(recall_target=0.9, k=K, n_queries=8)
    target = idx.shard() if point.sharded else idx
    kw = ({"rescore_mult": point.rescore_mult}
          if point.rescore_mult is not None else {})
    for b in BATCHES:
        q = _vectors(b, seed=11)
        target.search(q, k=K, where=where, **kw)


# ---------------------------------------------------------------------------
# PLAN_STAGES coverage (INV-STAGE-COVERAGE).
# ---------------------------------------------------------------------------

STAGE_MODULES = (
    "repro.core.bruteforce",
    "repro.core.ivf",
    "repro.core.hnsw",
    "repro.core.segments",
    "repro.core.predicate",
    "repro.core.binary",
    "repro.dist.retrieval",
    "repro.engine.fusion",
    "repro.tune.selectivity",
)


def _coverage_witnesses() -> Dict[str, Callable[[Sequence[StageCapture]], bool]]:
    """How each exported stage factory proves it was captured."""
    def by_stage(
        stage: str, backend: Optional[str] = None,
    ) -> Callable[[Sequence[StageCapture]], bool]:
        def pred(caps: Sequence[StageCapture]) -> bool:
            return any(c.stage == stage
                       and (backend is None or c.backend == backend)
                       for c in caps)
        return pred

    def hybrid_point(caps: Sequence[StageCapture]) -> bool:
        # fusion.search_hybrid's dense channel is an ordinary plan; proof of
        # coverage is any stage witnessed while a hybrid grid point ran.
        return any(str(label).startswith("hybrid")
                   for c in caps for label in c.context.get("labels", ()))

    return {
        "repro.core.bruteforce:scan_stage": by_stage("scan"),
        "repro.core.ivf:search_stage": by_stage("main", "IvfFlatIndex"),
        "repro.core.hnsw:search_stage": by_stage("main", "HnswIndex"),
        "repro.core.segments:merge_stage": by_stage("merge"),
        "repro.core.predicate:build_stage_fn": by_stage("predicate_mask"),
        "repro.core.binary:coarse_scan_stage": by_stage("coarse_scan"),
        "repro.core.binary:survivor_topk_stage": by_stage("survivor_topk"),
        "repro.core.binary:gathered_rescore_stage":
            by_stage("gathered_rescore"),
        "repro.dist.retrieval:make_scan_topk_shardmap":
            by_stage("shard_scan", "ShardedMonaVec"),
        "repro.dist.retrieval:make_cascade_topk_shardmap":
            by_stage("cascade_shard_scan", "ShardedMonaVec"),
        "repro.engine.fusion:search_hybrid": hybrid_point,
        "repro.tune.selectivity:make_popcount_fn":
            by_stage("selectivity_popcount"),
    }


def coverage_findings(captures: Sequence[StageCapture]) -> List[Finding]:
    """Every PLAN_STAGES export must be witnessed; an export the auditor
    does not know how to witness is ALSO a finding (teach grid.py first)."""
    witnesses = _coverage_witnesses()
    found: List[Finding] = []
    for mod_name in STAGE_MODULES:
        mod = importlib.import_module(mod_name)
        for factory in getattr(mod, "PLAN_STAGES", ()):
            key = f"{mod_name}:{factory}"
            witness = witnesses.get(key)
            if witness is None:
                found.append(annotate(Finding(
                    check="uncovered-stage", site=key,
                    detail=(f"{key} is exported via PLAN_STAGES but the "
                            f"audit grid has no witness for it — add a "
                            f"grid point/witness in analysis/grid.py"),
                    signature=("uncovered-stage", "no-witness", key))))
            elif not witness(captures):
                found.append(annotate(Finding(
                    check="uncovered-stage", site=key,
                    detail=(f"{key} was never captured by the audit grid "
                            f"run — its stage factory is outside the "
                            f"auditor's view"),
                    signature=("uncovered-stage", "not-captured", key))))
    return found

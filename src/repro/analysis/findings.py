"""Structured findings, stable fingerprints, and the committed allowlist.

A ``Finding`` is one detected violation of a determinism invariant: which
check fired, where (backend / stage / module), and a detail signature that
is STABLE across runs and machines — fingerprints hash only structural
fields (never shapes of the tiny audit corpora, object ids, or paths
outside the repo), so an allowlist entry accepted once keeps matching until
the underlying code actually changes what it stages.

The allowlist is a committed JSON file (``repro/analysis/allowlist.json``).
Every entry must carry a human ``reason``; the audit treats a STALE entry
(an allowlisted fingerprint that no longer matches any finding) as a
failure in strict mode, so the allowlist cannot silently rot — and
tampering with it (adding entries that match nothing) fails CI.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One determinism-contract violation (or allowlist mismatch)."""

    check: str                    # e.g. "const-array" (jaxpr_audit.CHECKS)
    site: str                     # "<backend>/<stage>" or "<module>:<line>"
    detail: str                   # human-readable description
    signature: Tuple[str, ...]    # structural fields, the fingerprint input
    invariant: str = ""           # filled from invariants.py at report time
    design_ref: str = ""
    severity: str = "error"

    def fingerprint(self) -> str:
        return fingerprint(self.check, self.site, self.signature)

    def to_dict(self, allowlisted: bool = False) -> dict:
        return {
            "check": self.check,
            "site": self.site,
            "detail": self.detail,
            "signature": list(self.signature),
            "invariant": self.invariant,
            "design_ref": self.design_ref,
            "severity": self.severity,
            "fingerprint": self.fingerprint(),
            "allowlisted": allowlisted,
        }


def fingerprint(check: str, site: str, signature: Sequence[str]) -> str:
    """Stable 16-hex digest of a finding's structural identity."""
    payload = json.dumps([check, site, list(signature)], sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass
class Allowlist:
    """Accepted findings: fingerprint -> reason (the committed gate state)."""

    entries: Dict[str, str] = dataclasses.field(default_factory=dict)
    path: Optional[str] = None

    def match(self, finding: Finding) -> bool:
        return finding.fingerprint() in self.entries

    def stale(self, findings: Sequence[Finding]) -> List[str]:
        """Fingerprints in the allowlist that matched NO finding — evidence
        of a fixed hazard (remove the entry) or a tampered file."""
        seen = {f.fingerprint() for f in findings}
        return sorted(fp for fp in self.entries if fp not in seen)


def load_allowlist(path: str) -> Allowlist:
    with open(path) as fh:
        payload = json.load(fh)
    entries: Dict[str, str] = {}
    for entry in payload.get("entries", []):
        fp = entry["fingerprint"]
        reason = entry.get("reason", "")
        if not reason:
            raise ValueError(
                f"allowlist entry {fp} has no reason; every accepted finding "
                f"must say why it is safe ({path})")
        entries[fp] = reason
    return Allowlist(entries=entries, path=path)


def render_report(
    findings: Sequence[Finding],
    allowlist: Allowlist,
    *,
    stale_is_error: bool = True,
    extra: Optional[dict] = None,
) -> dict:
    """The AUDIT_REPORT.json payload: findings split by allowlist state,
    stale allowlist entries surfaced, and an overall ``ok`` verdict."""
    active = [f for f in findings if not allowlist.match(f)]
    accepted = [f for f in findings if allowlist.match(f)]
    stale = allowlist.stale(findings)
    ok = not active and not (stale and stale_is_error)
    report = {
        "ok": ok,
        "counts": {
            "active": len(active),
            "allowlisted": len(accepted),
            "stale_allowlist": len(stale),
        },
        "findings": (
            [f.to_dict(allowlisted=False) for f in active]
            + [f.to_dict(allowlisted=True) for f in accepted]
        ),
        "stale_allowlist_entries": stale,
    }
    if extra:
        report.update(extra)
    return report

"""AST-level repo invariant linter — the source rules jaxprs cannot see.

The jaxpr auditor proves properties of what actually got COMPILED; this
module proves properties of what was WRITTEN, catching hazards before they
are reachable from any grid point:

* ``unseeded-random``          (L001) — ``random.*`` / bare ``np.random.*``
  calls in stage-building modules (core/engine/dist/kernels): all index
  randomness must flow from seeded generators (``np.random.RandomState(s)``
  / ``np.random.default_rng(s)``) or seeded ``jax.random`` keys, so builds
  replay byte-identically.
* ``host-time``                (L001) — ``time.*()`` calls in those same
  modules: wall-clock reads belong to obs/ and launch/, never near stage
  construction (a clock INJECTED as a parameter default is fine; a call is
  not).
* ``frombuffer-outside-reader`` (L002) — ``np.frombuffer`` anywhere except
  ``mvec_format._Reader``, the one place that length-checks bytes first.
* ``obs-in-jit``               (L003) — ``obs.inc`` / ``obs.observe`` /
  ``obs.timed_span`` / ``get_registry`` inside a jit-compiled function
  body: host-side observability inside a trace either breaks purity or
  silently becomes a trace-time-only no-op.  Detects ``@jax.jit``
  decorators, ``functools.partial(jax.jit, ...)`` decorators, and
  functions passed to ``jax.jit(...)`` by name anywhere in the module.
* ``stage-asarray``            (L004) — ``jnp.asarray``/``jnp.array`` of a
  closure-captured name inside a jit-compiled body: converting a captured
  array inside the trace bakes it in as a constant (the runtime twin is
  jaxpr_audit's const-array check).

Findings carry line numbers in ``detail`` but NOT in their fingerprint
(site is ``path:qualname``), so unrelated edits above a finding do not
invalidate allowlist entries.
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence, Set

from .findings import Finding
from .invariants import annotate

#: Directories (relative to src/repro) whose modules build stages or bytes.
STAGE_BUILDING_DIRS = ("core", "engine", "dist", "kernels", "tune")
#: The one sanctioned frombuffer site.
READER_MODULE = os.path.join("core", "mvec_format.py")
READER_CLASS = "_Reader"

_OBS_CALLS = {"inc", "observe", "timed_span", "get_registry", "histogram"}
_TIME_CALLS = {"time", "monotonic", "perf_counter", "process_time",
               "thread_time", "clock_gettime"}
_SEEDED_FACTORIES = {"RandomState", "default_rng", "Generator", "SeedSequence"}

RULES = ("unseeded-random", "host-time", "frombuffer-outside-reader",
         "obs-in-jit", "stage-asarray")


def _attr_chain(node: ast.AST) -> Optional[str]:
    """'np.random.randint' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(node: ast.AST) -> bool:
    chain = _attr_chain(node)
    return chain in ("jax.jit", "jit")


def _jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        if _is_jax_jit(dec):
            return True
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func):
                return True
            # functools.partial(jax.jit, ...)
            if (_attr_chain(dec.func) in ("functools.partial", "partial")
                    and dec.args and _is_jax_jit(dec.args[0])):
                return True
    return False


def _names_passed_to_jit(tree: ast.AST) -> Set[str]:
    """Function NAMES given to jax.jit(...) anywhere in the module — catches
    ``jitted = jax.jit(wrapper)`` after a plain ``def wrapper``."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
    return names


def _local_names(fn: ast.AST) -> Set[str]:
    """Parameters + names assigned anywhere inside ``fn`` (so only true
    closure captures count as 'free' for stage-asarray)."""
    args = fn.args
    names = {a.arg for a in (
        list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs))}
    for extra in (args.vararg, args.kwarg):
        if extra is not None:
            names.add(extra.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                names.add(node.name)
    return names


def _finding(rule: str, rel: str, qualname: str, line: int, call: str,
             detail: str) -> Finding:
    return annotate(Finding(
        check=rule,
        site=f"{rel}:{qualname}" if qualname else rel,
        detail=f"{rel}:{line}: {detail}",
        signature=(rule, call),
    ))


class _ModuleLinter(ast.NodeVisitor):
    def __init__(self, rel: str, tree: ast.Module):
        self.rel = rel
        self.findings: List[Finding] = []
        self.stage_building = any(
            rel.startswith(d + os.sep) for d in STAGE_BUILDING_DIRS)
        self.is_reader_module = rel == READER_MODULE
        self._jit_names = _names_passed_to_jit(tree)
        self._class_stack: List[str] = []
        self._fn_stack: List["ast.FunctionDef | ast.AsyncFunctionDef"] = []
        self._jit_depth = 0

    # -- context tracking --------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_fn(
        self, node: "ast.FunctionDef | ast.AsyncFunctionDef",
    ) -> None:
        jitted = _jit_decorated(node) or node.name in self._jit_names
        self._fn_stack.append(node)
        self._jit_depth += 1 if jitted else 0
        self.generic_visit(node)
        self._jit_depth -= 1 if jitted else 0
        self._fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    @property
    def _qualname(self) -> str:
        parts = list(self._class_stack) + [f.name for f in self._fn_stack]
        return ".".join(parts)

    # -- the rules ---------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func) or ""
        self._rule_l001(node, chain)
        self._rule_l002(node, chain)
        self._rule_l003(node, chain)
        self._rule_l004(node, chain)
        self.generic_visit(node)

    def _rule_l001(self, node: ast.Call, chain: str) -> None:
        if not self.stage_building:
            return
        if chain.startswith("random."):
            self.findings.append(_finding(
                "unseeded-random", self.rel, self._qualname, node.lineno,
                chain,
                f"stdlib '{chain}(...)' in a stage-building module — all "
                f"randomness must come from a seeded generator"))
        elif chain.startswith(("np.random.", "numpy.random.")):
            leaf = chain.rsplit(".", 1)[1]
            if leaf in _SEEDED_FACTORIES and node.args:
                return          # np.random.RandomState(seed) — the idiom
            self.findings.append(_finding(
                "unseeded-random", self.rel, self._qualname, node.lineno,
                chain,
                f"'{chain}(...)' draws from (or seeds without an explicit "
                f"seed) the GLOBAL numpy RNG in a stage-building module"))
        elif chain.startswith("time.") and chain.split(".")[1] in _TIME_CALLS:
            self.findings.append(_finding(
                "host-time", self.rel, self._qualname, node.lineno, chain,
                f"wall-clock read '{chain}()' in a stage-building module — "
                f"clocks live in obs/ and launch/, or arrive injected"))

    def _rule_l002(self, node: ast.Call, chain: str) -> None:
        if not chain.endswith("frombuffer"):
            return
        if self.is_reader_module and READER_CLASS in self._class_stack:
            return
        self.findings.append(_finding(
            "frombuffer-outside-reader", self.rel, self._qualname,
            node.lineno, chain,
            f"'{chain}' outside mvec_format.{READER_CLASS} — raw bytes are "
            f"parsed only through the length-checked reader"))

    def _rule_l003(self, node: ast.Call, chain: str) -> None:
        if self._jit_depth <= 0:
            return
        parts = chain.split(".")
        if ((len(parts) >= 2 and parts[0] == "obs"
             and parts[-1] in _OBS_CALLS)
                or parts[-1] == "timed_span"
                or chain == "get_registry"):
            self.findings.append(_finding(
                "obs-in-jit", self.rel, self._qualname, node.lineno, chain,
                f"observability call '{chain}(...)' inside a jit-compiled "
                f"body: runs at trace time only (or breaks purity)"))

    def _rule_l004(self, node: ast.Call, chain: str) -> None:
        if self._jit_depth <= 0 or not self._fn_stack:
            return
        if chain not in ("jnp.asarray", "jnp.array"):
            return
        if not node.args or not isinstance(node.args[0], ast.Name):
            return
        name = node.args[0].id
        if name in _local_names(self._fn_stack[-1]):
            return
        self.findings.append(_finding(
            "stage-asarray", self.rel, self._qualname, node.lineno,
            f"{chain}({name})",
            f"'{chain}({name})' converts the closure-captured '{name}' "
            f"inside a jit body — it bakes in as a trace constant; pass it "
            f"as a stage argument instead"))


def lint_file(path: str, rel: str) -> List[Finding]:
    with open(path) as fh:
        tree = ast.parse(fh.read(), filename=path)
    linter = _ModuleLinter(rel, tree)
    linter.visit(tree)
    return linter.findings


def lint_tree(root: Optional[str] = None) -> List[Finding]:
    """Lint every module under src/repro (analysis excluded — it is the
    checker, and its only 'violations' are the patterns it documents)."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__", "analysis"))
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            findings.extend(lint_file(path, rel))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import json as _json

    from .findings import Allowlist, load_allowlist, render_report

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST invariant linter over src/repro")
    default_allow = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "allowlist.json")
    parser.add_argument("--allowlist", default=default_allow)
    parser.add_argument("--root", default=None,
                        help="package root to lint (default: src/repro)")
    args = parser.parse_args(argv)

    allow = (load_allowlist(args.allowlist)
             if os.path.exists(args.allowlist) else Allowlist())
    findings = lint_tree(args.root)
    # Lint shares the audit allowlist but must not call ITS unmatched
    # entries stale — the jaxpr checks own those.
    report = render_report(findings, allow, stale_is_error=False)
    for f in report["findings"]:
        mark = "ALLOWED" if f["allowlisted"] else "ERROR  "
        print(f"{mark} {f['check']:26s} {f['site']}\n        {f['detail']}")
    active = report["counts"]["active"]
    print(_json.dumps({"ok": active == 0, "counts": report["counts"]}))
    return 0 if active == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""The declarative invariant registry: DESIGN.md contract -> enforcing checks.

Each ``Invariant`` names one clause of the determinism contract and lists
the check codes (jaxpr_audit.CHECKS and lint.RULES) that enforce it
mechanically.  Findings cite the invariant they break, so an AUDIT_REPORT
line reads as "which promise in DESIGN.md did this code violate", not just
"which pattern matched".  DESIGN.md §10 renders this registry as a table.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .findings import Finding


@dataclasses.dataclass(frozen=True)
class Invariant:
    id: str
    design_ref: str
    summary: str
    checks: Tuple[str, ...]


INVARIANTS: Tuple[Invariant, ...] = (
    Invariant(
        id="INV-ARGS-NOT-CONSTS",
        design_ref="DESIGN.md §7.2",
        summary=(
            "Every corpus-scale or arbitrary-valued array (packed codes, "
            "qnorms, CSR, graph tables, masks, perm, predicate keys) is a "
            "stage ARGUMENT, never a closure constant: XLA constant-folds "
            "captured arrays and folded float arithmetic is not guaranteed "
            "bit-identical to the runtime op sequence.  Exempt: scalars, "
            "uniform fills, integer iotas, and seeded ±1/0 factors (the "
            "RHDH signs and Hadamard blocks — exact under multiplication, "
            "and their seed is part of the plan fingerprint)."),
        checks=("const-array", "stage-asarray"),
    ),
    Invariant(
        id="INV-CHUNKED-DOT",
        design_ref="DESIGN.md §5, §7.3",
        summary=(
            "Full-corpus float dots run in fixed 8-row query chunks behind "
            "an optimization_barrier (kernels/ref.py) or inside the Pallas "
            "kernel's fixed tiling: XLA's dot strategy — and hence the last "
            "ulp — otherwise varies with the batch shape.  Full-corpus "
            "float reductions outside that structure are flagged too."),
        checks=("full-scan-dot", "full-reduce"),
    ),
    Invariant(
        id="INV-NO-X64",
        design_ref="DESIGN.md §8",
        summary=(
            "No 64-bit float/int values inside a compiled stage: JAX runs "
            "with x64 disabled, and the u64 predicate keys are lowered to "
            "uint32 (hi, lo) planes precisely so device masks match the "
            "host oracle without x64.  A float64/int64/uint64 aval in a "
            "stage jaxpr means an implicit-x64 or dtype-widening leak."),
        checks=("x64-leak",),
    ),
    Invariant(
        id="INV-NO-HOST-IN-TRACE",
        design_ref="DESIGN.md §9",
        summary=(
            "Host-side effects never enter a traced function: no pure/io/"
            "debug callbacks or live RNG primitives inside stage jaxprs, no "
            "timed_span/registry calls or time.* reads inside jit-decorated "
            "bodies (obs timers wrap the CALL to a compiled stage — bit-"
            "identity with tracing on/off is asserted on raw bytes)."),
        checks=("callback-prim", "rng-prim", "obs-in-jit", "host-time"),
    ),
    Invariant(
        id="INV-SEEDED-RANDOMNESS",
        design_ref="DESIGN.md §2, §6",
        summary=(
            "All randomness is seeded and replayable: stage-building "
            "modules never call unseeded random.* / np.random.* — segment "
            "seeds derive from (root, ordinal) and the RHDH sign stream "
            "from the header seed, so the same op sequence reproduces the "
            "same packed bytes on any platform."),
        checks=("unseeded-random",),
    ),
    Invariant(
        id="INV-READER-VALIDATES",
        design_ref="DESIGN.md §6",
        summary=(
            ".mvec bytes are parsed only through mvec_format._Reader, which "
            "length-checks every block before np.frombuffer sees it; a "
            "frombuffer anywhere else can misparse a truncated file into "
            "silently-wrong (but deterministic-looking) arrays."),
        checks=("frombuffer-outside-reader",),
    ),
    Invariant(
        id="INV-ZERO-RETRACE",
        design_ref="DESIGN.md §7.1",
        summary=(
            "Same plan key ⇒ zero retraces, and no tracer leaks out of a "
            "stage: the audit replays a small plan grid under "
            "jax.checking_leaks and fails on any unexpected trace."),
        checks=("unexpected-retrace", "tracer-leak"),
    ),
    Invariant(
        id="INV-STAGE-COVERAGE",
        design_ref="DESIGN.md §10",
        summary=(
            "Every stage factory a module exports via PLAN_STAGES is "
            "actually captured by the audit grid — a new stage cannot ship "
            "outside the auditor's view."),
        checks=("uncovered-stage",),
    ),
)


_BY_CHECK: Dict[str, Invariant] = {
    check: inv for inv in INVARIANTS for check in inv.checks
}


def invariant_for_check(check: str) -> Optional[Invariant]:
    return _BY_CHECK.get(check)


def annotate(finding: Finding) -> Finding:
    """Return a copy of ``finding`` citing the invariant its check enforces."""
    inv = invariant_for_check(finding.check)
    if inv is None:
        return finding
    return dataclasses.replace(finding, invariant=inv.id,
                               design_ref=inv.design_ref)

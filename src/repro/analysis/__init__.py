# repro.analysis (DESIGN.md §10): the machine-checked determinism contract.
#
# MonaVec's headline guarantee — byte-identical results everywhere — rests on
# invariants that used to live only in DESIGN.md prose and example-based
# tests (arrays are staged as arguments, never closure constants; full-scan
# dots run in fixed 8-row chunks behind an optimization_barrier; host-side
# timers never enter a traced function; predicate constants ride as dynamic
# args).  This package checks them mechanically on every commit:
#
#   * jaxpr_audit  — traces every registered SearchPlan stage across a
#                    backend × metric × bits × lifecycle grid and flags
#                    determinism hazards in the ClosedJaxprs;
#   * invariants   — the declarative registry mapping each DESIGN.md
#                    contract to the checks that enforce it;
#   * lint         — AST-level source rules the jaxpr cannot see;
#   * audit        — the CLI (`python -m repro.analysis.audit`) emitting
#                    AUDIT_REPORT.json against the committed allowlist.

from .findings import (Allowlist, Finding, fingerprint, load_allowlist,
                       render_report)
from .invariants import INVARIANTS, Invariant, invariant_for_check
from .jaxpr_audit import StageCapture, audit_captures, audit_jaxpr

__all__ = [
    "Allowlist", "Finding", "INVARIANTS", "Invariant", "StageCapture",
    "audit_captures", "audit_jaxpr", "fingerprint", "invariant_for_check",
    "load_allowlist", "render_report",
]

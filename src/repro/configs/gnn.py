"""Assigned GNN architecture: gin-tu [arXiv:1810.00826]."""

from __future__ import annotations

from repro.models.gnn import GINConfig

from .registry import GNN_SHAPES, Arch, register


def gin_tu() -> GINConfig:
    # n_layers=5 d_hidden=64 aggregator=sum eps=learnable.  d_feat/n_classes
    # are per-shape (cora-like / reddit-like / products-like / molecule);
    # the dry-run instantiates the right head per shape spec.
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64,
                     d_feat=1433, n_classes=7)


def gin_smoke() -> GINConfig:
    return GINConfig(name="gin-smoke", n_layers=3, d_hidden=16, d_feat=8,
                     n_classes=3)


register(Arch(
    arch_id="gin-tu", family="gnn", make_config=gin_tu, make_smoke=gin_smoke,
    shapes=GNN_SHAPES,
    notes=("The paper's ANN-scoring technique is inapplicable to message "
           "passing itself (DESIGN.md §4); GIN runs WITHOUT it.  Trained node "
           "embeddings can be indexed by MonaVec post-hoc (examples/).  "
           "Sampled minibatch mode uses depth=len(fanout)=2 aggregation "
           "blocks per the assigned fanout 15-10."),
))

"""Assigned RecSys architecture configs."""

from __future__ import annotations

from repro.models.recsys import DIENConfig, DLRMConfig, FMConfig, TwoTowerConfig

from .registry import RECSYS_SHAPES, Arch, register


# -- dien [arXiv:1809.03672] -------------------------------------------------

def dien() -> DIENConfig:
    return DIENConfig(name="dien", embed_dim=18, seq_len=100, gru_dim=108,
                      mlp=(200, 80), item_vocab=1 << 20, cat_vocab=1 << 14)


def dien_smoke() -> DIENConfig:
    return DIENConfig(name="dien-smoke", embed_dim=8, seq_len=12, gru_dim=16,
                      mlp=(32, 16), item_vocab=256, cat_vocab=32)


register(Arch(
    arch_id="dien", family="recsys", make_config=dien, make_smoke=dien_smoke,
    shapes=RECSYS_SHAPES,
    notes="retrieval_cand broadcasts one user history against 1M target items "
          "(AUGRU re-evolved per candidate — the DIEN scoring semantics).",
))


# -- dlrm-rm2 [arXiv:1906.00091] ----------------------------------------------

def dlrm() -> DLRMConfig:
    return DLRMConfig(name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
                      vocab_sizes=tuple([1 << 20] * 26),
                      bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1))


def dlrm_smoke() -> DLRMConfig:
    return DLRMConfig(name="dlrm-smoke", n_dense=13, n_sparse=4, embed_dim=8,
                      vocab_sizes=(64, 64, 64, 64), bot_mlp=(16, 8),
                      top_mlp=(16, 8, 1))


register(Arch(
    arch_id="dlrm-rm2", family="recsys", make_config=dlrm, make_smoke=dlrm_smoke,
    shapes=RECSYS_SHAPES,
))


# -- two-tower-retrieval [RecSys'19 (YouTube)] -------------------------------

def two_tower() -> TwoTowerConfig:
    return TwoTowerConfig(name="two-tower-retrieval", embed_dim=256,
                          tower_mlp=(1024, 512, 256),
                          user_vocab=1 << 21, item_vocab=1 << 21)


def two_tower_smoke() -> TwoTowerConfig:
    return TwoTowerConfig(name="two-tower-smoke", embed_dim=16,
                          tower_mlp=(32, 16), user_vocab=512, item_vocab=512,
                          n_user_feats=4)


register(Arch(
    arch_id="two-tower-retrieval", family="recsys", make_config=two_tower,
    make_smoke=two_tower_smoke, shapes=RECSYS_SHAPES,
    notes="retrieval_cand is the paper's own setting at scale: candidate "
          "scoring dispatches to the MonaVec 4-bit packed scan "
          "(dist.retrieval), with the f32 matmul as the exact baseline.",
))


# -- fm [ICDM'10 (Rendle)] -----------------------------------------------------

def fm() -> FMConfig:
    return FMConfig(name="fm", n_sparse=39, embed_dim=10,
                    vocab_sizes=tuple([1 << 18] * 39))


def fm_smoke() -> FMConfig:
    return FMConfig(name="fm-smoke", n_sparse=6, embed_dim=4,
                    vocab_sizes=tuple([64] * 6))


register(Arch(
    arch_id="fm", family="recsys", make_config=fm, make_smoke=fm_smoke,
    shapes=RECSYS_SHAPES,
))

"""Assigned LM-family architecture configs (exact, from public literature)."""

from __future__ import annotations

from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

from .registry import LM_SHAPES, Arch, register

_FULL_ATTN_SKIP = (
    "long_500k requires sub-quadratic attention; this arch is a pure "
    "full-attention stack (skip noted in DESIGN.md §Arch-applicability)."
)


# -- gemma2-2b [arXiv:2408.00118]: local+global alternating, logit softcaps --

def gemma2_2b() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-2b", n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        head_dim=256, d_ff=9216, vocab=256000, rope_theta=10000.0,
        attn_softcap=50.0, final_softcap=30.0,
        window=4096, window_pattern="alternate", post_norms=True,
        embed_scale=True, tie_embeddings=True,
    )


def gemma2_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=512, attn_softcap=50.0, final_softcap=30.0,
        window=8, window_pattern="alternate", post_norms=True, embed_scale=True,
        tie_embeddings=True, dtype="float32",
    )


register(Arch(
    arch_id="gemma2-2b", family="lm", make_config=gemma2_2b,
    make_smoke=gemma2_smoke, shapes=LM_SHAPES,
    notes=("long_500k RUNS for this arch: 13/26 layers are 4k sliding-window "
           "(local+global hybrid); decode attends a sequence-sharded cache."),
))


# -- qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: MHA with QKV bias ------------------

def qwen15_05b() -> TransformerConfig:
    return TransformerConfig(
        name="qwen1.5-0.5b", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=2816, vocab=151936,
        rope_theta=1_000_000.0, qkv_bias=True, tie_embeddings=True,
    )


def qwen_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=160, vocab=512, qkv_bias=True, dtype="float32",
    )


register(Arch(
    arch_id="qwen1.5-0.5b", family="lm", make_config=qwen15_05b,
    make_smoke=qwen_smoke, shapes=LM_SHAPES,
    skips={"long_500k": _FULL_ATTN_SKIP},
))


# -- llama3.2-3b [hf:meta-llama]: GQA kv=8 ------------------------------------

def llama32_3b() -> TransformerConfig:
    return TransformerConfig(
        name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=128256, rope_theta=500_000.0,
        tie_embeddings=True,
    )


def llama_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="llama-smoke", n_layers=3, d_model=96, n_heads=6, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab=512, dtype="float32",
    )


register(Arch(
    arch_id="llama3.2-3b", family="lm", make_config=llama32_3b,
    make_smoke=llama_smoke, shapes=LM_SHAPES,
    skips={"long_500k": _FULL_ATTN_SKIP},
))


# -- deepseek-v3-671b [arXiv:2412.19437]: MLA + 1 shared + 256 routed top-8 +
#    MTP; first 3 layers dense (d_ff 18432), aux-loss-free sigmoid router ----

def deepseek_v3() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
        rope_theta=10000.0, tie_embeddings=False,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                      router="sigmoid", capacity_factor=1.25,
                      first_dense_layers=3),
        mtp=True,
    )


def deepseek_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512, tie_embeddings=False,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      router="sigmoid", first_dense_layers=1),
        mtp=True, dtype="float32",
    )


register(Arch(
    arch_id="deepseek-v3-671b", family="lm", make_config=deepseek_v3,
    make_smoke=deepseek_smoke, shapes=LM_SHAPES,
    skips={"long_500k": _FULL_ATTN_SKIP},
    notes="optimizer state kept in bf16 for the dry-run memory budget "
          "(EXPERIMENTS.md §Dry-run).",
))


# -- olmoe-1b-7b [arXiv:2409.02060]: 64 experts top-8, all layers MoE --------

def olmoe() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1024, vocab=50304,
        rope_theta=10000.0, tie_embeddings=False,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                      router="softmax", capacity_factor=1.25),
    )


def olmoe_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="olmoe-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=64, vocab=512, tie_embeddings=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, router="softmax"),
        dtype="float32",
    )


register(Arch(
    arch_id="olmoe-1b-7b", family="lm", make_config=olmoe,
    make_smoke=olmoe_smoke, shapes=LM_SHAPES,
    skips={"long_500k": _FULL_ATTN_SKIP},
))

"""The paper's own workload as a first-class arch: monavec-scan.

Distributed 4-bit brute-force retrieval (corpus sharded over the mesh, packed
scan + local top-k + global top-k).  The corpus sizes sweep from the paper's
AG News (45K) to production scale (1B vectors — only viable because of the
8x quantization, the paper's §4.5 'scaling argument').
"""

from __future__ import annotations

import dataclasses

from .registry import Arch, ShapeSpec, register


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    name: str = "monavec-scan"
    dim: int = 1024                 # BGE-M3 embedding dim (paper's AG News)
    bits: int = 4
    metric: str = "cosine"
    k: int = 10


def monavec_scan() -> RetrievalConfig:
    return RetrievalConfig()


def monavec_smoke() -> RetrievalConfig:
    return RetrievalConfig(name="monavec-smoke", dim=128)


MONAVEC_SHAPES = (
    ShapeSpec("agnews_45k", "mv_scan", {"n_corpus": 45_056, "batch_q": 256}),
    ShapeSpec("glove_1m", "mv_scan", {"n_corpus": 1_179_648, "batch_q": 256}),
    ShapeSpec("corpus_100m", "mv_scan", {"n_corpus": 100_663_296, "batch_q": 256}),
    ShapeSpec("corpus_1b", "mv_scan", {"n_corpus": 1_073_741_824, "batch_q": 64}),
)

register(Arch(
    arch_id="monavec-scan", family="retrieval", make_config=monavec_scan,
    make_smoke=monavec_smoke, shapes=MONAVEC_SHAPES,
    notes="The paper's technique itself as a distributed serving workload; "
          "supplementary to the 40 assigned cells.",
))

# Architecture configs: importing this package populates the registry.
from . import lm, gnn, recsys, retrieval  # noqa: F401
from .registry import Arch, ShapeSpec, all_archs, cells, get  # noqa: F401

"""Architecture registry: ``--arch <id>`` resolves here.

Each Arch bundles the exact assigned full config (dry-run only — instantiated
as ShapeDtypeStructs, never allocated), a reduced smoke config (instantiated
on CPU in tests), and its assigned input-shape set.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train|prefill|decode|full_graph|minibatch|graphs|recsys_train|recsys_serve|retrieval
    dims: Mapping[str, int]


@dataclasses.dataclass(frozen=True)
class Arch:
    arch_id: str
    family: str                  # lm|gnn|recsys|retrieval
    make_config: Callable[[], object]
    make_smoke: Callable[[], object]
    shapes: Tuple[ShapeSpec, ...]
    skips: Mapping[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""


_REGISTRY: Dict[str, Arch] = {}


def register(arch: Arch) -> Arch:
    assert arch.arch_id not in _REGISTRY, f"duplicate arch {arch.arch_id}"
    _REGISTRY[arch.arch_id] = arch
    return arch


def get(arch_id: str) -> Arch:
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}") from None


def all_archs() -> Dict[str, Arch]:
    return dict(_REGISTRY)


def cells(include_skipped: bool = False):
    """Every (arch, shape) dry-run cell, optionally including documented skips."""
    out = []
    for arch in _REGISTRY.values():
        for s in arch.shapes:
            if s.name in arch.skips and not include_skipped:
                continue
            out.append((arch, s))
    return out


# Shared LM shape set (assigned): seq_len x global_batch.
LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "full_graph",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7}),
    ShapeSpec("minibatch_lg", "minibatch",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout0": 15, "fanout1": 10, "d_feat": 602, "n_classes": 41}),
    ShapeSpec("ogb_products", "full_graph",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
               "n_classes": 47}),
    ShapeSpec("molecule", "graphs",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
               "n_classes": 2}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "recsys_train", {"batch": 65536}),
    ShapeSpec("serve_p99", "recsys_serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "recsys_serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

"""Pure-jnp oracles for every Pallas kernel in this package.

These mirror the kernels' semantics with no packing tricks or fused dequant —
the simplest possible correct implementation.  All kernel tests
assert_allclose against these.

The full-corpus dots run ROW-CHUNKED (fixed 8-query blocks via ``lax.map``):
XLA's dot emitter may pick a different reduction strategy per operand shape,
so a plain ``[b, d] @ [d, n]`` matmul can return different last-ulp results
for the SAME query row at different batch sizes (observed on the CPU backend
with tiny ``n``).  Fixing the chunk shape makes every row's score a pure
function of (row, corpus) regardless of batch composition — the property the
engine's shape-bucketed plans (DESIGN.md §7) and the eager oracles both rely
on, and the same 8-row granularity the Pallas kernel's ``block_q`` tiling
already has.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lloydmax
from repro.core.quantize import unpack_2bit, unpack_4bit
from repro.core.rhdh import hadamard_matrix

_ROW_CHUNK = 8


def _chunked_dot(q_rot: jnp.ndarray, deq_t: jnp.ndarray) -> jnp.ndarray:
    """[b, d] @ [d, n] in fixed [8, d] query chunks (batch-size-stable).

    The optimization barrier is load-bearing: without it XLA folds
    pad -> single-trip map -> slice back into an unpadded [b, d] dot and the
    shape-dependent strategy choice returns.  With it, every chunk runs the
    SAME [8, d] @ [d, n] program regardless of b.
    """
    b = q_rot.shape[0]
    b_pad = ((b + _ROW_CHUNK - 1) // _ROW_CHUNK) * _ROW_CHUNK
    qp = jnp.pad(q_rot, ((0, b_pad - b), (0, 0)))
    chunks = qp.reshape(b_pad // _ROW_CHUNK, _ROW_CHUNK, q_rot.shape[1])
    chunks = jax.lax.optimization_barrier(chunks)
    out = jax.lax.map(lambda qc: qc @ deq_t, chunks)
    return out.reshape(b_pad, deq_t.shape[1])[:b]


def nibble_dot_ref(packed: jnp.ndarray, q_rot: jnp.ndarray) -> jnp.ndarray:
    """[n, d/2] packed uint8, [b, d] rotated f32 query -> [b, n] raw scores."""
    codes = unpack_4bit(packed)                       # [n, d]
    deq = lloydmax.dequantize(codes, 4)               # [n, d] f32
    return _chunked_dot(q_rot, deq.T)


def crumb_dot_ref(packed: jnp.ndarray, q_rot: jnp.ndarray) -> jnp.ndarray:
    """[n, d/4] packed uint8 (2-bit codes), [b, d] query -> [b, n]."""
    codes = unpack_2bit(packed)
    deq = lloydmax.dequantize(codes, 2)
    return _chunked_dot(q_rot, deq.T)


def mixed_dot_ref(
    packed: jnp.ndarray, q_rot: jnp.ndarray, n4_dims: int
) -> jnp.ndarray:
    """Mixed [4-bit block | 2-bit block] layout (paper §3.2)."""
    b4 = n4_dims // 2
    s4 = nibble_dot_ref(packed[:, :b4], q_rot[:, :n4_dims])
    s2 = crumb_dot_ref(packed[:, b4:], q_rot[:, n4_dims:])
    return s4 + s2


def gather_nibble_dot_ref(
    packed: jnp.ndarray, q_rot: jnp.ndarray, cand: jnp.ndarray
) -> jnp.ndarray:
    """Gathered candidate scoring oracle: [n, d/2] packed, [b, d] queries,
    [b, mc] row indices -> [b, mc] raw scores of row cand[b, i] vs query b."""
    pr = jnp.take(packed, cand, axis=0)               # [b, mc, d/2]
    deq = lloydmax.dequantize(unpack_4bit(pr), 4)     # [b, mc, d]
    return jnp.einsum("bmd,bd->bm", deq, q_rot)


def gather_crumb_dot_ref(
    packed: jnp.ndarray, q_rot: jnp.ndarray, cand: jnp.ndarray
) -> jnp.ndarray:
    pr = jnp.take(packed, cand, axis=0)               # [b, mc, d/4]
    deq = lloydmax.dequantize(unpack_2bit(pr), 2)
    return jnp.einsum("bmd,bd->bm", deq, q_rot)


def gather_mixed_dot_ref(
    packed: jnp.ndarray, q_rot: jnp.ndarray, cand: jnp.ndarray, n4_dims: int
) -> jnp.ndarray:
    b4 = n4_dims // 2
    s4 = gather_nibble_dot_ref(packed[:, :b4], q_rot[:, :n4_dims], cand)
    s2 = gather_crumb_dot_ref(packed[:, b4:], q_rot[:, n4_dims:], cand)
    return s4 + s2


def hadamard_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Direct H @ x on the last axis (unnormalized), O(d^2) oracle."""
    d = x.shape[-1]
    H = jnp.asarray(hadamard_matrix(d))
    return x @ H.T

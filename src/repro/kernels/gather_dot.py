"""Pallas TPU kernel: gathered candidate-set scoring (IVF/HNSW hot path).

The non-brute backends (paper §3.4.2/§3.4.3) score per-query CANDIDATE subsets
of the corpus: query ``b`` is scored only against rows ``cand[b, :]``.  The
full-corpus ``nibble_dot`` kernel cannot express this (its packed operand is
shared by every query), so this kernel scores pre-gathered per-query candidate
matrices ``[b, mc, bytes]`` directly from packed nibbles/crumbs — the candidate
gather stays in the uint8 packed domain (preserving the paper's 8× memory
edge), and the compare-select dequant is fused into the dot so no
``[b, mc, d']`` f32 tensor ever materializes.

Structure shared with ``nibble_dot`` (DESIGN.md §2): compare-select dequant
(no VPU gather, centroids as immediates), deinterleaved query planes (no
minor-dim shuffle), fixed accumulation order over packed-dim blocks.

The per-(query, candidate-tile, k-tile) computation lives in ``_nibble_tile``
/ ``_crumb_tile`` and is shared VERBATIM by the kernel body and by the
pure-jnp mirrors (``gather_nibble_dot_jnp`` / ``gather_crumb_dot_jnp``), which
iterate the exact same (b-chunk, m-tile, k-tile) grid in the same order.  That
makes the non-kernel path bit-identical to the interpret-mode kernel — the
property the ``use_kernel`` contract tests assert on IVF/HNSW search results.

VMEM (defaults bb=8, bm=256, bk=256 packed bytes):
  gathered  8*256*256          = 512 KiB
  deq lo/hi 2 * 8*256*256*4    =   4 MiB (transient, per select tree)
  planes    2 * 8*256*4        =  16 KiB
  out       8*256*4            =   8 KiB      -> well under 16 MiB VMEM.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nibble_dot import _TABLE2, _TABLE4, _dequant_select


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def gather_blocks(b: int, m: int, dk: int) -> Tuple[int, int, int]:
    """Block sizes (bb, bm, bk) for a [b, m, dk] gathered-candidate scoring.

    A pure function of the operand shape: the kernel wrapper AND the jnp
    mirror both derive their tiling from here, which is what keeps the two
    paths bit-identical (same tile shapes -> same dot reductions).
    """
    bb = b if b < 8 else 8
    bm = _round_up(m, 8) if m < 256 else 256
    bk = min(256, _round_up(dk, 128))
    return bb, bm, bk


def _nibble_tile(g: jnp.ndarray, q_even: jnp.ndarray, q_odd: jnp.ndarray) -> jnp.ndarray:
    """One candidate tile for one query: [bm, bk] uint8 × 2×[bk] f32 -> [bm].

    Nibble 2i is the low half of byte i, nibble 2i+1 the high half, so
    ``deq(lo) @ q_even + deq(hi) @ q_odd`` is the exact dot product.
    """
    lo = (g & 0xF).astype(jnp.int32)
    hi = (g >> 4).astype(jnp.int32)
    part = jnp.dot(_dequant_select(lo, _TABLE4), q_even,
                   preferred_element_type=jnp.float32)
    part += jnp.dot(_dequant_select(hi, _TABLE4), q_odd,
                    preferred_element_type=jnp.float32)
    return part


def _crumb_tile(g: jnp.ndarray, q0, q1, q2, q3) -> jnp.ndarray:
    """2-bit variant: four crumbs per byte, four deinterleaved planes."""
    part = jnp.zeros((g.shape[0],), jnp.float32)
    for shift, q in ((0, q0), (2, q1), (4, q2), (6, q3)):
        codes = ((g >> shift) & 0x3).astype(jnp.int32)
        part += jnp.dot(_dequant_select(codes, _TABLE2), q,
                        preferred_element_type=jnp.float32)
    return part


# Batched over the in-block query chunk: [bb, bm, bk] × [bb, bk] -> [bb, bm].
_nibble_tile_b = jax.vmap(_nibble_tile)
_crumb_tile_b = jax.vmap(_crumb_tile)


def _gather_nibble_kernel(g_ref, q_even_ref, q_odd_ref, out_ref):
    """One (bb, bm) output tile, accumulating over the packed-dim grid axis."""
    kt = pl.program_id(2)
    part = _nibble_tile_b(g_ref[...], q_even_ref[...], q_odd_ref[...])

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = part

    @pl.when(kt > 0)
    def _acc():
        out_ref[...] += part


def _gather_crumb_kernel(g_ref, q0_ref, q1_ref, q2_ref, q3_ref, out_ref):
    kt = pl.program_id(2)
    part = _crumb_tile_b(g_ref[...], q0_ref[...], q1_ref[...], q2_ref[...],
                         q3_ref[...])

    @pl.when(kt == 0)
    def _init():
        out_ref[...] = part

    @pl.when(kt > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_m", "block_k", "interpret")
)
def gather_nibble_dot_raw(
    gathered: jnp.ndarray,   # [b, mc, d'/2] uint8 — per-query candidate rows
    q_even: jnp.ndarray,     # [b, d'/2] f32 — rotated query dims 0,2,4,...
    q_odd: jnp.ndarray,      # [b, d'/2] f32 — rotated query dims 1,3,5,...
    *,
    block_b: int = 8,
    block_m: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw scores [b, mc]: row ``gathered[b, i]`` against query ``b``.

    Shapes must tile evenly (wrapper in ops.py pads).  interpret=True runs the
    kernel body on CPU for validation; on TPU pass interpret=False.
    """
    b, m, dk = gathered.shape
    assert q_even.shape == (b, dk) and q_odd.shape == (b, dk)
    assert b % block_b == 0 and m % block_m == 0 and dk % block_k == 0, (
        f"shapes ({b},{m},{dk}) must tile by ({block_b},{block_m},{block_k})"
    )
    grid = (b // block_b, m // block_m, dk // block_k)

    return pl.pallas_call(
        _gather_nibble_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_m, block_k), lambda i, j, k: (i, j, k)),
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(gathered, q_even, q_odd)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_m", "block_k", "interpret")
)
def gather_crumb_dot_raw(
    gathered: jnp.ndarray,   # [b, mc, d/4] uint8
    q_planes: jnp.ndarray,   # [4, b, d/4] f32
    *,
    block_b: int = 8,
    block_m: int = 256,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, m, dk = gathered.shape
    assert q_planes.shape == (4, b, dk)
    assert b % block_b == 0 and m % block_m == 0 and dk % block_k == 0
    grid = (b // block_b, m // block_m, dk // block_k)

    return pl.pallas_call(
        _gather_crumb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_m, block_k), lambda i, j, k: (i, j, k)),
        ] + [
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k))
            for _ in range(4)
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(gathered, q_planes[0], q_planes[1], q_planes[2], q_planes[3])


# ---------------------------------------------------------------------------
# Pure-jnp mirrors: the non-kernel production path (XLA-fused on CPU/GPU).
# Same tile function, same grid order as the kernel -> bit-identical output.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "block_k"))
def gather_nibble_dot_jnp(
    gathered: jnp.ndarray,
    q_even: jnp.ndarray,
    q_odd: jnp.ndarray,
    *,
    block_b: int = 8,
    block_m: int = 256,
    block_k: int = 256,
) -> jnp.ndarray:
    b, m, dk = gathered.shape
    assert b % block_b == 0 and m % block_m == 0 and dk % block_k == 0
    brows = []
    for i in range(b // block_b):
        bs = slice(i * block_b, (i + 1) * block_b)
        cols = []
        for j in range(m // block_m):
            ms = slice(j * block_m, (j + 1) * block_m)
            acc = jnp.zeros((block_b, block_m), jnp.float32)
            for kt in range(dk // block_k):
                ks = slice(kt * block_k, (kt + 1) * block_k)
                acc = acc + _nibble_tile_b(
                    gathered[bs, ms, ks], q_even[bs, ks], q_odd[bs, ks]
                )
            cols.append(acc)
        brows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(brows, axis=0)


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "block_k"))
def gather_crumb_dot_jnp(
    gathered: jnp.ndarray,
    q_planes: jnp.ndarray,
    *,
    block_b: int = 8,
    block_m: int = 256,
    block_k: int = 128,
) -> jnp.ndarray:
    b, m, dk = gathered.shape
    assert b % block_b == 0 and m % block_m == 0 and dk % block_k == 0
    brows = []
    for i in range(b // block_b):
        bs = slice(i * block_b, (i + 1) * block_b)
        cols = []
        for j in range(m // block_m):
            ms = slice(j * block_m, (j + 1) * block_m)
            acc = jnp.zeros((block_b, block_m), jnp.float32)
            for kt in range(dk // block_k):
                ks = slice(kt * block_k, (kt + 1) * block_k)
                acc = acc + _crumb_tile_b(
                    gathered[bs, ms, ks],
                    q_planes[0, bs, ks], q_planes[1, bs, ks],
                    q_planes[2, bs, ks], q_planes[3, bs, ks],
                )
            cols.append(acc)
        brows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(brows, axis=0)

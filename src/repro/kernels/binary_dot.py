"""Pallas TPU kernel: binarized coarse-scan proxies (cascade stage 1).

ROADMAP's raw-speed path to 10M+ vectors per device is a training-free
binarized pre-filter in front of the 4-bit rescore ("From HNSW to
Information-Theoretic Binarization", PAPERS.md): the RHDH rotation already
conditions coordinates toward N(0,1), so a per-dimension sign bit (or the
two-bit Lloyd-Max code, the "crumb") is derivable from the packed nibbles
with no data pass — exactly the MonaVec contract.

Two proxies, both INTEGER-valued (DESIGN.md §11):

  * **sign**: proxy = -hamming(q_bits, v_bits).  The kernel XORs packed
    sign bytes and popcounts with a SWAR tree (shifts/ands/adds only — no
    ``lax.population_count``, which has no guaranteed Mosaic lowering, and
    no per-lane gather).  Hamming distance — not agreement count — is the
    accumulated quantity because a zero PAD byte XORs to 0 and contributes
    exactly 0, so k-padding is free, mirroring the nibble kernel's
    zero-plane padding argument.
  * **crumb**: proxy = sum_i L(cq_i) * L(cv_i) with the symmetric level
    map L(c) = 2c - 3 in {-3,-1,1,3}.  The codes are stored as two SIGN
    PLANES (hi bit plane then lo bit plane, each packed 8 dims/byte), and
    with c = 2h + l the product expands to a popcount identity per dim:

        L(a)L(b) = 16 h_a h_b + 8 h_a l_b + 8 l_a h_b + 4 l_a l_b
                   - 12 h_a - 6 l_a - 12 h_b - 6 l_b + 9

    so the pairwise part is four weighted AND+popcount passes (the same
    SWAR tree as the sign kernel), and the remaining terms are rank-1
    corrections — a per-row and a per-query popcount plus the constant
    ``9 d'`` — applied identically on both dispatch paths.

Because both proxies are exact integer arithmetic (associative), the
Pallas kernel and the chunked jnp mirror below are bit-identical BY
CONSTRUCTION for any block configuration — the property the cascade tests
pin.  The mirrors chunk the corpus rows through ``lax.map`` so the scan
never materializes an [b, n, d'/8] intermediate at 1M rows, and popcount
via a uint32 bitcast + ``lax.population_count`` (an order of magnitude
faster than the byte-wise SWAR tree under XLA, and exactly equal: both
count the same bits).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount8(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of a uint8 array (values 0..8) — the kernel-body form
    (Mosaic-safe: shifts/ands/adds only)."""
    x = x - ((x >> 1) & 0x55)
    x = (x & 0x33) + ((x >> 2) & 0x33)
    return (x + (x >> 4)) & 0x0F


def _to_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Bitcast the trailing byte axis to uint32 words ([..., w] -> [..., w/4]),
    zero-padding to a multiple of 4 bytes first (zero bytes carry 0 bits).

    The mirrors bitcast BEFORE broadcasting query against corpus: XOR/AND
    then run on 4x fewer elements and XLA fuses the popcount-sum into the
    same loop, instead of materializing an [b, n, d'/8] uint8 intermediate
    (measured ~50x on the 45k x 1024 scan)."""
    w = x.shape[-1]
    wp = -(-w // 4) * 4
    if wp != w:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, wp - w)])
    return jax.lax.bitcast_convert_type(
        x.reshape(x.shape[:-1] + (wp // 4, 4)), jnp.uint32)


def _pc_sum(x32: jnp.ndarray) -> jnp.ndarray:
    """Exact popcount-sum over the trailing uint32-word axis (int32)."""
    return jnp.sum(jax.lax.population_count(x32).astype(jnp.int32), axis=-1)


def _popcount_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Exact popcount-sum over the trailing byte axis (int32)."""
    return _pc_sum(_to_u32(x))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Sign proxy: XOR + popcount.
# ---------------------------------------------------------------------------

def _sign_hamming_kernel(cbits_ref, qbits_ref, out_ref):
    """One (bq, bn) int32 hamming tile, accumulating over packed-byte blocks."""
    k = pl.program_id(2)

    cbits = cbits_ref[...]                          # [bn, bk] uint8
    qbits = qbits_ref[...]                          # [bq, bk] uint8
    x = jnp.bitwise_xor(qbits[:, None, :], cbits[None, :, :])
    part = jnp.sum(_popcount8(x).astype(jnp.int32), axis=-1)   # [bq, bn]

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_n", "block_k", "interpret")
)
def sign_hamming_raw(
    cbits: jnp.ndarray,      # [n, d'/8] uint8 — packed corpus sign bits
    qbits: jnp.ndarray,      # [b, d'/8] uint8 — packed query sign bits
    *,
    block_q: int = 8,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Hamming distances [b, n] (int32).  Shapes must tile evenly (the
    wrapper in ops.py pads); zero pad bytes contribute exactly 0."""
    n, dk = cbits.shape
    b, dk2 = qbits.shape
    assert dk == dk2
    assert n % block_n == 0 and b % block_q == 0 and dk % block_k == 0, (
        f"shapes ({b},{n},{dk}) must tile by ({block_q},{block_n},{block_k})"
    )
    grid = (b // block_q, n // block_n, dk // block_k)

    return pl.pallas_call(
        _sign_hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(cbits, qbits)


def sign_hamming_jnp(
    cbits: jnp.ndarray,      # [n, d'/8] uint8
    qbits: jnp.ndarray,      # [b, d'/8] uint8
    *,
    row_chunk: int = 65536,
) -> jnp.ndarray:
    """jnp mirror of the sign kernel: bit-identical (integer arithmetic is
    exact under any evaluation order).  Corpus rows stream through lax.map
    in fixed-size chunks so the XOR intermediate stays [b, chunk, d'/8]."""
    n = cbits.shape[0]
    b = qbits.shape[0]
    c32 = _to_u32(cbits)                            # [n, w] uint32
    q32 = _to_u32(qbits)                            # [b, w] uint32
    w = c32.shape[-1]

    def one(c):
        return _pc_sum(jnp.bitwise_xor(q32[:, None, :], c[None, :, :]))

    if n <= row_chunk:
        return one(c32)
    n_pad = _round_up(n, row_chunk)
    chunks = jnp.pad(c32, ((0, n_pad - n), (0, 0)))
    chunks = chunks.reshape(n_pad // row_chunk, row_chunk, w)
    out = jax.lax.map(one, chunks)                  # [nc, b, chunk]
    return jnp.moveaxis(out, 0, 1).reshape(b, n_pad)[:, :n]


# ---------------------------------------------------------------------------
# Crumb proxy: plane AND + popcount with rank-1 corrections.
# ---------------------------------------------------------------------------

def _crumb_corrections(
    chi: jnp.ndarray,        # [n, d'/8] uint8 — corpus hi plane
    clo: jnp.ndarray,        # [n, d'/8] uint8 — corpus lo plane
    qhi: jnp.ndarray,        # [b, d'/8] uint8 — query hi plane
    qlo: jnp.ndarray,        # [b, d'/8] uint8 — query lo plane
    dim: int,
) -> jnp.ndarray:
    """The rank-1 part of the popcount identity, broadcast to [b, n] int32:
    ``9 d' - 12 pc(qhi) - 6 pc(qlo) - 12 pc(chi) - 6 pc(clo)``.  Computed
    by ONE shared function so both dispatch paths add identical integers;
    zero pad rows/bytes popcount to 0, so padding never perturbs it."""
    row = 12 * _popcount_sum(chi) + 6 * _popcount_sum(clo)        # [n]
    qc = 12 * _popcount_sum(qhi) + 6 * _popcount_sum(qlo)         # [b]
    return (9 * dim - qc)[:, None] - row[None, :]


def _crumb_cross_kernel(chi_ref, clo_ref, qhi_ref, qlo_ref, out_ref):
    """One (bq, bn) int32 tile of the pairwise term: four weighted
    AND+popcount passes over the plane bytes (zero pad bytes AND to 0)."""
    k = pl.program_id(2)
    chi, clo = chi_ref[...], clo_ref[...]           # [bn, bk] uint8
    qhi, qlo = qhi_ref[...], qlo_ref[...]           # [bq, bk] uint8

    def pc(a):
        return jnp.sum(_popcount8(a).astype(jnp.int32), axis=-1)

    part = (16 * pc(qhi[:, None, :] & chi[None, :, :])
            + 8 * pc(qhi[:, None, :] & clo[None, :, :])
            + 8 * pc(qlo[:, None, :] & chi[None, :, :])
            + 4 * pc(qlo[:, None, :] & clo[None, :, :]))          # [bq, bn]

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(
    jax.jit,
    static_argnames=("dim", "block_q", "block_n", "block_k", "interpret"),
)
def crumb_affinity_raw(
    chi: jnp.ndarray,        # [n, d'/8] uint8 — corpus hi plane
    clo: jnp.ndarray,        # [n, d'/8] uint8 — corpus lo plane
    qhi: jnp.ndarray,        # [b, d'/8] uint8 — query hi plane
    qlo: jnp.ndarray,        # [b, d'/8] uint8 — query lo plane
    *,
    dim: int,
    block_q: int = 8,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Crumb affinities [b, n] (int32): the Pallas kernel accumulates the
    pairwise AND-popcount term; the rank-1 corrections are added outside
    the grid (they are per-row/per-query, not per-tile)."""
    n, dk = chi.shape
    b = qhi.shape[0]
    assert clo.shape == chi.shape and qlo.shape == qhi.shape == (b, dk)
    assert n % block_n == 0 and b % block_q == 0 and dk % block_k == 0, (
        f"shapes ({b},{n},{dk}) must tile by ({block_q},{block_n},{block_k})"
    )
    grid = (b // block_q, n // block_n, dk // block_k)

    corpus_spec = pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k))
    query_spec = pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k))
    cross = pl.pallas_call(
        _crumb_cross_kernel,
        grid=grid,
        in_specs=[corpus_spec, corpus_spec, query_spec, query_spec],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(chi, clo, qhi, qlo)
    return cross + _crumb_corrections(chi, clo, qhi, qlo, dim)


def crumb_affinity_jnp(
    chi: jnp.ndarray,        # [n, d'/8] uint8
    clo: jnp.ndarray,        # [n, d'/8] uint8
    qhi: jnp.ndarray,        # [b, d'/8] uint8
    qlo: jnp.ndarray,        # [b, d'/8] uint8
    *,
    dim: int,
    row_chunk: int = 65536,
) -> jnp.ndarray:
    """jnp mirror of the crumb kernel (bit-identical: exact popcounts and
    exact int32 sums on both paths).  Same chunked-row streaming as the
    sign mirror; the two corpus planes travel concatenated per chunk."""
    n = chi.shape[0]
    b = qhi.shape[0]
    chi32, clo32 = _to_u32(chi), _to_u32(clo)       # [n, w] uint32
    qhi32, qlo32 = _to_u32(qhi), _to_u32(qlo)       # [b, w] uint32
    w = chi32.shape[-1]

    def one(c):
        ch, cl = c[:, :w], c[:, w:]
        return (16 * _pc_sum(qhi32[:, None, :] & ch[None, :, :])
                + 8 * _pc_sum(qhi32[:, None, :] & cl[None, :, :])
                + 8 * _pc_sum(qlo32[:, None, :] & ch[None, :, :])
                + 4 * _pc_sum(qlo32[:, None, :] & cl[None, :, :]))

    both = jnp.concatenate([chi32, clo32], axis=-1)  # [n, 2 w]
    if n <= row_chunk:
        cross = one(both)
    else:
        n_pad = _round_up(n, row_chunk)
        chunks = jnp.pad(both, ((0, n_pad - n), (0, 0)))
        chunks = chunks.reshape(n_pad // row_chunk, row_chunk, 2 * w)
        out = jax.lax.map(one, chunks)              # [nc, b, chunk]
        cross = jnp.moveaxis(out, 0, 1).reshape(b, n_pad)[:, :n]
    return cross + _crumb_corrections(chi, clo, qhi, qlo, dim)

"""Jit'd public wrappers around the Pallas kernels.

``score_packed`` is the production scoring entry point: it handles padding to
block multiples, the deinterleaved-query trick, metric adjustment, and backend
dispatch (Pallas kernel on TPU / interpret-mode validation on CPU / pure-jnp
fallback that lowers cleanly under pjit on any backend — the analogue of the
paper's runtime SIMD dispatch, §3.7).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core.scoring import adjust_scores
from . import nibble_dot, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def deinterleave_query(q_rot: jnp.ndarray, ways: int) -> jnp.ndarray:
    """[b, d] -> [ways, b, d/ways]: plane p holds dims p, p+ways, p+2*ways, ..."""
    b, d = q_rot.shape
    return q_rot.reshape(b, d // ways, ways).transpose(2, 0, 1)


def nibble_score_raw(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw 4-bit scores [b, n]; pads to tile multiples and unpads the result.

    Dispatch (the paper's runtime-SIMD-dispatch analogue, §3.7): the Pallas
    kernel on TPU; elsewhere the pure-jnp reference (XLA-fused) — interpret
    mode executes the kernel body per grid cell in python and is for
    VALIDATION, not throughput.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_kernel:
        return ref.nibble_dot_ref(packed, q_rot)

    n, dk = packed.shape
    b = q_rot.shape[0]
    planes = deinterleave_query(q_rot, 2)             # [2, b, dk]

    bq = min(128, _round_up(b, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(256, dk)
    b_pad, n_pad = _round_up(b, bq), _round_up(n, bn)
    # k padding is safe: padded query planes are zero, so centroid(0) bytes
    # contribute exactly 0.  n/b padding is sliced off below.
    packed_p = jnp.pad(packed, ((0, n_pad - n), (0, 0)))
    planes_p = jnp.pad(planes, ((0, 0), (0, b_pad - b), (0, 0)))
    out = nibble_dot.nibble_dot_raw(
        packed_p, planes_p[0], planes_p[1],
        block_q=bq, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:b, :n]


def crumb_score_raw(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw 2-bit scores [b, n]."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_kernel:
        return ref.crumb_dot_ref(packed, q_rot)

    n, dk = packed.shape
    b = q_rot.shape[0]
    planes = deinterleave_query(q_rot, 4)             # [4, b, dk]
    bq = min(128, _round_up(b, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(128, dk)
    b_pad, n_pad = _round_up(b, bq), _round_up(n, bn)
    packed_p = jnp.pad(packed, ((0, n_pad - n), (0, 0)))
    planes_p = jnp.pad(planes, ((0, 0), (0, b_pad - b), (0, 0)))
    out = nibble_dot.crumb_dot_raw(
        packed_p, planes_p,
        block_q=bq, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:b, :n]


def score_raw(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    *,
    bits: int,
    n4_dims: int = 0,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw (un-adjusted) scores [b, n] for any bit mode, from raw arrays.

    The single bit-mode dispatch point — score_packed and the sharded scan
    (repro.dist.retrieval) both go through here, so the packed layout is
    interpreted identically on every path.
    """
    if bits == 4:
        return nibble_score_raw(packed, q_rot, use_kernel=use_kernel,
                                interpret=interpret)
    if bits == 2:
        return crumb_score_raw(packed, q_rot, use_kernel=use_kernel,
                               interpret=interpret)
    if bits == 3:  # mixed [4-bit | 2-bit]
        b4 = n4_dims // 2
        raw4 = nibble_score_raw(packed[:, :b4], q_rot[:, :n4_dims],
                                use_kernel=use_kernel, interpret=interpret)
        raw2 = crumb_score_raw(packed[:, b4:], q_rot[:, n4_dims:],
                               use_kernel=use_kernel, interpret=interpret)
        return raw4 + raw2
    raise ValueError(f"unsupported bits={bits}")


def score_packed(
    q_rot: jnp.ndarray,
    enc: qz.Encoded,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Metric-adjusted scores [b, n] for an Encoded corpus (any bit mode)."""
    raw = score_raw(enc.packed, q_rot, bits=enc.bits, n4_dims=enc.n4_dims,
                    use_kernel=use_kernel, interpret=interpret)
    return adjust_scores(raw, enc.qnorms, enc.metric)

"""Jit'd public wrappers around the Pallas kernels.

``score_packed`` is the production scoring entry point for FULL-corpus scans:
it handles padding to block multiples, the deinterleaved-query trick, metric
adjustment, and backend dispatch (Pallas kernel on TPU / interpret-mode
validation on CPU / pure-jnp fallback that lowers cleanly under pjit on any
backend — the analogue of the paper's runtime SIMD dispatch, §3.7).

``score_gathered`` is the same contract for CANDIDATE-SET scans (IVF probe
lists, HNSW frontiers; DESIGN.md §5): per-query row subsets scored directly
from the packed bytes, with the allowlist and validity masks applied before
any top-k.  Its non-kernel path mirrors the kernel's tile decomposition
exactly, so use_kernel=False and use_kernel=True/interpret=True return
bit-identical scores — the property the backend contract tests pin down.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core.allowlist import NEG
from repro.core.scoring import adjust_scores
from . import binary_dot, gather_dot, nibble_dot, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_dispatch(
    use_kernel: Optional[bool], interpret: Optional[bool]
) -> tuple:
    """Resolve the (use_kernel, interpret) pair exactly like score_packed:
    kernel on TPU, pure-jnp elsewhere; interpret mode only for validation."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    return use_kernel, interpret


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def deinterleave_query(q_rot: jnp.ndarray, ways: int) -> jnp.ndarray:
    """[b, d] -> [ways, b, d/ways]: plane p holds dims p, p+ways, p+2*ways, ..."""
    b, d = q_rot.shape
    return q_rot.reshape(b, d // ways, ways).transpose(2, 0, 1)


def nibble_score_raw(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw 4-bit scores [b, n]; pads to tile multiples and unpads the result.

    Dispatch (the paper's runtime-SIMD-dispatch analogue, §3.7): the Pallas
    kernel on TPU; elsewhere the pure-jnp reference (XLA-fused) — interpret
    mode executes the kernel body per grid cell in python and is for
    VALIDATION, not throughput.
    """
    use_kernel, interpret = resolve_dispatch(use_kernel, interpret)
    if not use_kernel:
        return ref.nibble_dot_ref(packed, q_rot)

    n, dk = packed.shape
    b = q_rot.shape[0]
    planes = deinterleave_query(q_rot, 2)             # [2, b, dk]

    bq = min(128, _round_up(b, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(256, dk)
    b_pad, n_pad = _round_up(b, bq), _round_up(n, bn)
    # k padding is safe: padded query planes are zero, so centroid(0) bytes
    # contribute exactly 0.  n/b padding is sliced off below.
    packed_p = jnp.pad(packed, ((0, n_pad - n), (0, 0)))
    planes_p = jnp.pad(planes, ((0, 0), (0, b_pad - b), (0, 0)))
    out = nibble_dot.nibble_dot_raw(
        packed_p, planes_p[0], planes_p[1],
        block_q=bq, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:b, :n]


def crumb_score_raw(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw 2-bit scores [b, n]."""
    use_kernel, interpret = resolve_dispatch(use_kernel, interpret)
    if not use_kernel:
        return ref.crumb_dot_ref(packed, q_rot)

    n, dk = packed.shape
    b = q_rot.shape[0]
    planes = deinterleave_query(q_rot, 4)             # [4, b, dk]
    bq = min(128, _round_up(b, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(128, dk)
    b_pad, n_pad = _round_up(b, bq), _round_up(n, bn)
    packed_p = jnp.pad(packed, ((0, n_pad - n), (0, 0)))
    planes_p = jnp.pad(planes, ((0, 0), (0, b_pad - b), (0, 0)))
    out = nibble_dot.crumb_dot_raw(
        packed_p, planes_p,
        block_q=bq, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:b, :n]


def score_raw(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    *,
    bits: int,
    n4_dims: int = 0,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw (un-adjusted) scores [b, n] for any bit mode, from raw arrays.

    The single bit-mode dispatch point — score_packed and the sharded scan
    (repro.dist.retrieval) both go through here, so the packed layout is
    interpreted identically on every path.
    """
    if bits == 4:
        return nibble_score_raw(packed, q_rot, use_kernel=use_kernel,
                                interpret=interpret)
    if bits == 2:
        return crumb_score_raw(packed, q_rot, use_kernel=use_kernel,
                               interpret=interpret)
    if bits == 3:  # mixed [4-bit | 2-bit]
        b4 = n4_dims // 2
        raw4 = nibble_score_raw(packed[:, :b4], q_rot[:, :n4_dims],
                                use_kernel=use_kernel, interpret=interpret)
        raw2 = crumb_score_raw(packed[:, b4:], q_rot[:, n4_dims:],
                               use_kernel=use_kernel, interpret=interpret)
        return raw4 + raw2
    raise ValueError(f"unsupported bits={bits}")


def score_packed(
    q_rot: jnp.ndarray,
    enc: qz.Encoded,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Metric-adjusted scores [b, n] for an Encoded corpus (any bit mode)."""
    raw = score_raw(enc.packed, q_rot, bits=enc.bits, n4_dims=enc.n4_dims,
                    use_kernel=use_kernel, interpret=interpret)
    return adjust_scores(raw, enc.qnorms, enc.metric)


# ---------------------------------------------------------------------------
# Binarized coarse-scan proxies (cascade stage 1; DESIGN.md §11).
# ---------------------------------------------------------------------------

def sign_coarse_raw(
    cbits: jnp.ndarray,      # [n, d'/8] uint8 — packed corpus sign bits
    qbits: jnp.ndarray,      # [b, d'/8] uint8 — packed query sign bits
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Hamming distances [b, n] (int32); pads to tile multiples and unpads.

    Both dispatch paths are bit-identical by construction (integer
    arithmetic); zero pad bytes XOR to 0 and contribute exactly 0.
    """
    use_kernel, interpret = resolve_dispatch(use_kernel, interpret)
    if not use_kernel:
        return binary_dot.sign_hamming_jnp(cbits, qbits)

    n, dk = cbits.shape
    b = qbits.shape[0]
    bq = min(8, _round_up(b, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(128, dk)        # dk is a power of two (d' = pow2 >= 8), so bk | dk
    b_pad, n_pad = _round_up(b, bq), _round_up(n, bn)
    cbits_p = jnp.pad(cbits, ((0, n_pad - n), (0, 0)))
    qbits_p = jnp.pad(qbits, ((0, b_pad - b), (0, 0)))
    out = binary_dot.sign_hamming_raw(
        cbits_p, qbits_p,
        block_q=bq, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:b, :n]


def crumb_coarse_raw(
    ccodes: jnp.ndarray,     # [n, d'/4] uint8 — corpus crumb planes (hi || lo)
    qplanes: jnp.ndarray,    # [b, d'/4] uint8 — query crumb planes (hi || lo)
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Crumb affinities [b, n] (int32) from plane-packed codes.

    Both byte arrays carry the hi bit plane then the lo bit plane, each
    d'/8 bytes (binary.derive_codes / binary.query_crumb_planes layout);
    zero pad rows AND to 0 and popcount to 0, so padding is free.
    """
    use_kernel, interpret = resolve_dispatch(use_kernel, interpret)
    dkp = ccodes.shape[-1] // 2
    dim = dkp * 8
    chi, clo = ccodes[:, :dkp], ccodes[:, dkp:]
    qhi, qlo = qplanes[:, :dkp], qplanes[:, dkp:]
    if not use_kernel:
        return binary_dot.crumb_affinity_jnp(chi, clo, qhi, qlo, dim=dim)

    n = ccodes.shape[0]
    b = qplanes.shape[0]
    bq = min(8, _round_up(b, 8))
    bn = min(256, _round_up(n, 128))
    bk = min(128, dkp)       # dkp is a power of two (d' = pow2 >= 8), so bk | dkp
    b_pad, n_pad = _round_up(b, bq), _round_up(n, bn)
    pad_c = ((0, n_pad - n), (0, 0))
    pad_q = ((0, b_pad - b), (0, 0))
    out = binary_dot.crumb_affinity_raw(
        jnp.pad(chi, pad_c), jnp.pad(clo, pad_c),
        jnp.pad(qhi, pad_q), jnp.pad(qlo, pad_q),
        dim=dim, block_q=bq, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:b, :n]


# ---------------------------------------------------------------------------
# Gathered candidate-set scoring (IVF probe lists, HNSW frontiers).
# ---------------------------------------------------------------------------

def _pad_gathered(gathered, planes, bb, bm, bk):
    """Pad [b, m, dk] bytes + [p, b, dk] planes to block multiples.

    k-padding is safe (padded plane entries are zero, so any byte contributes
    exactly 0); b/m padding is sliced off by the caller.  Both dispatch paths
    pad identically — a precondition of their bit-identity.
    """
    b, m, dk = gathered.shape
    b_pad, m_pad, k_pad = _round_up(b, bb), _round_up(m, bm), _round_up(dk, bk)
    gathered = jnp.pad(gathered, ((0, b_pad - b), (0, m_pad - m), (0, k_pad - dk)))
    planes = jnp.pad(planes, ((0, 0), (0, b_pad - b), (0, k_pad - dk)))
    return gathered, planes


def _gather_nibble_raw(
    gathered: jnp.ndarray,   # [b, mc, d/2] uint8 — pre-gathered candidate rows
    q_rot: jnp.ndarray,      # [b, d] rotated f32 queries
    use_kernel: bool,
    interpret: bool,
) -> jnp.ndarray:
    b, mc, dk = gathered.shape
    planes = deinterleave_query(q_rot, 2)             # [2, b, dk]
    bb, bm, bk = gather_dot.gather_blocks(b, mc, dk)
    gathered_p, planes_p = _pad_gathered(gathered, planes, bb, bm, bk)
    if use_kernel:
        out = gather_dot.gather_nibble_dot_raw(
            gathered_p, planes_p[0], planes_p[1],
            block_b=bb, block_m=bm, block_k=bk, interpret=interpret,
        )
    else:
        out = gather_dot.gather_nibble_dot_jnp(
            gathered_p, planes_p[0], planes_p[1],
            block_b=bb, block_m=bm, block_k=bk,
        )
    return out[:b, :mc]


def _gather_crumb_raw(
    gathered: jnp.ndarray,   # [b, mc, d/4] uint8
    q_rot: jnp.ndarray,
    use_kernel: bool,
    interpret: bool,
) -> jnp.ndarray:
    b, mc, dk = gathered.shape
    planes = deinterleave_query(q_rot, 4)             # [4, b, dk]
    bb, bm, bk = gather_dot.gather_blocks(b, mc, dk)
    bk = min(bk, 128)
    gathered_p, planes_p = _pad_gathered(gathered, planes, bb, bm, bk)
    if use_kernel:
        out = gather_dot.gather_crumb_dot_raw(
            gathered_p, planes_p,
            block_b=bb, block_m=bm, block_k=bk, interpret=interpret,
        )
    else:
        out = gather_dot.gather_crumb_dot_jnp(
            gathered_p, planes_p,
            block_b=bb, block_m=bm, block_k=bk,
        )
    return out[:b, :mc]


def score_gathered_raw(
    packed: jnp.ndarray,     # [n, bytes] packed corpus
    q_rot: jnp.ndarray,      # [b, d'] rotated f32 queries
    cand: jnp.ndarray,       # [b, mc] row indices (callers clamp/mask -1 pads)
    *,
    bits: int,
    n4_dims: int = 0,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Raw (un-adjusted) scores [b, mc] of row ``cand[b, i]`` vs query ``b``.

    The single bit-mode dispatch point for candidate-set scans — the IVF probe
    scan and the HNSW beam both go through here, so gathered packed bytes are
    interpreted identically on every path (the ``score_raw`` invariant,
    extended to per-query row subsets).  The gather itself stays uint8.
    """
    use_kernel, interpret = resolve_dispatch(use_kernel, interpret)
    gathered = jnp.take(packed, cand, axis=0)         # [b, mc, bytes] uint8
    if bits == 4:
        return _gather_nibble_raw(gathered, q_rot, use_kernel, interpret)
    if bits == 2:
        return _gather_crumb_raw(gathered, q_rot, use_kernel, interpret)
    if bits == 3:  # mixed [4-bit | 2-bit]
        b4 = n4_dims // 2
        raw4 = _gather_nibble_raw(gathered[:, :, :b4], q_rot[:, :n4_dims],
                                  use_kernel, interpret)
        raw2 = _gather_crumb_raw(gathered[:, :, b4:], q_rot[:, n4_dims:],
                                 use_kernel, interpret)
        return raw4 + raw2
    raise ValueError(f"unsupported bits={bits}")


def score_gathered(
    packed: jnp.ndarray,
    q_rot: jnp.ndarray,
    cand: jnp.ndarray,       # [b, mc] row indices, -1 = padding
    valid: Optional[jnp.ndarray] = None,   # [b, mc] bool; default cand >= 0
    *,
    bits: int,
    n4_dims: int = 0,
    qnorms: Optional[jnp.ndarray] = None,  # [n]; with metric -> adjusted scores
    metric: Optional[str] = None,
    allow_mask: Optional[jnp.ndarray] = None,  # [n] bool allowlist (pre-top-k)
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Scores [b, mc] for per-query candidate sets, masked BEFORE any top-k.

    ``-1`` sentinel rows (CSR padding), disallowed rows, and ``valid=False``
    rows all come back as NEG, so a stable top-k over the result honors the
    §3.5 pre-filter guarantee.  With ``qnorms``+``metric`` the scores are
    metric-adjusted; otherwise raw dot products.
    """
    valid_ = cand >= 0 if valid is None else valid
    cand_c = jnp.maximum(cand, 0)
    scores = score_gathered_raw(packed, q_rot, cand_c, bits=bits,
                                n4_dims=n4_dims, use_kernel=use_kernel,
                                interpret=interpret)
    if qnorms is not None:
        assert metric is not None, "metric required to adjust scores"
        scores = adjust_scores(scores, jnp.take(qnorms, cand_c, axis=0), metric)
    if allow_mask is not None:
        valid_ = valid_ & jnp.take(allow_mask, cand_c, axis=0)
    return jnp.where(valid_, scores, NEG)

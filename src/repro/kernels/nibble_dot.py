"""Pallas TPU kernel: asymmetric 4-bit nibble dot product (the paper's hot path).

Paper §3.7: the scoring kernel unpacks nibbles, looks up the 16 Lloyd-Max
centroids, multiplies by the f32 query and accumulates — on CPU this is an AVX2
``_mm256_permutevar8x32_ps`` LUT plus FMA chains.

TPU adaptation (DESIGN.md §2):
  * **no per-lane gather** on the VPU -> the 16-entry table lookup becomes a
    compare-select tree: ``vals = sum_k table[k] * (codes == k)``.  The 16
    centroids are compiled into the kernel as immediates, exactly like the
    paper compiles its tables into the binary.
  * **deinterleaved query trick**: instead of interleaving lo/hi nibbles back
    into position (an awkward minor-dim shuffle on TPU), the wrapper splits the
    rotated query into even/odd coordinate planes once per batch;  the kernel
    computes ``q_even @ deq(lo)^T + q_odd @ deq(hi)^T`` — two MXU matmuls, no
    shuffle.  This preserves the exact dot product because nibble 2i is the
    low half of byte i and nibble 2i+1 the high half.
  * the reduction over packed-dim blocks accumulates f32 in a fixed grid order
    (k innermost) -> bitwise-deterministic for a fixed block configuration,
    mirroring the paper's fixed SIMD reduction order.

VMEM tiling: default blocks (bq=128, bn=256, bk=256 packed bytes = 512 dims):
  packed   256*256           =  64 KiB
  deq lo/hi 2 * 256*512*4    =   1 MiB
  queries  2 * 128*256*4     = 256 KiB
  out      128*256*4         = 128 KiB      -> ~1.5 MiB, well under 16 MiB VMEM.
MXU alignment: all matmul dims are multiples of (8,128) f32 tiles.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lloydmax

# The frozen Lloyd-Max tables, baked in as Python floats (immediates).
# Shared with the gathered candidate-scan kernel (gather_dot.py) so every
# scan path dequantizes through the exact same values.
_TABLE4: Tuple[float, ...] = tuple(float(v) for v in lloydmax.CENTROIDS_4BIT)
_TABLE2: Tuple[float, ...] = tuple(float(v) for v in lloydmax.CENTROIDS_2BIT)


def _dequant_select(codes: jnp.ndarray, table: Tuple[float, ...]) -> jnp.ndarray:
    """Compare-select dequantization: no gather, pure VPU select tree.

    Fixed summation order over the table -> deterministic.  Value-identical
    to ``lloydmax.dequantize`` (a single table term is selected; adding the
    zero terms is exact), which is what lets the full-scan and gathered-scan
    kernels share it with the pure-jnp references.
    """
    vals = jnp.zeros(codes.shape, jnp.float32)
    for k, ck in enumerate(table):
        vals += jnp.where(codes == k, jnp.float32(ck), jnp.float32(0.0))
    return vals


def _nibble_dot_kernel(packed_ref, q_even_ref, q_odd_ref, out_ref, *, n_k: int):
    """One (bq, bn) output tile, accumulating over the packed-dim grid axis."""
    k = pl.program_id(2)

    packed = packed_ref[...]                        # [bn, bk] uint8
    lo = (packed & 0xF).astype(jnp.int32)           # nibble 2i   (dims 0,2,4,..)
    hi = (packed >> 4).astype(jnp.int32)            # nibble 2i+1 (dims 1,3,5,..)
    deq_lo = _dequant_select(lo, _TABLE4)           # [bn, bk] f32
    deq_hi = _dequant_select(hi, _TABLE4)

    q_even = q_even_ref[...]                        # [bq, bk] f32
    q_odd = q_odd_ref[...]

    part = jnp.dot(q_even, deq_lo.T, preferred_element_type=jnp.float32)
    part += jnp.dot(q_odd, deq_hi.T, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_n", "block_k", "interpret")
)
def nibble_dot_raw(
    packed: jnp.ndarray,     # [n, d'/2] uint8
    q_even: jnp.ndarray,     # [b, d'/2] f32 — rotated query dims 0,2,4,...
    q_odd: jnp.ndarray,      # [b, d'/2] f32 — rotated query dims 1,3,5,...
    *,
    block_q: int = 128,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw (un-adjusted) scores [b, n] = <q_rot, dequant(packed)>.

    Shapes must tile evenly (wrapper in ops.py pads).  interpret=True runs the
    kernel body on CPU for validation; on TPU pass interpret=False.
    """
    n, dk = packed.shape
    b, dk2 = q_even.shape
    assert dk == dk2 and q_odd.shape == q_even.shape
    assert n % block_n == 0 and b % block_q == 0 and dk % block_k == 0, (
        f"shapes ({b},{n},{dk}) must tile by ({block_q},{block_n},{block_k})"
    )
    grid = (b // block_q, n // block_n, dk // block_k)

    return pl.pallas_call(
        functools.partial(_nibble_dot_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(packed, q_even, q_odd)


def _crumb_dot_kernel(packed_ref, q0_ref, q1_ref, q2_ref, q3_ref, out_ref):
    """2-bit variant: four crumbs per byte, four deinterleaved query planes."""
    k = pl.program_id(2)
    packed = packed_ref[...]
    part = jnp.zeros((q0_ref.shape[0], packed.shape[0]), jnp.float32)
    for shift, q_ref in ((0, q0_ref), (2, q1_ref), (4, q2_ref), (6, q3_ref)):
        codes = ((packed >> shift) & 0x3).astype(jnp.int32)
        deq = _dequant_select(codes, _TABLE2)
        part += jnp.dot(q_ref[...], deq.T, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = part

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_n", "block_k", "interpret")
)
def crumb_dot_raw(
    packed: jnp.ndarray,   # [n, d/4] uint8
    q_planes: jnp.ndarray,  # [4, b, d/4] f32 — query dims {4i, 4i+1, 4i+2, 4i+3}
    *,
    block_q: int = 128,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    n, dk = packed.shape
    _, b, dk2 = q_planes.shape
    assert dk == dk2
    assert n % block_n == 0 and b % block_q == 0 and dk % block_k == 0
    grid = (b // block_q, n // block_n, dk // block_k)

    return pl.pallas_call(
        _crumb_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_q, block_k), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        ) if not interpret else None,
        interpret=interpret,
    )(packed, q_planes[0], q_planes[1], q_planes[2], q_planes[3])

"""Pallas TPU kernel: blocked Walsh-Hadamard transform (RHDH hot path).

TPU adaptation: the classic O(d log d) butterfly FWHT is log2(d) serial
VPU-shuffle stages — poor MXU utilization.  We instead use the Kronecker
factorization  H_{ab} = H_a (x) H_b  and compute  Y = H_a X H_b  on an
(a, b) reshape of each vector: two small dense matmuls that run on the MXU.
For d'=1024 (a=b=32 -> padded to MXU tiles) this moves ~all FLOPs to the
systolic array.  The Hadamard factors are passed in as f32 operands
(constant-folded by XLA; <= 256x256 each).

Grid: one axis over row blocks.  Per block VMEM: x + y = 2 * br * d' * 4B
(br=256, d'=1024 -> 2 MiB) plus the two factors (<= 512 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.rhdh import _split_pow2, hadamard_matrix


def _hadamard_kernel(x_ref, ha_ref, hb_ref, o_ref):
    x = x_ref[...]                                    # [br, a, b]
    ha = ha_ref[...]                                  # [a, a]
    hb = hb_ref[...]                                  # [b, b]
    br, a, b = x.shape
    # Right-multiply by H_b: collapse (br, a) and hit the MXU once.
    t = jnp.dot(x.reshape(br * a, b), hb, preferred_element_type=jnp.float32)
    t = t.reshape(br, a, b)
    # Left-multiply by H_a on the middle axis.
    y = jax.lax.dot_general(
        t, ha,
        dimension_numbers=(((1,), (0,)), ((), ())),   # [br, b, a] after contract
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = y.transpose(0, 2, 1)                 # back to [br, a, b]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fwht_pallas(
    x: jnp.ndarray,          # [n, d'] f32, d' a power of two
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Unnormalized FWHT over the last axis via the Kronecker-factored kernel."""
    n, d = x.shape
    assert d & (d - 1) == 0, f"d'={d} must be a power of two"
    a, b = _split_pow2(d)
    ha = jnp.asarray(hadamard_matrix(a))
    hb = jnp.asarray(hadamard_matrix(b))

    pad = (-n) % block_rows
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    np_ = xp.shape[0]
    xr = xp.reshape(np_, a, b)
    grid = (np_ // block_rows,)

    y = pl.pallas_call(
        _hadamard_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, a, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, a, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, a, b), jnp.float32),
        interpret=interpret,
    )(xr, ha, hb)
    y = y.reshape(np_, d)
    return y[:n] if pad else y

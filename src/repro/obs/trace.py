"""Query tracing: host-side span trees around compiled stage calls.

A ``QueryTrace`` is a tree of ``Span``s covering one query's path through
the stack — plan-cache lookup, per-stage device dispatch, mask/merge/top-k,
micro-batcher scatter-back.  Spans are opened and closed strictly HOST-SIDE
(``timed_span`` wraps the *call* to a jitted stage, never runs inside a
trace), so tracing can never perturb a compiled program: the golden-digest
bit-identity tests run with tracing on and off and compare raw bytes.

A timing caveat the reader must know: JAX dispatch is asynchronous, so a
span around a stage call measures host dispatch time unless something
downstream blocks; the engine's ``sync`` span (around the device->host
transfer of the final top-k) is where outstanding device work completes.
Per-stage spans are therefore a *structure + dispatch-cost* record on
accelerators and close to wall time on CPU.  (DESIGN.md §9.)

The active trace is thread-local: ``with trace("query"):`` activates one,
any ``span()``/``timed_span()`` underneath nests into it, and a thread with
no active trace pays a single attribute check.  ``Tracer`` adds 1-in-N
deterministic sampling for serving loops (`serve.py --trace-sample N`).
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

from .registry import DEFAULT_LATENCY_EDGES_US
from .registry import enabled as _metrics_enabled
from .registry import registry as _registry

_LOCAL = threading.local()


class Span:
    __slots__ = ("name", "attrs", "t_start", "t_end", "children")

    def __init__(self, name: str, attrs: Optional[dict] = None,
                 t_start: float = 0.0) -> None:
        self.name = name
        self.attrs: Dict[str, object] = dict(attrs or {})
        self.t_start = t_start
        self.t_end: Optional[float] = None
        self.children: List["Span"] = []

    @property
    def duration_us(self) -> Optional[float]:
        if self.t_end is None:
            return None
        return (self.t_end - self.t_start) * 1e6

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "duration_us": self.duration_us,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }


class QueryTrace:
    """One query's span tree.  ``push``/``pop`` maintain a stack, so spans
    opened while another is active nest under it; ``render()`` pretty-prints
    the tree for `--trace-sample` dumps."""

    def __init__(self, name: str, attrs: Optional[dict] = None,
                 clock=time.perf_counter) -> None:
        self._clock = clock
        self.root = Span(name, attrs, t_start=clock())
        self._stack: List[Span] = [self.root]

    def push(self, name: str, **attrs: object) -> Span:
        sp = Span(name, attrs, t_start=self._clock())
        self._stack[-1].children.append(sp)
        self._stack.append(sp)
        return sp

    def pop(self, span: Span) -> None:
        span.t_end = self._clock()
        # Tolerate mis-nested pops (an exception unwound past a span): close
        # everything above `span` on the stack rather than corrupting it.
        while len(self._stack) > 1:
            top = self._stack.pop()
            if top.t_end is None:
                top.t_end = span.t_end
            if top is span:
                break

    def finish(self) -> "QueryTrace":
        now = self._clock()
        while self._stack:
            top = self._stack.pop()
            if top.t_end is None:
                top.t_end = now
        return self

    def to_dict(self) -> dict:
        return self.root.to_dict()

    def render(self, indent: str = "  ") -> str:
        lines: List[str] = []

        def walk(sp: Span, depth: int) -> None:
            dur = sp.duration_us
            dur_s = "..." if dur is None else f"{dur:.0f}us"
            attrs = "".join(f" {k}={v}" for k, v in sorted(sp.attrs.items()))
            lines.append(f"{indent * depth}{sp.name} {dur_s}{attrs}")
            for c in sp.children:
                walk(c, depth + 1)

        walk(self.root, 0)
        return "\n".join(lines)


def current_trace() -> Optional[QueryTrace]:
    return getattr(_LOCAL, "trace", None)


@contextmanager
def trace(name: str, **attrs: object):
    """Activate a QueryTrace on this thread; restores any outer trace."""
    prev = current_trace()
    tr = QueryTrace(name, attrs)
    _LOCAL.trace = tr
    try:
        yield tr
    finally:
        tr.finish()
        _LOCAL.trace = prev


class _NullCm:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CM = _NullCm()


class _TimedSpan:
    """Times one host-side block: appends a child span to the active trace
    (if any) and observes the duration into a registry histogram (if metrics
    are enabled and a histogram name was given)."""

    __slots__ = ("_name", "_hist", "_edges", "_labels", "_attrs",
                 "_tr", "_sp", "_t0")

    def __init__(self, name, hist, edges, labels, attrs) -> None:
        self._name = name
        self._hist = hist
        self._edges = edges
        self._labels = labels
        self._attrs = attrs
        self._tr = None
        self._sp = None
        self._t0 = 0.0

    def __enter__(self) -> Optional[Span]:
        self._tr = current_trace()
        if self._tr is not None:
            self._sp = self._tr.push(self._name, **(self._attrs or {}))
        self._t0 = time.perf_counter()
        return self._sp

    def __exit__(self, exc_type, exc, tb) -> bool:
        dt_us = (time.perf_counter() - self._t0) * 1e6
        if self._sp is not None:
            if exc_type is not None:
                self._sp.attrs["error"] = exc_type.__name__
            self._tr.pop(self._sp)
        if self._hist is not None and _metrics_enabled():
            _registry().histogram(
                self._hist, self._edges,
                **(self._labels or {})).observe(dt_us)
        return False


def timed_span(name: str, *, histogram: Optional[str] = None,
               edges: Tuple[float, ...] = DEFAULT_LATENCY_EDGES_US,
               labels: Optional[dict] = None,
               attrs: Optional[dict] = None):
    """Context manager: time a host-side block into ``histogram`` (us) and,
    when a trace is active, record it as a nested span.  Free (a shared
    null object) when there is nothing to record."""
    if current_trace() is None and (histogram is None or not _metrics_enabled()):
        return _NULL_CM
    return _TimedSpan(name, histogram, edges, labels, attrs)


def span(name: str, **attrs: object):
    """Trace-only child span (no histogram)."""
    return timed_span(name, attrs=attrs)


class Tracer:
    """Deterministic 1-in-N sampler for serving loops.

    ``maybe(name)`` activates a full QueryTrace on the 1st, (N+1)th, ...
    call and a no-op otherwise; completed traces accumulate (bounded) until
    ``drain()``.  N == 0 disables sampling entirely.
    """

    def __init__(self, sample_every: int = 0, keep: int = 64) -> None:
        self.sample_every = int(sample_every)
        self.keep = int(keep)
        self.traces: List[QueryTrace] = []
        self._n = 0

    def maybe(self, name: str, **attrs: object):
        self._n += 1
        if self.sample_every <= 0 or (self._n - 1) % self.sample_every:
            return _NULL_CM
        return self._capture(name, attrs)

    @contextmanager
    def _capture(self, name: str, attrs: dict):
        with trace(name, **attrs) as tr:
            yield tr
        if len(self.traces) < self.keep:
            self.traces.append(tr)

    def drain(self) -> List[QueryTrace]:
        out, self.traces = self.traces, []
        return out

"""Process-wide metrics: counters, gauges, fixed-bucket histograms.

The observability contract (DESIGN.md §9) in one sentence: everything is
measured HOST-SIDE, around compiled calls, never inside a traced function —
so a metrics-enabled search is byte-identical to a disabled one, and the
snapshot *structure* (metric names, label sets, histogram bucket edges) is
deterministic even though the observed latencies are not.

Histograms use fixed, committed bucket edges (a 1-2.5-5 decade ladder in
microseconds) rather than adaptive ones: two runs of the same workload emit
snapshots with identical shape, so trajectory tooling and dashboards can
diff them field-by-field.

Values are plain Python ints/floats mutated under the GIL; metric *creation*
is locked, increments are not — single-writer serving loops (the repo's
shape) observe exact counts, and concurrent writers degrade to approximate
counts, never corruption.  ``enable(False)`` turns every helper in
``repro.obs`` into a no-op for overhead-sensitive runs; the bit-identity
tests flip it both ways and compare result bytes.
"""

from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, Iterable, List, Optional, Tuple

# Latency bucket edges in MICROSECONDS: a 1-2.5-5 ladder from 1us to 10s.
# Pinned by tests/test_obs.py — changing them is a snapshot-schema change.
DEFAULT_LATENCY_EDGES_US: Tuple[float, ...] = (
    1, 2.5, 5, 10, 25, 50, 100, 250, 500,
    1_000, 2_500, 5_000, 10_000, 25_000, 50_000,
    100_000, 250_000, 500_000,
    1_000_000, 2_500_000, 5_000_000, 10_000_000,
)

# Small-count edges (batch coalescing factors, queue depths): powers of two.
DEFAULT_COUNT_EDGES: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024,
)

Labels = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[dict]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def render_key(name: str, labels: Labels) -> str:
    """``name{k="v",...}`` — the stable string form used in snapshots."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-edge histogram: ``counts[i]`` tallies observations with
    ``v <= edges[i]`` (exclusive of earlier buckets); the last slot is the
    +Inf overflow.  Edges are part of the snapshot, so a reader never has
    to guess the schema."""

    __slots__ = ("edges", "counts", "count", "total", "min", "max")

    def __init__(self, edges: Tuple[float, ...]) -> None:
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram edges must be sorted, got {edges!r}")
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper edge of the bucket the
        q-th observation falls in; +Inf bucket reports the observed max)."""
        if self.count == 0:
            return float("nan")
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return self.edges[i] if i < len(self.edges) else self.max
        return self.max


class MetricsRegistry:
    """Name+labels -> metric.  One process-wide instance (``registry()``)
    backs every instrumented layer; tests construct private ones."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, Labels], Counter] = {}
        self._gauges: Dict[Tuple[str, Labels], Gauge] = {}
        self._histograms: Dict[Tuple[str, Labels], Histogram] = {}

    # -- get-or-create -----------------------------------------------------

    def _check_kind(self, name: str, kind: str) -> None:
        kinds = {"counter": self._counters, "gauge": self._gauges,
                 "histogram": self._histograms}
        for other, table in kinds.items():
            if other != kind and any(k[0] == name for k in table):
                raise ValueError(
                    f"metric {name!r} already registered as a {other}")

    def counter(self, name: str, **labels: object) -> Counter:
        key = (name, _labels_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.get(key)
                if c is None:
                    self._check_kind(name, "counter")
                    c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: object) -> Gauge:
        key = (name, _labels_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.get(key)
                if g is None:
                    self._check_kind(name, "gauge")
                    g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str,
                  edges: Tuple[float, ...] = DEFAULT_LATENCY_EDGES_US,
                  **labels: object) -> Histogram:
        key = (name, _labels_key(labels))
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.get(key)
                if h is None:
                    self._check_kind(name, "histogram")
                    h = self._histograms[key] = Histogram(edges)
        elif tuple(edges) != h.edges:
            raise ValueError(
                f"histogram {name!r} already registered with edges "
                f"{h.edges}, got {tuple(edges)}")
        return h

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """A plain-dict, JSON-serializable snapshot with deterministic key
        order: ``{"counters": {...}, "gauges": {...}, "histograms": {...}}``.
        Histogram entries carry their edges — the schema travels with the
        data."""
        counters = {render_key(n, ls): c.value
                    for (n, ls), c in sorted(self._counters.items())}
        gauges = {render_key(n, ls): g.value
                  for (n, ls), g in sorted(self._gauges.items())}
        hists = {}
        for (n, ls), h in sorted(self._histograms.items()):
            hists[render_key(n, ls)] = {
                "edges": list(h.edges),
                "counts": list(h.counts),
                "count": h.count,
                "sum": h.total,
                "min": None if h.count == 0 else h.min,
                "max": None if h.count == 0 else h.max,
            }
        return {"counters": counters, "gauges": gauges, "histograms": hists}

    def snapshot_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (0.0.4): dots in names become
        underscores, histograms emit cumulative ``_bucket`` series plus
        ``_sum``/``_count``."""
        out: List[str] = []

        def pname(name: str) -> str:
            return name.replace(".", "_").replace("-", "_")

        def labelstr(labels: Labels, extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in labels]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        def fmt(v: float) -> str:
            return repr(int(v)) if float(v).is_integer() else repr(float(v))

        typed = set()
        for (name, labels), c in sorted(self._counters.items()):
            if name not in typed:
                out.append(f"# TYPE {pname(name)} counter")
                typed.add(name)
            out.append(f"{pname(name)}{labelstr(labels)} {c.value}")
        for (name, labels), g in sorted(self._gauges.items()):
            if name not in typed:
                out.append(f"# TYPE {pname(name)} gauge")
                typed.add(name)
            out.append(f"{pname(name)}{labelstr(labels)} {fmt(g.value)}")
        for (name, labels), h in sorted(self._histograms.items()):
            if name not in typed:
                out.append(f"# TYPE {pname(name)} histogram")
                typed.add(name)
            cum = 0
            for edge, c in zip(h.edges, h.counts):
                cum += c
                le = 'le="%s"' % fmt(edge)
                out.append(f"{pname(name)}_bucket{labelstr(labels, le)} {cum}")
            cum += h.counts[-1]
            le_inf = 'le="+Inf"'
            out.append(f"{pname(name)}_bucket{labelstr(labels, le_inf)} {cum}")
            out.append(f"{pname(name)}_sum{labelstr(labels)} {fmt(h.total)}")
            out.append(f"{pname(name)}_count{labelstr(labels)} {h.count}")
        return "\n".join(out) + ("\n" if out else "")


# ---------------------------------------------------------------------------
# The process-wide default registry + enable flag.
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()
_ENABLED = True


def registry() -> MetricsRegistry:
    """The process-wide registry every instrumented layer records into."""
    return _REGISTRY


def enabled() -> bool:
    return _ENABLED


def enable(flag: bool = True) -> bool:
    """Toggle metric collection process-wide; returns the previous value.
    Disabling turns every ``inc``/``set_gauge``/``observe``/``timed_span``
    into a no-op — results are bit-identical either way (tests/test_obs.py)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(flag)
    return prev


def inc(name: str, n: int = 1, **labels: object) -> None:
    if _ENABLED:
        _REGISTRY.counter(name, **labels).inc(n)


def set_gauge(name: str, v: float, **labels: object) -> None:
    if _ENABLED:
        _REGISTRY.gauge(name, **labels).set(v)


def observe(name: str, v: float,
            edges: Tuple[float, ...] = DEFAULT_LATENCY_EDGES_US,
            **labels: object) -> None:
    if _ENABLED:
        _REGISTRY.histogram(name, edges, **labels).observe(v)


# ---------------------------------------------------------------------------
# Snapshot arithmetic + human rendering (serve.py phase reports).
# ---------------------------------------------------------------------------

def counter_deltas(new: dict, old: dict) -> Dict[str, int]:
    """Per-key counter difference between two snapshots (new keys count from
    zero); gauges/histograms are point-in-time and are not diffed here."""
    oldc = old.get("counters", {})
    return {k: v - oldc.get(k, 0) for k, v in new.get("counters", {}).items()}


def counter_total(counters: Dict[str, int], name: str) -> int:
    """Sum a (possibly labeled) counter family out of a snapshot or delta
    dict: exact-name match plus every ``name{...}`` labeled series."""
    prefix = name + "{"
    return sum(v for k, v in counters.items()
               if k == name or k.startswith(prefix))


def render_text(snapshot: dict, only: Optional[Iterable[str]] = None) -> str:
    """Compact human-readable snapshot dump (one metric per line)."""
    prefixes = tuple(only) if only else None

    def keep(k: str) -> bool:
        return prefixes is None or k.startswith(prefixes)

    lines: List[str] = []
    for k, v in snapshot.get("counters", {}).items():
        if keep(k):
            lines.append(f"{k} = {v}")
    for k, v in snapshot.get("gauges", {}).items():
        if keep(k):
            lines.append(f"{k} = {v:g}")
    for k, h in snapshot.get("histograms", {}).items():
        if not keep(k):
            continue
        if h["count"] == 0:
            lines.append(f"{k}: count=0")
            continue
        mean = h["sum"] / h["count"]
        hist = Histogram(tuple(h["edges"]))
        hist.counts = list(h["counts"])
        hist.count = h["count"]
        hist.max = h["max"]
        lines.append(
            f"{k}: count={h['count']} mean={mean:.1f}us "
            f"p50<={hist.quantile(0.5):g}us p99<={hist.quantile(0.99):g}us "
            f"max={h['max']:.1f}us")
    return "\n".join(lines)

"""Shared delta-window arithmetic for ad-hoc counter dataclasses.

``PlanStats`` and ``BatcherStats`` (repro.engine) each grew identical
``snapshot()``/``since()`` methods for measuring a serving window; this is
the one implementation both now inherit.  Any all-numeric dataclass gets
the same contract by subclassing:

    @dataclasses.dataclass
    class MyStats(DeltaStats):
        hits: int = 0

    before = stats.snapshot()
    ...
    window = stats.since(before)     # field-wise difference, same type
"""

from __future__ import annotations

import dataclasses


class DeltaStats:
    """Mixin for ``@dataclass`` counter bundles: field-wise copy and diff."""

    def snapshot(self):
        """An immutable-by-convention copy of the current counter values."""
        return dataclasses.replace(self)

    def since(self, before):
        """Field-wise ``self - before``, returned as the same stats type."""
        if type(before) is not type(self):
            raise TypeError(
                f"since() expects a {type(self).__name__} snapshot, "
                f"got {type(before).__name__}")
        return type(self)(**{
            f.name: getattr(self, f.name) - getattr(before, f.name)
            for f in dataclasses.fields(self)})

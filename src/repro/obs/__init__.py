# repro.obs — engine-wide observability (DESIGN.md §9): the process-wide
# MetricsRegistry (counters / gauges / fixed-bucket latency histograms with
# deterministic edges), the QueryTrace span API with host-side timers that
# never enter a traced function, and the DeltaStats snapshot/since mixin.
#
# Instrumentation is additive by contract: a metrics-enabled or traced
# search returns bytes identical to a disabled one (tests/test_obs.py).

from .registry import (DEFAULT_COUNT_EDGES, DEFAULT_LATENCY_EDGES_US,
                       Counter, Gauge, Histogram, MetricsRegistry,
                       counter_deltas, counter_total, enable, enabled, inc,
                       observe, registry, render_key, render_text, set_gauge)
from .stats import DeltaStats
from .trace import (QueryTrace, Span, Tracer, current_trace, span, timed_span,
                    trace)

__all__ = [
    "DEFAULT_COUNT_EDGES", "DEFAULT_LATENCY_EDGES_US",
    "Counter", "DeltaStats", "Gauge", "Histogram", "MetricsRegistry",
    "QueryTrace", "Span", "Tracer",
    "counter_deltas", "counter_total", "current_trace", "enable", "enabled",
    "inc", "observe", "registry", "render_key", "render_text", "set_gauge",
    "span", "timed_span", "trace",
]

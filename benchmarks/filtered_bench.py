"""Filtered-search cost model: predicate selectivity vs the unfiltered scan.

The compiled predicate stage (DESIGN.md §8) masks rows BEFORE the top-k, so
a filtered query costs one fused mask stage on top of the same bucketed
scan — it does not re-partition, re-encode, or post-filter.  This sweep
measures that claim: per backend, QPS and recall@10 (vs the exact filtered
oracle: full-precision scores with non-matching rows masked to -inf) at
predicate selectivities of ~1%, ~10%, and ~50%, against the unfiltered
baseline on the same corpus.

    PYTHONPATH=src python -m benchmarks.filtered_bench [--n 32000] [--dim 256]

Emits the standard ``name,us_per_call,derived`` rows plus structured
records (common.record) for the BENCH_filtered.json artifact.
"""

from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import MonaVec, Lt
from repro.core.scoring import score_f32, topk
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, recall_at_10, record, time_fn

SELECTIVITIES = (1, 10, 50)   # Lt("attr", s) over uniform 0..99 => s percent


def _filtered_gt(queries: np.ndarray, corpus: np.ndarray, metric: str,
                 mask: Optional[np.ndarray], k: int = 10) -> np.ndarray:
    """Exact oracle: f32 scores, non-matching rows masked to -inf pre-top-k."""
    scores = score_f32(jnp.asarray(queries), jnp.asarray(corpus), metric)
    if mask is not None:
        scores = jnp.where(jnp.asarray(mask)[None, :], scores, -jnp.inf)
    return np.asarray(topk(scores, k)[1])


def bench_filtered(n: int = 32_000, dim: int = 256, batch_q: int = 16,
                   k: int = 10,
                   backends: Sequence[str] = ("bruteforce",)) -> None:
    corpus = embedding_corpus(63, n, dim)
    rng = np.random.RandomState(63)
    attr = rng.randint(0, 100, size=n).astype(np.int64)
    queries = np.asarray(queries_from_corpus(corpus, 163, batch_q))

    for backend in backends:
        kw = {"nlist": 64} if backend == "ivf" else (
            {"m": 16, "ef_construction": 64} if backend == "hnsw" else {})
        idx = MonaVec.build(corpus, metric="cosine", index=backend,
                            meta={"attr": attr}, **kw)
        bpv = int(idx.backend.enc.packed.shape[-1])
        for sel in (None,) + SELECTIVITIES:
            where = None if sel is None else Lt("attr", int(sel))
            mask = None if sel is None else attr < sel
            search = idx.searcher(k=k, where=where, use_kernel=False)
            search.warmup(batch_q)
            us = time_fn(lambda: search(queries))
            ids = np.asarray(search(queries)[1])
            gt = _filtered_gt(queries, corpus, "cosine", mask, k)
            rec = recall_at_10(ids, gt)
            qps = batch_q / (us / 1e6)
            label = "unfiltered" if sel is None else f"sel{sel:02d}"
            live = n if mask is None else int(mask.sum())
            emit(f"filtered/{backend}/{label}", us,
                 f"qps={qps:.0f} recall={rec:.3f} live={live}/{n} "
                 f"bytes_per_vec={bpv}")
            record(bench="filtered", backend=backend, n=n, dim=dim,
                   batch_q=batch_q, k=k,
                   selectivity_pct=(100.0 if sel is None else float(sel)),
                   live_rows=live, qps=float(qps), recall_at_10=float(rec),
                   bytes_per_vector=bpv, us_per_call=float(us))


def emit_benchmark() -> None:
    """Hook for benchmarks.run (all three backends, moderate shape)."""
    bench_filtered(n=16_000, dim=256, backends=("bruteforce", "ivf", "hnsw"))


def emit_benchmark_smoke() -> None:
    """CI smoke hook (benchmarks.run --smoke): tiny shape, same code paths —
    the compiled predicate stage runs at every selectivity."""
    bench_filtered(n=2_048, dim=64, batch_q=4, backends=("bruteforce",))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32_000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--batch-q", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--backends", default="bruteforce,ivf,hnsw")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_filtered(n=args.n, dim=args.dim, batch_q=args.batch_q, k=args.k,
                   backends=tuple(args.backends.split(",")))


if __name__ == "__main__":
    main()

"""Segmented-lifecycle cost model: mutation throughput + scan overhead.

What the segment subsystem (DESIGN.md §6) buys and what it costs:

  * ``add`` is O(batch) quantization — no index rebuild (the whole point);
  * a mutated BruteForce search pays one extra packed scan per segment plus
    the tombstone mask (measured as segmented-vs-static overhead);
  * ``compact`` pays one decode→inverse-rotate→re-encode pass and returns
    the index to static-scan speed.

    PYTHONPATH=src python -m benchmarks.segments_bench [--n 16000] [--dim 512]

Emits the standard ``name,us_per_call,derived`` rows.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import MonaVec
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, time_fn


def bench_segment_lifecycle(n: int = 16_000, dim: int = 512,
                            add_frac: float = 0.10, batch_q: int = 16,
                            k: int = 10) -> None:
    corpus = embedding_corpus(41, n, dim)
    q = queries_from_corpus(corpus, 42, batch_q)
    idx = MonaVec.build(corpus, metric="cosine")

    us = time_fn(lambda: idx.search(q, k, use_kernel=False))
    emit("segments/static_scan", us, f"n={n} qps={batch_q / (us * 1e-6):.0f}")

    add_n = max(1, int(n * add_frac))
    delta = np.asarray(embedding_corpus(43, add_n, dim))
    t0 = time.perf_counter()
    idx.add(delta)
    dt = time.perf_counter() - t0
    emit("segments/add", dt * 1e6,
         f"rows={add_n} rows_per_s={add_n / dt:.0f}")

    idx.delete(idx.ids[::13])
    us_mut = time_fn(lambda: idx.search(q, k, use_kernel=False))
    emit("segments/segmented_scan", us_mut,
         f"segments=2 live={idx.n_live} overhead={us_mut / us:.2f}x")

    t0 = time.perf_counter()
    reclaimed = idx.compact()
    dt = time.perf_counter() - t0
    emit("segments/compact", dt * 1e6,
         f"reclaimed={reclaimed} rows_per_s={idx.n_live / dt:.0f}")

    us_post = time_fn(lambda: idx.search(q, k, use_kernel=False))
    emit("segments/post_compact_scan", us_post,
         f"n={idx.n_live} vs_static={us_post / us:.2f}x")


def emit_benchmark() -> None:
    """Hook for benchmarks.run (small shapes to keep the sweep fast)."""
    bench_segment_lifecycle(n=8_000, dim=256)


def emit_benchmark_smoke() -> None:
    """CI smoke hook (benchmarks.run --smoke): tiny shapes, same code paths."""
    bench_segment_lifecycle(n=1_024, dim=64, batch_q=4)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--add-frac", type=float, default=0.10)
    ap.add_argument("--batch-q", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_segment_lifecycle(n=args.n, dim=args.dim, add_frac=args.add_frac,
                            batch_q=args.batch_q, k=args.k)


if __name__ == "__main__":
    main()

"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(single-link worst case; the 2D torus gives each axis its own links, so the
collective term is an upper bound).

    compute    = HLO_FLOPs_per_chip / 197e12
    memory     = HLO_bytes_per_chip / 819e9
    collective = wire_bytes_per_chip / 50e9

All three in seconds; the max is the bottleneck.  roofline_fraction =
compute / max(terms): 1.0 when compute-bound (the optimization target).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _extrapolate(scan: dict, pa: dict, pb: dict, l_a: int, l_b: int,
                 l_full: int) -> dict:
    """Per-layer costs are linear in depth (homogeneous stacks): combine two
    reduced-depth unrolled probes with the full-depth scan compile."""
    rec = dict(scan)
    rec["variant"] = "baseline"
    rec["extrapolated_from"] = [l_a, l_b, l_full]
    for key in ("hlo_flops", "hlo_bytes", "collective_wire_bytes"):
        fa, fb = pa.get(key, 0.0), pb.get(key, 0.0)
        slope = (fb - fa) / (l_b - l_a)
        rec[key] = fa + slope * (l_full - l_a)
    return rec


def load_records(art_dir: str = "artifacts/dryrun",
                 mesh: str = "single", variant: Optional[str] = None) -> List[dict]:
    raw = []
    for p in sorted(Path(art_dir).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh:
            continue
        raw.append(r)
    # Synthesize extrapolated baselines for heavy archs (scan + 2 probes).
    by_key: Dict[tuple, Dict[str, dict]] = {}
    for r in raw:
        by_key.setdefault((r["arch"], r["shape"]), {})[r.get("variant", "")] = r
    full_layers = {"deepseek-v3-671b": 61, "gemma2-2b": 26, "llama3.2-3b": 28}
    out = []
    for (arch, shape), vs in by_key.items():
        probes = sorted(int(k[5:]) for k in vs if k.startswith("probe")
                        and vs[k].get("ok"))
        if arch in full_layers and "scan" in vs and len(probes) >= 2 \
                and vs["scan"].get("ok"):
            la, lb = probes[0], probes[-1]
            out.append(_extrapolate(vs["scan"], vs[f"probe{la}"],
                                    vs[f"probe{lb}"], la, lb,
                                    full_layers[arch]))
            for k, v in vs.items():
                if k != "scan" and not k.startswith("probe"):
                    out.append(v)
            continue
        out.extend(vs.values())
    if variant is not None:
        out = [r for r in out if r.get("variant") == variant]
    return sorted(out, key=lambda r: (r["arch"], r["shape"], r.get("variant", "")))


def terms(rec: dict) -> Dict[str, float]:
    compute = rec.get("hlo_flops", 0.0) / PEAK_FLOPS
    memory = rec.get("hlo_bytes", 0.0) / HBM_BW
    collective = rec.get("collective_wire_bytes", 0.0) / ICI_BW
    dom = max(compute, memory, collective)
    out = {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bottleneck": ("compute" if dom == compute else
                       "memory" if dom == memory else "collective"),
        "roofline_fraction": compute / dom if dom > 0 else 0.0,
    }
    n_dev = rec.get("n_devices", 256)
    mf = rec.get("model_flops", 0.0) / n_dev
    out["model_flops_per_chip"] = mf
    out["useful_ratio"] = mf / rec["hlo_flops"] if rec.get("hlo_flops") else 0.0
    return out


def table(records: List[dict]) -> str:
    hdr = ("| arch | shape | step | variant | compute(s) | memory(s) | "
           "collective(s) | bottleneck | roofline frac | useful/HLO | "
           "temp GiB/chip |\n|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in records:
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | - | {r.get('variant')} "
                        f"| FAILED: {r.get('error', '?')[:60]} |||||||")
            continue
        t = terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('step')} | {r.get('variant')} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} | {t['collective_s']:.2e} "
            f"| {t['bottleneck']} | {t['roofline_fraction']:.2f} "
            f"| {t['useful_ratio']:.2f} "
            f"| {r.get('temp_size_in_bytes', 0) / 2**30:.2f} |")
    return hdr + "\n".join(rows) + "\n"


def emit_benchmark(art_dir: str = "artifacts/dryrun") -> None:
    from .common import emit
    recs = load_records(art_dir)
    if not recs:
        emit("roofline/no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    for r in recs:
        if not r.get("ok"):
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, "FAILED")
            continue
        t = terms(r)
        emit(f"roofline/{r['arch']}/{r['shape']}/{r.get('variant')}",
             max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
             f"bottleneck={t['bottleneck']} frac={t['roofline_fraction']:.2f} "
             f"useful={t['useful_ratio']:.2f}")
    out = Path(art_dir).parent / "roofline.md"
    multi = load_records(art_dir, mesh="multi")
    out.write_text(
        "# Roofline — single pod (16x16 = 256 chips)\n\n" + table(recs)
        + "\n# Roofline — multi-pod (2x16x16 = 512 chips)\n\n" + table(multi))
    emit("roofline/table_written", 0.0,
         f"{out} ({len(recs)} single-pod + {len(multi)} multi-pod rows)")

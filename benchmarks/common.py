"""Shared benchmark utilities: timing, recall, CSV + JSON record emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scoring import score_f32, topk

ROWS: List[Tuple[str, float, str]] = []

# Structured records for the machine-readable BENCH_<name>.json artifacts
# (benchmarks.run writes one file per benchmark from the records it appended).
# Fields are free-form per benchmark; the filtered/backends sweeps use
# {backend, n, dim, qps, recall_at_10, bytes_per_vector, ...}.
RECORDS: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def record(**fields: object) -> None:
    """Append one structured benchmark record (JSON-serializable scalars)."""
    RECORDS.append({k: (v.item() if isinstance(v, np.generic) else v)
                    for k, v in fields.items()})


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Best-of wall time in microseconds (paper reports best pass after warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def recall_at_10(pred_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    return float(np.mean([len(set(a.tolist()) & set(b.tolist())) / gt_ids.shape[1]
                          for a, b in zip(pred_ids.astype(np.int64), gt_ids)]))


def ground_truth(queries: np.ndarray, corpus: np.ndarray, metric: str,
                 k: int = 10) -> np.ndarray:
    return np.asarray(topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                                     metric), k)[1])

"""Shared benchmark utilities: timing, recall, CSV emission."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scoring import score_f32, topk

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Best-of wall time in microseconds (paper reports best pass after warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def recall_at_10(pred_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    return float(np.mean([len(set(a.tolist()) & set(b.tolist())) / gt_ids.shape[1]
                          for a, b in zip(pred_ids.astype(np.int64), gt_ids)]))


def ground_truth(queries: np.ndarray, corpus: np.ndarray, metric: str,
                 k: int = 10) -> np.ndarray:
    return np.asarray(topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                                     metric), k)[1])

"""Autotuner payoff: tuned knobs vs the always-safe knob, boosted filters.

Two claims from DESIGN.md §12, measured end to end:

* **Tuned vs safe** — the autotuner picks the cheapest IVF ``nprobe`` rung
  meeting ``recall@k >= target`` against the exact quantized-scan oracle.
  The alternative that needs no tuning is the always-safe ceiling
  (``nprobe = nlist``: sweep every list, oracle-exact by construction).
  The sweep reports QPS for both arms on held-out queries plus the tuned
  arm's recall against the safe arm — the speedup is the payoff of tuning,
  at a recall the target still bounds.  The speedup rides in the records as
  a QPS ratio (same machine, both arms), so the trajectory gate pins it.

* **Boost gain** — filtered IVF recall collapses at low selectivity because
  lists are pruned before the mask; the tuned boost curve widens ``nprobe``
  by the exact-popcount selectivity (repro.tune.selectivity).  The sweep
  runs the SAME ~1%-selectivity predicate with the boost curve stripped
  (``dataclasses.replace(tuned, boost=None)``) and with it active, against
  the exact filtered quantized oracle; the absolute recall gain is recorded
  (and pinned >= 0.15 by the committed baseline).

    PYTHONPATH=src python -m benchmarks.autotune_bench [--n 32000]

Emits the standard ``name,us_per_call,derived`` rows plus structured
records (common.record) for the BENCH_autotune.json artifact.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import Lt, MonaVec
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, recall_at_10, record, time_fn


def bench_autotune(n: int = 32_000, dim: int = 128, nlist: int = 64,
                   batch_q: int = 16, k: int = 10,
                   recall_target: float = 0.95, sel_pct: int = 1) -> None:
    corpus = embedding_corpus(97, n, dim)
    rng = np.random.RandomState(97)
    attr = rng.randint(0, 100, size=n).astype(np.int64)
    queries = np.asarray(queries_from_corpus(corpus, 197, batch_q))

    idx = MonaVec.build(corpus, metric="cosine", index="ivf", nlist=nlist,
                        meta={"attr": attr})
    t0 = time.time()
    idx.autotune(recall_target=recall_target, k=k)
    tune_s = time.time() - t0
    tuned = idx.tuned
    nprobe = int(idx.resolved_knobs(k)["nprobe"])
    emit("autotune/ivf/tune", tune_s * 1e6,
         f"nprobe={nprobe}/{nlist} met_target={tuned.met_target} "
         f"target={recall_target}")

    # -- tuned vs always-safe (unfiltered) --------------------------------
    # The safe arm IS the exact quantized oracle (nprobe=nlist sweeps every
    # list), so its ids double as the ground truth for the tuned arm.
    safe = idx.searcher(k=k, nprobe=nlist, use_kernel=False)
    safe.warmup(batch_q)
    us_safe = time_fn(lambda: safe(queries))
    gt_ids = np.asarray(safe(queries)[1])

    tuned_s = idx.searcher(k=k, use_kernel=False)   # knobs from idx.tuned
    tuned_s.warmup(batch_q)
    us_tuned = time_fn(lambda: tuned_s(queries))
    rec_tuned = recall_at_10(np.asarray(tuned_s(queries)[1]), gt_ids)

    qps_safe = batch_q / (us_safe / 1e6)
    qps_tuned = batch_q / (us_tuned / 1e6)
    speedup = qps_tuned / qps_safe
    emit(f"autotune/ivf/safe-nprobe{nlist}", us_safe, f"qps={qps_safe:.0f}")
    emit(f"autotune/ivf/tuned-nprobe{nprobe}", us_tuned,
         f"qps={qps_tuned:.0f} recall={rec_tuned:.3f} "
         f"speedup={speedup:.2f}x")
    common_id = dict(bench="autotune", backend="ivf", n=n, dim=dim,
                     batch_q=batch_q, k=k, recall_target=recall_target)
    record(arm="safe", qps=float(qps_safe), us_per_call=float(us_safe),
           **common_id)
    record(arm="tuned", qps=float(qps_tuned), us_per_call=float(us_tuned),
           recall_at_10=float(rec_tuned), **common_id)
    # Same-machine QPS ratio: machine-independent enough for the trajectory
    # gate to pin the >=1.5x tuned-vs-safe payoff as a "qps" metric.
    record(arm="speedup_tuned_vs_safe", qps=float(speedup), **common_id)

    # -- boost gain at ~1% selectivity ------------------------------------
    where = Lt("attr", int(sel_pct))
    mask = attr < sel_pct
    oracle = idx.searcher(k=k, nprobe=nlist, where=where, use_kernel=False)
    gt_f = np.asarray(oracle(queries)[1])

    idx.tuned = dataclasses.replace(tuned, boost=None)
    plain = idx.searcher(k=k, where=where, use_kernel=False)
    rec_plain = recall_at_10(np.asarray(plain(queries)[1]), gt_f)
    idx.tuned = tuned
    boosted = idx.searcher(k=k, where=where, use_kernel=False)
    rec_boost = recall_at_10(np.asarray(boosted(queries)[1]), gt_f)

    gain = rec_boost - rec_plain
    live = int(mask.sum())
    emit(f"autotune/ivf/filtered-sel{sel_pct:02d}-unboosted", float("nan"),
         f"recall={rec_plain:.3f} live={live}/{n}")
    emit(f"autotune/ivf/filtered-sel{sel_pct:02d}-boosted", float("nan"),
         f"recall={rec_boost:.3f} gain={gain:+.3f}")
    record(arm="filtered_unboosted", selectivity_pct=float(sel_pct),
           recall_at_10=float(rec_plain), **common_id)
    record(arm="filtered_boosted", selectivity_pct=float(sel_pct),
           recall_at_10=float(rec_boost), **common_id)
    # Absolute filtered-recall gain from the boost curve, pinned >= 0.15 by
    # the committed baseline (recall_at_10 gates on absolute drops).
    record(arm="boost_gain", selectivity_pct=float(sel_pct),
           recall_at_10=float(gain), **common_id)


def emit_benchmark() -> None:
    """Hook for benchmarks.run (moderate shape)."""
    bench_autotune(n=32_000, dim=128)


def emit_benchmark_smoke() -> None:
    """CI smoke hook (benchmarks.run --smoke): small shape, same code paths
    — the tune sweep, tuned serving, and the boosted filtered phase all run."""
    bench_autotune(n=8_192, dim=64, batch_q=8)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--nlist", type=int, default=64)
    ap.add_argument("--batch-q", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--recall-target", type=float, default=0.95)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_autotune(n=args.n, dim=args.dim, nlist=args.nlist,
                   batch_q=args.batch_q, k=args.k,
                   recall_target=args.recall_target)


if __name__ == "__main__":
    main()

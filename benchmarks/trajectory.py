"""Perf-trajectory gate: diff BENCH_*.json against committed baselines.

The smoke sweep (``benchmarks.run --smoke``) writes one machine-readable
``BENCH_<name>.json`` per benchmark, each carrying the structured records
appended via ``common.record`` — QPS / recall@10 / bytes-per-vector per
backend and shape.  This module compares a fresh run directory against the
committed ``benchmarks/baselines/`` and fails loudly when the trajectory
bends the wrong way:

  * ``qps``              — lower is a regression; gated by ``--qps-tol R``
                           (current must be >= R x baseline).  QPS is
                           machine-dependent, so CI runs with a lenient R.
  * ``recall_at_10``     — lower is a regression; gated by ``--recall-tol D``
                           (absolute drop > D fails).  The smoke shapes are
                           seeded and deterministic, so the default is strict.
  * ``bytes_per_vector`` — higher is a regression; gated by ``--bytes-tol R``
                           (current must be <= R x baseline).  Memory layout
                           is machine-independent, so the default is exact.

Records are matched by their identity fields — every field that is not a
metric (bench, backend, n, dim, batch_q, k, selectivity, ...).  A baseline
record with no matching current record is a coverage regression (a benchmark
silently stopped reporting); a current record absent from the baseline is
new coverage and only noted.  ``--write-baseline`` re-seeds the baseline
directory from the run directory instead of gating.

CLI (also callable as ``run(argv) -> int`` for tests):

    PYTHONPATH=src python -m benchmarks.trajectory --run-dir bench-json
    PYTHONPATH=src python -m benchmarks.trajectory --run-dir bench-json \
        --write-baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# Everything else in a record is identity.  ``us_per_call`` is raw wall time
# with no stable cross-machine meaning, so it is excluded from identity but
# never gated — qps already covers throughput with an explicit tolerance.
METRIC_FIELDS = ("qps", "recall_at_10", "bytes_per_vector", "us_per_call")
GATED_METRICS = ("qps", "recall_at_10", "bytes_per_vector")

_BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")


def _identity(bench: str, rec: Dict[str, object]) -> Tuple:
    items = tuple(sorted((k, v) for k, v in rec.items()
                         if k not in METRIC_FIELDS))
    return (bench,) + items


def load_records(json_dir: str) -> Dict[Tuple, Dict[str, float]]:
    """{identity key: {metric: value}} over every BENCH_*.json in the dir.

    Records with no metric fields (pure-timing benchmarks) carry nothing the
    gate can compare and are skipped.
    """
    out: Dict[Tuple, Dict[str, float]] = {}
    for path in sorted(glob.glob(os.path.join(json_dir, "BENCH_*.json"))):
        with open(path) as f:
            payload = json.load(f)
        for rec in payload.get("records", []):
            metrics = {k: float(rec[k]) for k in METRIC_FIELDS if k in rec}
            if not metrics:
                continue
            out[_identity(payload["bench"], rec)] = metrics
    return out


def _fmt_id(key: Tuple) -> str:
    bench, items = key[0], key[1:]
    return bench + "[" + " ".join(f"{k}={v}" for k, v in items) + "]"


def _fmt(v: float) -> str:
    return f"{v:.4g}"


def compare(current: Dict[Tuple, Dict[str, float]],
            baseline: Dict[Tuple, Dict[str, float]],
            *, qps_tol: float, recall_tol: float, bytes_tol: float,
            ) -> Tuple[List[str], List[str]]:
    """(table rows, failure messages) for the current-vs-baseline diff."""
    rows: List[str] = []
    failures: List[str] = []
    for key in sorted(baseline, key=_fmt_id):
        name = _fmt_id(key)
        if key not in current:
            failures.append(f"{name}: record missing from current run "
                            "(benchmark stopped reporting)")
            rows.append(f"  FAIL {name:<58} -- record missing")
            continue
        cur, base = current[key], baseline[key]
        for metric in GATED_METRICS:
            if metric not in base or metric not in cur:
                continue
            b, c = base[metric], cur[metric]
            if metric == "qps":
                ok = c >= qps_tol * b
                why = f"{_fmt(c)} < {qps_tol:g} x {_fmt(b)}"
            elif metric == "recall_at_10":
                ok = c >= b - recall_tol
                why = f"{_fmt(c)} < {_fmt(b)} - {recall_tol:g}"
            else:  # bytes_per_vector
                ok = c <= bytes_tol * b
                why = f"{_fmt(c)} > {bytes_tol:g} x {_fmt(b)}"
            mark = "ok  " if ok else "FAIL"
            rows.append(f"  {mark} {name:<58} {metric:<16} "
                        f"base={_fmt(b):>10} cur={_fmt(c):>10}")
            if not ok:
                failures.append(f"{name}: {metric} regressed ({why})")
    for key in sorted(set(current) - set(baseline), key=_fmt_id):
        rows.append(f"  new  {_fmt_id(key):<58} -- no baseline (noted only)")
    return rows, failures


def write_baseline(run_dir: str, baseline_dir: str) -> int:
    """Re-seed baseline_dir with the records from run_dir's BENCH files.

    Only the structured records survive — csv timing rows are machine noise
    the gate never reads, and dropping them keeps the committed baselines
    reviewable."""
    os.makedirs(baseline_dir, exist_ok=True)
    n = 0
    for path in sorted(glob.glob(os.path.join(run_dir, "BENCH_*.json"))):
        with open(path) as f:
            payload = json.load(f)
        if not payload.get("records"):
            continue
        out = {"bench": payload["bench"], "smoke": payload.get("smoke", False),
               "records": payload["records"]}
        dst = os.path.join(baseline_dir, os.path.basename(path))
        with open(dst, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        n += 1
    print(f"[trajectory] wrote {n} baseline file(s) to {baseline_dir}")
    return 0


def run(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate BENCH_*.json against committed perf baselines")
    ap.add_argument("--run-dir", required=True,
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--baseline-dir", default=_BASELINE_DIR)
    ap.add_argument("--qps-tol", type=float, default=0.85,
                    help="current qps must be >= TOL x baseline (ratio)")
    ap.add_argument("--recall-tol", type=float, default=0.0,
                    help="max allowed absolute recall_at_10 drop")
    ap.add_argument("--bytes-tol", type=float, default=1.0,
                    help="current bytes_per_vector must be <= TOL x baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="re-seed --baseline-dir from --run-dir and exit")
    args = ap.parse_args(argv)

    if args.write_baseline:
        return write_baseline(args.run_dir, args.baseline_dir)

    baseline = load_records(args.baseline_dir)
    current = load_records(args.run_dir)
    if not baseline:
        print(f"[trajectory] no baselines under {args.baseline_dir}; "
              "seed them with --write-baseline", file=sys.stderr)
        return 2
    rows, failures = compare(
        current, baseline, qps_tol=args.qps_tol,
        recall_tol=args.recall_tol, bytes_tol=args.bytes_tol)
    print(f"[trajectory] {len(baseline)} baseline record(s) vs "
          f"{len(current)} current (qps-tol={args.qps_tol:g} "
          f"recall-tol={args.recall_tol:g} bytes-tol={args.bytes_tol:g})")
    for row in rows:
        print(row)
    if failures:
        print(f"[trajectory] {len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("[trajectory] trajectory holds: no regressions")
    return 0


def main() -> None:
    sys.exit(run())


if __name__ == "__main__":
    main()

"""IVF candidate-scan: gathered packed scan vs the dequant-einsum baseline.

The pre-refactor ``IvfFlatIndex.search`` dequantized every candidate into a
``[b, max_cand, d']`` f32 tensor (8x the packed bytes) and ran an einsum over
it; the gathered scan (``ops.score_gathered``, DESIGN.md §5) scores the same
candidates straight from packed nibbles.  This benchmark keeps the old path
alive as a baseline so the speedup stays on the perf record, and adds HNSW
QPS (whose beam now rides the same primitive).

    PYTHONPATH=src python -m benchmarks.ivf_scan            # paper-scale run
        [--n 45000] [--dim 1024] [--nlist 64] [--nprobe 8]

Emits the standard ``name,us_per_call,derived`` rows.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import HnswIndex, IvfFlatIndex
from repro.core import quantize as qz
from repro.core.allowlist import NEG
from repro.core.scoring import adjust_scores, topk
from repro.core.standardize import L2
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, time_fn


def dequant_einsum_search(idx: IvfFlatIndex, queries, k: int, nprobe: int):
    """The pre-refactor IVF scan, verbatim: per-query host assembly loop,
    full f32 dequant of the gathered candidates, einsum, post-gather top-k."""
    queries = jnp.atleast_2d(queries)
    q_rot = qz.encode_query(queries, idx.enc)
    metric = idx.enc.metric
    if metric == L2:
        cs = (
            q_rot @ idx.centroids.T
            - 0.5 * jnp.sum(idx.centroids * idx.centroids, axis=1)[None, :]
        )
    else:
        cs = q_rot @ idx.centroids.T
    _, probe = topk(cs, min(nprobe, idx.nlist))
    probe = np.asarray(probe)

    counts = idx.offsets[1:] - idx.offsets[:-1]
    max_cand = int(np.sort(counts)[::-1][: min(nprobe, idx.nlist)].sum())
    max_cand = max(max_cand, k)
    b = queries.shape[0]
    cand = np.full((b, max_cand), -1, dtype=np.int64)
    for i in range(b):
        rows = np.concatenate(
            [idx.order[idx.offsets[c]: idx.offsets[c + 1]] for c in probe[i]]
        )
        cand[i, : len(rows)] = rows
    cand_j = jnp.asarray(np.maximum(cand, 0))
    valid = jnp.asarray(cand >= 0)

    packed_c = jnp.take(idx.enc.packed, cand_j, axis=0)      # [b, mc, bytes]
    qn_c = jnp.take(idx.enc.qnorms, cand_j, axis=0)
    deq = qz.decode(
        dataclasses.replace(idx.enc, packed=packed_c.reshape(-1, packed_c.shape[-1]))
    ).reshape(b, max_cand, -1)                               # [b, mc, d'] f32
    raw = jnp.einsum("bd,bmd->bm", q_rot, deq)
    scores = jnp.where(valid, adjust_scores(raw, qn_c, metric), NEG)
    vals, pos = topk(scores, min(k, max_cand))
    rows = np.take_along_axis(cand, np.asarray(pos), axis=1)
    return np.asarray(vals), idx.ids[np.maximum(rows, 0)]


def bench_ivf_scan(n: int = 12_000, dim: int = 512, nlist: int = 32,
                   nprobe: int = 8, batch_q: int = 16, k: int = 10) -> None:
    corpus = embedding_corpus(0, n, dim)
    queries = jnp.asarray(queries_from_corpus(corpus, 1, batch_q))
    idx = IvfFlatIndex.build(jnp.asarray(corpus), metric="cosine", nlist=nlist)

    us_old = time_fn(lambda: dequant_einsum_search(idx, queries, k, nprobe))
    us_new = time_fn(lambda: idx.search(queries, k, nprobe=nprobe))
    _, ids_old = dequant_einsum_search(idx, queries, k, nprobe)
    _, ids_new = idx.search(queries, k, nprobe=nprobe)
    overlap = float(np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k
        for a, b in zip(ids_old.astype(np.int64), ids_new.astype(np.int64))
    ]))

    tag = f"{n}x{dim}_nlist{nlist}_np{nprobe}"
    emit(f"ivf_scan_dequant_einsum_{tag}", us_old,
         f"{batch_q / (us_old / 1e6):.0f} QPS")
    emit(f"ivf_scan_gathered_{tag}", us_new,
         f"{batch_q / (us_new / 1e6):.0f} QPS; speedup={us_old / us_new:.2f}x; "
         f"top{k}_overlap={overlap:.2f}")


def bench_hnsw_qps(n: int = 4_000, dim: int = 256, batch_q: int = 16,
                   k: int = 10, ef: int = 64) -> None:
    corpus = embedding_corpus(3, n, dim)
    queries = jnp.asarray(queries_from_corpus(corpus, 4, batch_q))
    idx = HnswIndex.build(jnp.asarray(corpus), metric="cosine", m=16,
                          ef_construction=64)
    us = time_fn(lambda: idx.search(queries, k, ef=ef))
    emit(f"hnsw_gathered_beam_{n}x{dim}_ef{ef}", us,
         f"{batch_q / (us / 1e6):.0f} QPS")


def emit_benchmark() -> None:
    """Hook for benchmarks.run (small shapes to keep the sweep fast)."""
    bench_ivf_scan()
    bench_hnsw_qps()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=45_000)
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--nlist", type=int, default=64)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--batch-q", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--hnsw-n", type=int, default=8_000)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_ivf_scan(args.n, args.dim, args.nlist, args.nprobe, args.batch_q,
                   args.k)
    bench_hnsw_qps(args.hnsw_n)


if __name__ == "__main__":
    main()

"""One benchmark per paper table/figure (reduced scale where CPU-bound;
scale factors documented inline and in EXPERIMENTS.md)."""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BruteForceIndex, GlobalStd, HnswIndex,
                        IvfFlatIndex)
from repro.core import lloydmax, quantize as qz, scoring
from repro.core.standardize import PerDimWhiten
from repro.data import synthetic as syn
from repro.kernels import ops

from .common import emit, ground_truth, recall_at_10, time_fn


def table2_semantic_embeddings() -> None:
    """Table 2/5: recall + QPS on the AG News surrogate (45K x 1024, cosine).

    HNSW builds on an 8K subset (sequential deterministic build is O(n) host
    work — the paper itself reports 47-149 min builds at 1.18M).
    """
    n, d, nq = 45_056, 1024, 200
    # 2048 clusters / 45K docs + tight queries ~ BGE-M3-like separation (the
    # paper's 0.960 is on real semantic embeddings, not iid noise).
    corpus = syn.embedding_corpus(11, n, d, n_clusters=2048, noise=0.12)
    queries = syn.queries_from_corpus(corpus, 12, nq, noise=0.06)
    gt = ground_truth(queries, corpus, "cosine")

    bf = BruteForceIndex.build(jnp.asarray(corpus), metric="cosine")
    search = lambda: bf.search(jnp.asarray(queries), 10)
    us = time_fn(search, iters=3)
    _, ids = search()
    qps = nq / (us / 1e6)
    mem_mb = (bf.enc.packed.size + bf.enc.qnorms.size * 4 + bf.ids.size * 8) / 2**20
    emit("table2/bf4bit_recall10", us / nq, f"recall={recall_at_10(ids, gt):.3f}")
    emit("table2/bf4bit_qps", us / nq, f"qps={qps:.0f} mem_mb={mem_mb:.1f}")

    # float32 exact (sqlite-vec analogue: accuracy ceiling, 4x memory)
    t_exact = time_fn(lambda: scoring.topk(
        scoring.score_f32(jnp.asarray(queries), jnp.asarray(corpus), "cosine"), 10))
    emit("table2/f32exact_qps", t_exact / nq,
         f"qps={nq / (t_exact / 1e6):.0f} recall=1.000 mem_mb={corpus.nbytes / 2**20:.0f}")

    # HNSW on 8K subset
    sub, subq = corpus[:8192], queries[:64]
    gt_sub = ground_truth(subq, sub, "cosine")
    h = HnswIndex.build(jnp.asarray(sub), metric="cosine", m=16,
                        ef_construction=128)
    hs = lambda: h.search(jnp.asarray(subq), 10, ef=192)
    us_h = time_fn(hs, iters=2)
    _, ids_h = hs()
    emit("table2/hnsw4bit_recall10", us_h / len(subq),
         f"recall={recall_at_10(ids_h, gt_sub):.3f} n=8192")

    ivf = IvfFlatIndex.build(jnp.asarray(sub), metric="cosine", nlist=64)
    iv = lambda: ivf.search(jnp.asarray(subq), 10, nprobe=16)
    us_i = time_fn(iv, iters=2)
    _, ids_i = iv()
    emit("table2/ivf_recall10", us_i / len(subq),
         f"recall={recall_at_10(ids_i, gt_sub):.3f} nprobe=16")


def table3_l2_standardization() -> None:
    """Table 3 / Fig 7: L2 fit() ablation on the pixel surrogate."""
    corpus = syn.pixel_corpus(13, 10_000, 784)
    queries = syn.queries_from_corpus(corpus, 14, 100, noise=3.0)
    gt = ground_truth(queries, corpus, "l2")

    for name, std in [
        ("raw", None),
        ("global_fit", GlobalStd.fit(corpus)),
    ]:
        idx = BruteForceIndex.build(jnp.asarray(corpus), metric="l2", std=std)
        us = time_fn(lambda: idx.search(jnp.asarray(queries), 10), iters=2)
        _, ids = idx.search(jnp.asarray(queries), 10)
        emit(f"table3/bf_{name}", us / 100, f"recall={recall_at_10(ids, gt):.3f}")

    # per-dimension whitening ablation (paper: loses to global scaling)
    w = PerDimWhiten.fit(corpus)
    cw, qw = np.asarray(w.transform(jnp.asarray(corpus))), np.asarray(w.transform(jnp.asarray(queries)))
    idx_w = BruteForceIndex.build(jnp.asarray(cw), metric="l2")
    _, ids_w = idx_w.search(jnp.asarray(qw), 10)
    emit("table3/bf_perdim_whiten", 0.0, f"recall={recall_at_10(ids_w, gt):.3f}")

    # HNSW with metric-aware build (contribution #3) vs dot-product build
    std = GlobalStd.fit(corpus)
    sub, subq = corpus[:4096], queries[:50]
    gt_sub = ground_truth(subq, sub, "l2")
    h = HnswIndex.build(jnp.asarray(sub), metric="l2", std=std, m=16,
                        ef_construction=96)
    _, ids_h = h.search(jnp.asarray(subq), 10, ef=128)
    emit("table3/hnsw_l2_fit", 0.0, f"recall={recall_at_10(ids_h, gt_sub):.3f}")


def table4_auto_m() -> None:
    """Table 4: M must scale with N (scaled demonstration at 10K; the paper's
    1.18M build takes 47-149 min single-threaded — same policy, bigger N)."""
    corpus = syn.embedding_corpus(15, 10_000, 100, n_clusters=256)
    queries = syn.queries_from_corpus(corpus, 16, 64)
    gt = ground_truth(queries, corpus, "cosine")
    for m in (4, 8, 16):
        h = HnswIndex.build(jnp.asarray(corpus), metric="cosine", m=m,
                            ef_construction=64)
        us = time_fn(lambda: h.search(jnp.asarray(queries), 10, ef=64), iters=2)
        _, ids = h.search(jnp.asarray(queries), 10, ef=64)
        emit(f"table4/hnsw_m{m}", us / 64,
             f"recall={recall_at_10(ids, gt):.3f} (diameter shrinks with M)")
    from repro.core import recommended_m
    emit("table4/auto_m_policy", 0.0,
         f"M(45K)={recommended_m(45_000)} M(1.18M)={recommended_m(1_180_000)}")


def table7_lloydmax_vs_uniform() -> None:
    """Table 7: Lloyd-Max vs uniform 4-bit on synthetic Gaussian."""
    rng = np.random.RandomState(17)
    for d in (384, 768, 1536):
        corpus = rng.randn(4000, d).astype(np.float32)
        queries = rng.randn(64, d).astype(np.float32)
        gt = ground_truth(queries, corpus, "cosine")
        recs = {}
        for table in ("lloydmax", "uniform"):
            enc = qz.encode(jnp.asarray(corpus), metric="cosine", seed=1,
                            table=table)
            qr = qz.encode_query(jnp.asarray(queries), enc)
            s = scoring.score_packed_ref(qr, enc)
            _, ids = scoring.topk(s, 10)
            recs[table] = recall_at_10(np.asarray(ids), gt)
        gain = (recs["lloydmax"] - recs["uniform"]) / max(recs["uniform"], 1e-9)
        emit(f"table7/d{d}", 0.0,
             f"lloydmax={recs['lloydmax']:.3f} uniform={recs['uniform']:.3f} "
             f"gain={100 * gain:.1f}%")


def fig3_mixed_precision() -> None:
    """Fig 3: mixed 4/2-bit water-filling on anisotropic Gaussian (low-rank
    structure is where the variance permutation pays — paper §3.2)."""
    rng = np.random.RandomState(19)
    d = 1024
    spectrum = np.exp(-np.arange(d) / 80).astype(np.float32)   # low-rank-ish
    corpus = (rng.randn(4000, d) * spectrum).astype(np.float32)
    queries = (rng.randn(64, d) * spectrum).astype(np.float32)
    gt = ground_truth(queries, corpus, "cosine")

    def run(enc):
        qr = qz.encode_query(jnp.asarray(queries), enc)
        s = ops.score_packed(qr, enc, use_kernel=False)
        _, ids = scoring.topk(s, 10)
        return recall_at_10(np.asarray(ids), gt)

    enc4 = qz.encode(jnp.asarray(corpus), metric="cosine", seed=2, bits=4)
    enc2 = qz.encode(jnp.asarray(corpus), metric="cosine", seed=2, bits=2)
    enc3 = qz.encode_mixed(jnp.asarray(corpus), metric="cosine", seed=2,
                           avg_bits=3.0)
    # v7 extension: persisted variance permutation (paper computes, drops it)
    from repro.core.rhdh import rhdh_apply
    from repro.core.standardize import prepare
    rot = rhdh_apply(prepare(jnp.asarray(corpus[:512]), "cosine"), 2,
                     normalized=False)
    perm = qz.variance_permutation(rot)
    enc3p = qz.encode_mixed(jnp.asarray(corpus), metric="cosine", seed=2,
                            avg_bits=3.0, perm=perm)
    for name, enc in [("pure4bit", enc4), ("mixed3bit_leading", enc3),
                      ("mixed3bit_perm_v7", enc3p), ("pure2bit", enc2)]:
        comp = corpus.nbytes / enc.packed.size
        emit(f"fig3/{name}", 0.0, f"recall={run(enc):.3f} compression={comp:.1f}x")


def table6_cross_kernel_reproducibility() -> None:
    """Table 6 (§4.6): the same index scored by two independent kernel paths
    (Pallas compare-select vs pure-jnp table lookup — our AVX2-vs-scalar
    analogue) must agree on the top-10 set; plus the affine-ramp NEON bug
    reproduced deliberately to show why table lookup matters."""
    corpus = syn.embedding_corpus(21, 8192, 1024)
    queries = syn.queries_from_corpus(corpus, 22, 100)
    gt = ground_truth(queries, corpus, "cosine")
    enc = qz.encode(jnp.asarray(corpus), metric="cosine", seed=6)
    qr = qz.encode_query(jnp.asarray(queries), enc)

    s_kernel = ops.score_packed(qr, enc, use_kernel=True, interpret=True)
    s_ref = scoring.score_packed_ref(qr, enc)
    _, ids_k = scoring.topk(s_kernel, 10)
    _, ids_r = scoring.topk(s_ref, 10)
    set_match = np.mean([set(a.tolist()) == set(b.tolist())
                         for a, b in zip(np.asarray(ids_k), np.asarray(ids_r))])
    order_match = np.mean((np.asarray(ids_k) == np.asarray(ids_r)).all(axis=1))
    emit("table6/kernel_vs_ref", 0.0,
         f"set_match={100 * set_match:.1f}% order_match={100 * order_match:.1f}% "
         f"recall={recall_at_10(np.asarray(ids_k), gt):.4f}")

    # The paper's NEON bug: centroid(i) ~ A + B*i (affine ramp). Lloyd-Max
    # centroids are non-uniform, so this is wrong for i >= 2.
    c = lloydmax.CENTROIDS_4BIT
    ramp = c[0] + (c[1] - c[0]) * np.arange(16, dtype=np.float32)
    codes = qz.unpack_4bit(enc.packed)
    deq_bug = jnp.take(jnp.asarray(ramp), codes.astype(jnp.int32))
    raw_bug = qr @ deq_bug.T
    s_bug = scoring.adjust_scores(raw_bug, enc.qnorms, enc.metric)
    _, ids_b = scoring.topk(s_bug, 10)
    set_match_b = np.mean([set(a.tolist()) == set(b.tolist())
                           for a, b in zip(np.asarray(ids_b), np.asarray(ids_r))])
    emit("table6/affine_ramp_bug", 0.0,
         f"recall={recall_at_10(np.asarray(ids_b), gt):.4f} "
         f"set_match={100 * set_match_b:.1f}% (degrades, monotone ramp)")


def bench_quantized_kv_decode() -> None:
    """Beyond-paper: MonaVec 4-bit KV cache in LM decode (smoke scale)."""
    import repro.configs as C
    from repro.models import transformer as tf
    cfg = C.get("llama3.2-3b").make_smoke()
    params = tf.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 1), 0, cfg.vocab)

    for name, quant in (("bf16_cache", False), ("quant4_cache", True)):
        cache = tf.init_decode_cache(cfg, 4, 128, quantized=quant)
        # no donation here: the timing loop reuses the same cache buffers
        step = jax.jit(lambda c, t, n, q=quant: tf.decode_step(
            params, cfg, c, t, n, quantized=q))
        lg, cache = step(cache, toks, jnp.int32(0))
        us = time_fn(lambda: step(cache, toks, jnp.int32(5))[0], iters=3)
        cache_bytes = sum(l.nbytes for l in jax.tree.leaves(cache))
        emit(f"kvquant/{name}", us, f"cache_bytes={cache_bytes}")

"""Binarized cascade vs the full 4-bit scan (DESIGN.md §11 gate).

The cascade's claim is a memory-bandwidth trade: the coarse pass reads
dim_pad/8 bytes per row (sign) instead of the full scan's dim_pad/2, and
only ``m = rescore_mult * k`` survivors per segment pay the 4-bit gathered
rescore.  This bench measures both sides of the claim on the same corpus:

  * QPS of the full scan (``rescore_mult`` absent — the plain plan) vs the
    cascade at the default budget, same index, same queries;
  * recall@10 of each against the exact f32 oracle, plus the cascade's
    overlap with the full scan's own ids (the cascade can only lose rows
    the coarse proxy misranks — this is the number the ≥0.95x acceptance
    bound pins).

The paper-scale point is 1M x 1024 (acceptance: cascade ≥ 3x the full
scan's QPS at ≥ 0.95x its recall@10); 45k x 1024 shows the same shape at
a size where the full scan is still comfortably cache-resident.

    PYTHONPATH=src python -m benchmarks.cascade_bench [--n 45000] [--dim 1024]

Emits the standard ``name,us_per_call,derived`` rows plus structured
records for the BENCH_cascade.json artifact (``bytes_per_vector`` is the
FIRST-PASS bytes read per row: the coarse plane for the cascade, the
packed codes for the full scan — the compression the paper claims).
"""

from __future__ import annotations

import argparse
from typing import Sequence

import numpy as np

from repro.core import MonaVec
from repro.core.binary import DEFAULT_RESCORE_MULT
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, ground_truth, recall_at_10, record, time_fn


def bench_cascade(n: int = 45_000, dim: int = 1024, batch_q: int = 16,
                  k: int = 10, kinds: Sequence[str] = ("sign", "crumb"),
                  rescore_mults: Sequence[int] = (DEFAULT_RESCORE_MULT,),
                  ) -> None:
    corpus = embedding_corpus(41, n, dim)
    queries = np.asarray(queries_from_corpus(corpus, 141, batch_q))
    gt = ground_truth(queries, corpus, "cosine", k)

    # ONE build serves every kind: the coarse code is a pure function of
    # the packed nibbles, so enable_coarse just re-derives the codes, and
    # the plan cache keys on enc.coarse — the full scan is the SAME plan
    # either way and is measured once as the shared baseline.
    idx = MonaVec.build(corpus, metric="cosine")
    packed_bpv = int(idx.backend.enc.packed.shape[-1])

    full = idx.searcher(k=k, use_kernel=False)
    full.warmup(batch_q)
    us_full = time_fn(lambda: full(queries))
    ids_full = np.asarray(full(queries)[1])
    rec_full = recall_at_10(ids_full, gt)
    qps_full = batch_q / (us_full / 1e6)
    emit(f"cascade/fullscan/n{n}", us_full,
         f"qps={qps_full:.1f} recall={rec_full:.3f} "
         f"bytes_per_vec={packed_bpv}")
    record(bench="cascade", kind="full", n=n, dim=dim, batch_q=batch_q,
           k=k, rescore_mult=0, qps=float(qps_full),
           recall_at_10=float(rec_full), bytes_per_vector=packed_bpv,
           us_per_call=float(us_full))

    for kind in kinds:
        idx.enable_coarse(kind)
        code_bpv = int(idx.backend.enc.ccodes.shape[-1])

        for rm in rescore_mults:
            casc = idx.searcher(k=k, use_kernel=False, rescore_mult=rm)
            casc.warmup(batch_q)
            us = time_fn(lambda: casc(queries))
            ids = np.asarray(casc(queries)[1])
            rec = recall_at_10(ids, gt)
            rec_vs_full = recall_at_10(ids, ids_full)
            qps = batch_q / (us / 1e6)
            speedup = us_full / us
            emit(f"cascade/{kind}/n{n}/rm{rm}", us,
                 f"qps={qps:.1f} recall={rec:.3f} "
                 f"vs_fullscan={rec_vs_full:.3f} speedup={speedup:.2f}x "
                 f"m={rm * k} bytes_per_vec={code_bpv}")
            record(bench="cascade", kind=kind, n=n, dim=dim, batch_q=batch_q,
                   k=k, rescore_mult=int(rm), qps=float(qps),
                   recall_at_10=float(rec), bytes_per_vector=code_bpv,
                   us_per_call=float(us))


def emit_benchmark() -> None:
    """Hook for benchmarks.run: the acceptance shapes (45k and 1M x 1024)."""
    bench_cascade(n=45_000, dim=1024)
    bench_cascade(n=1_000_000, dim=1024)


def emit_benchmark_smoke() -> None:
    """CI smoke hook: tiny shape, both coarse kinds, same code paths — the
    cascade plan (coarse_scan -> survivor_topk -> gathered_rescore) compiles
    and is gated on recall/qps/bytes against the committed baseline."""
    bench_cascade(n=4_096, dim=128, batch_q=4, kinds=("sign", "crumb"),
                  rescore_mults=(8,))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=45_000)
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--batch-q", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--kinds", default="sign,crumb")
    ap.add_argument("--rescore-mults", default=str(DEFAULT_RESCORE_MULT))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_cascade(n=args.n, dim=args.dim, batch_q=args.batch_q, k=args.k,
                  kinds=tuple(args.kinds.split(",")),
                  rescore_mults=tuple(
                      int(r) for r in args.rescore_mults.split(",")))


if __name__ == "__main__":
    main()

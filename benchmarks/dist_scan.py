"""Sharded vs single-device scan throughput (the repro.dist perf baseline).

Run standalone to control the device count (it must be set before jax
imports, so the hook in benchmarks.run measures whatever the process has —
1 device unless the caller exported XLA_FLAGS):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python -m benchmarks.dist_scan [--n 65536] [--dim 512]

Emits the standard ``name,us_per_call,derived`` rows: single-device pjit
scan, shard_map scan, and the merge-correctness check (ids must match).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, time_fn


def bench_dist_scan(n: int = 16_384, dim: int = 256, batch_q: int = 32,
                    k: int = 10) -> None:
    from repro.dist.retrieval import make_scan_topk_shardmap, scan_topk_pjit

    corpus = embedding_corpus(0, n, dim)
    queries = queries_from_corpus(corpus, 1, batch_q)
    enc = qz.encode(jnp.asarray(corpus), metric="cosine")
    q_rot = qz.encode_query(jnp.asarray(queries), enc)

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

    with mesh:
        us_pjit = time_fn(lambda: scan_topk_pjit(
            q_rot, enc.packed, enc.qnorms, metric="cosine", k=k))
        fn = make_scan_topk_shardmap(mesh, metric="cosine", k=k)
        us_sm = time_fn(lambda: fn(q_rot, enc.packed, enc.qnorms))
        _, i1 = scan_topk_pjit(q_rot, enc.packed, enc.qnorms,
                               metric="cosine", k=k)
        _, i2 = fn(q_rot, enc.packed, enc.qnorms)
    identical = bool(np.array_equal(np.asarray(i1), np.asarray(i2)))

    qps_pjit = batch_q / (us_pjit / 1e6)
    qps_sm = batch_q / (us_sm / 1e6)
    emit(f"dist_scan_pjit_{n}x{dim}", us_pjit, f"{qps_pjit:.0f} QPS")
    emit(f"dist_scan_shardmap_{n}x{dim}_dev{n_dev}", us_sm,
         f"{qps_sm:.0f} QPS; ids_identical={identical}")


def emit_benchmark() -> None:
    """Hook for benchmarks.run (small shapes; device count as inherited)."""
    bench_dist_scan()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65_536)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--batch-q", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_dist_scan(args.n, args.dim, args.batch_q, args.k)


if __name__ == "__main__":
    main()

# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run [--only X]`."""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()

    from . import dist_scan
    from . import ivf_scan
    from . import paper_tables as pt
    from . import roofline

    benches = [
        ("table2_semantic_embeddings", pt.table2_semantic_embeddings),
        ("table3_l2_standardization", pt.table3_l2_standardization),
        ("table4_auto_m", pt.table4_auto_m),
        ("table7_lloydmax_vs_uniform", pt.table7_lloydmax_vs_uniform),
        ("fig3_mixed_precision", pt.fig3_mixed_precision),
        ("table6_cross_kernel_reproducibility", pt.table6_cross_kernel_reproducibility),
        ("bench_quantized_kv_decode", pt.bench_quantized_kv_decode),
        ("dist_scan", dist_scan.emit_benchmark),
        ("ivf_scan", ivf_scan.emit_benchmark),
        ("roofline", roofline.emit_benchmark),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

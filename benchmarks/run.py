# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run [--only X]`.

``--smoke`` runs the CI drift gate: every benchmark that has a small-shape
variant executes end to end (same code paths, tiny problem sizes) so a
kernel or benchmark regression fails the build in minutes; benchmarks with
no cheap variant are skipped and say so.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="small-shape CI sweep (skips benchmarks without a "
                         "smoke variant)")
    args = ap.parse_args()

    from . import dist_scan
    from . import engine_bench
    from . import ivf_scan
    from . import paper_tables as pt
    from . import roofline
    from . import segments_bench

    # (name, full run, smoke run or None).
    benches = [
        ("table2_semantic_embeddings", pt.table2_semantic_embeddings, None),
        ("table3_l2_standardization", pt.table3_l2_standardization, None),
        ("table4_auto_m", pt.table4_auto_m, pt.table4_auto_m),
        ("table7_lloydmax_vs_uniform", pt.table7_lloydmax_vs_uniform, None),
        ("fig3_mixed_precision", pt.fig3_mixed_precision, None),
        ("table6_cross_kernel_reproducibility",
         pt.table6_cross_kernel_reproducibility, None),
        ("bench_quantized_kv_decode", pt.bench_quantized_kv_decode, None),
        ("dist_scan", dist_scan.emit_benchmark,
         lambda: dist_scan.bench_dist_scan(n=4_096, dim=128, batch_q=8)),
        ("ivf_scan", ivf_scan.emit_benchmark,
         lambda: (ivf_scan.bench_ivf_scan(n=2_048, dim=128, nlist=8),
                  ivf_scan.bench_hnsw_qps(n=1_024, dim=128, batch_q=4))),
        ("segments", segments_bench.emit_benchmark,
         segments_bench.emit_benchmark_smoke),
        ("engine", engine_bench.emit_benchmark,
         engine_bench.emit_benchmark_smoke),
        ("roofline", roofline.emit_benchmark, None),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn, smoke_fn in benches:
        if args.only and args.only not in name:
            continue
        if args.smoke:
            if smoke_fn is None:
                print(f"{name},nan,SKIPPED(no smoke variant)", flush=True)
                continue
            fn = smoke_fn
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

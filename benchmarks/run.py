# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run [--only X]`.

``--smoke`` runs the CI drift gate: every benchmark that has a small-shape
variant executes end to end (same code paths, tiny problem sizes) so a
kernel or benchmark regression fails the build in minutes; benchmarks with
no cheap variant are skipped and say so.

Besides the CSV on stdout, every executed benchmark writes a machine-
readable ``BENCH_<name>.json`` next to the working directory (or under
``--json-dir``): the csv rows it printed plus any structured records it
appended via ``common.record`` (QPS / recall / bytes-per-vector per
backend and shape).  The sweep also dumps the process-wide metrics
registry (``repro.obs``) as ``METRICS_SNAPSHOT.json`` in the same
directory — plan-cache counters and per-stage latency histograms for the
whole run.  CI uploads both as workflow artifacts and gates the records
against ``benchmarks/baselines/`` via ``benchmarks.trajectory``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def _write_json(json_dir: str, name: str, status: str, smoke: bool,
                rows, records) -> None:
    payload = {
        "bench": name,
        "status": status,
        "smoke": smoke,
        "csv_rows": [{"name": r[0], "us_per_call": r[1], "derived": r[2]}
                     for r in rows],
        "records": list(records),
    }
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="small-shape CI sweep (skips benchmarks without a "
                         "smoke variant)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_<name>.json artifacts")
    args = ap.parse_args()

    from . import autotune_bench
    from . import cascade_bench
    from . import common
    from . import dist_scan
    from . import engine_bench
    from . import filtered_bench
    from . import ivf_scan
    from . import paper_tables as pt
    from . import roofline
    from . import segments_bench

    # (name, full run, smoke run or None).
    benches = [
        ("table2_semantic_embeddings", pt.table2_semantic_embeddings, None),
        ("table3_l2_standardization", pt.table3_l2_standardization, None),
        ("table4_auto_m", pt.table4_auto_m, pt.table4_auto_m),
        ("table7_lloydmax_vs_uniform", pt.table7_lloydmax_vs_uniform, None),
        ("fig3_mixed_precision", pt.fig3_mixed_precision, None),
        ("table6_cross_kernel_reproducibility",
         pt.table6_cross_kernel_reproducibility, None),
        ("bench_quantized_kv_decode", pt.bench_quantized_kv_decode, None),
        ("dist_scan", dist_scan.emit_benchmark,
         lambda: dist_scan.bench_dist_scan(n=4_096, dim=128, batch_q=8)),
        ("ivf_scan", ivf_scan.emit_benchmark,
         lambda: (ivf_scan.bench_ivf_scan(n=2_048, dim=128, nlist=8),
                  ivf_scan.bench_hnsw_qps(n=1_024, dim=128, batch_q=4))),
        ("segments", segments_bench.emit_benchmark,
         segments_bench.emit_benchmark_smoke),
        ("engine", engine_bench.emit_benchmark,
         engine_bench.emit_benchmark_smoke),
        ("filtered", filtered_bench.emit_benchmark,
         filtered_bench.emit_benchmark_smoke),
        ("cascade", cascade_bench.emit_benchmark,
         cascade_bench.emit_benchmark_smoke),
        ("autotune", autotune_bench.emit_benchmark,
         autotune_bench.emit_benchmark_smoke),
        ("roofline", roofline.emit_benchmark, None),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn, smoke_fn in benches:
        if args.only and args.only not in name:
            continue
        if args.smoke:
            if smoke_fn is None:
                print(f"{name},nan,SKIPPED(no smoke variant)", flush=True)
                continue
            fn = smoke_fn
        rows_at, recs_at = len(common.ROWS), len(common.RECORDS)
        try:
            fn()
            status = "ok"
        except Exception:  # noqa: BLE001
            failed += 1
            status = "error"
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
        _write_json(args.json_dir, name, status, args.smoke,
                    common.ROWS[rows_at:], common.RECORDS[recs_at:])

    # The whole sweep ran through the instrumented engine; snapshot the
    # registry next to the BENCH files (deterministic bucket edges make the
    # histogram SHAPE diffable across runs even though counts are timing).
    from repro import obs
    os.makedirs(args.json_dir, exist_ok=True)
    with open(os.path.join(args.json_dir, "METRICS_SNAPSHOT.json"), "w") as f:
        f.write(obs.registry().snapshot_json())
        f.write("\n")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

"""Query-execution engine cost model: plans, buckets, micro-batches.

What the engine (DESIGN.md §7) buys over ad-hoc dispatch:

  * ``per_call``  — the no-cache baseline: the plan cache is cleared before
    every batch, so every call pays plan build + jit trace + compile (what
    a shape-wobbling serving loop used to pay on every new shape);
  * ``cached``    — the serving path: one warm-up compile, then every batch
    is a plan-cache hit.  Asserts ZERO retraces across the measured loop —
    the acceptance criterion of the engine;
  * ``wobble``    — batch sizes wobble inside one power-of-two bucket; still
    zero retraces (bucketed padding is bit-identical, so serving never
    re-compiles on ragged traffic);
  * ``micro``     — many small multi-tenant requests coalesced by the
    MicroBatcher into few bucketed executions, vs the same requests served
    solo.

    PYTHONPATH=src python -m benchmarks.engine_bench [--n 16000] [--dim 512]

Emits the standard ``name,us_per_call,derived`` rows.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro import engine
from repro.core import MonaVec, TenantRegistry
from repro.data.synthetic import embedding_corpus, queries_from_corpus

from .common import emit, record, time_fn


def _batches(corpus, batch_q: int, count: int):
    return [np.asarray(queries_from_corpus(corpus, 100 + i, batch_q))
            for i in range(count)]


def bench_engine(n: int = 16_000, dim: int = 512, batch_q: int = 16,
                 k: int = 10, batches: int = 8, tenants: int = 4) -> None:
    cache = engine.plan_cache()
    corpus = embedding_corpus(51, n, dim)
    idx = MonaVec.build(corpus, metric="cosine")
    qs = _batches(corpus, batch_q, batches)

    # --- per-call: every batch re-builds + re-traces its plan. -------------
    retraces = 0
    t0 = time.perf_counter()
    for q in qs:
        cache.clear()            # clearing resets counters: tally per batch
        idx.search(q, k, use_kernel=False)
        retraces += cache.stats.traces
    dt = time.perf_counter() - t0
    us_per_call = dt / batches * 1e6
    emit("engine/per_call", us_per_call,
         f"batches={batches} retraces={retraces}")

    # --- cached plan: warm once, then hits only. ---------------------------
    cache.clear()
    search = idx.searcher(k=k, use_kernel=False).warmup(batch_q)
    warm = cache.stats.snapshot()
    t0 = time.perf_counter()
    for q in qs:
        search(q)
    dt = time.perf_counter() - t0
    us_cached = dt / batches * 1e6
    d = cache.stats.since(warm)
    assert d.traces == 0, f"cached plan retraced {d.traces}x"
    assert d.misses == 0, f"cached plan missed {d.misses}x"
    emit("engine/cached", us_cached,
         f"hits={d.hits} retraces=0 speedup={us_per_call / us_cached:.1f}x")
    record(path="cached", backend="BruteForceIndex", n=n, dim=dim,
           batch_q=batch_q, k=k, retraces=0,
           qps=batch_q / (us_cached / 1e6))

    # --- bucket wobble: ragged batch sizes, one bucket, zero retraces. -----
    sizes = [batch_q, batch_q - 1, batch_q // 2 + 1, batch_q - 3]
    sizes = [max(1, min(batch_q, s)) for s in sizes]
    before = cache.stats.snapshot()
    us = time_fn(lambda: [search(qs[i][: sizes[i % len(sizes)]])
                          for i in range(batches)])
    d = cache.stats.since(before)
    assert d.traces == 0, f"bucketed wobble retraced {d.traces}x"
    emit("engine/wobble", us / batches,
         f"sizes={sorted(set(sizes))} retraces=0")

    # --- micro-batched multi-tenant serving. -------------------------------
    reg = TenantRegistry()
    per_tenant = max(1, batch_q // tenants)
    for t in range(tenants):
        reg.put(f"tenant{t}", "docs", idx)   # same-shape corpora share plans

    def solo():
        for t in range(tenants):
            for q in qs[:2]:
                reg.get(f"tenant{t}", "docs").search(
                    q[:per_tenant], k=k, use_kernel=False)

    def micro():
        mb = engine.MicroBatcher(reg, use_kernel=False)
        tickets = [mb.submit(f"tenant{t}", "docs", q[:per_tenant], k=k)
                   for t in range(tenants) for q in qs[:2]]
        mb.flush()
        for tk in tickets:
            tk.result()
        return mb

    solo()      # warm both shapes
    micro()
    us_solo = time_fn(solo)
    us_micro = time_fn(micro)
    mb = micro()
    emit("engine/micro_batched", us_micro,
         f"requests={mb.stats.requests} executions={mb.stats.executions} "
         f"solo_us={us_solo:.0f} speedup={us_solo / us_micro:.1f}x")


def emit_benchmark() -> None:
    """Hook for benchmarks.run (small shapes to keep the sweep fast)."""
    bench_engine(n=8_000, dim=256)


def emit_benchmark_smoke() -> None:
    """CI smoke hook (benchmarks.run --smoke): tiny shapes, same code paths
    — including the zero-retrace assertions."""
    bench_engine(n=1_024, dim=64, batch_q=4, batches=4, tenants=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16_000)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--batch-q", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batches", type=int, default=8)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_engine(n=args.n, dim=args.dim, batch_q=args.batch_q, k=args.k,
                 batches=args.batches)


if __name__ == "__main__":
    main()

"""Hypothesis property suite for the segmented lifecycle (DESIGN.md §6).

Random interleavings of add/delete/compact across metric × bits × backend:
  * search() must match the per-segment brute-force oracle over the
    surviving rows' codes (exact for BruteForce — the scan IS the oracle
    computation; tie-robust admissible-set equality for IVF/HNSW, which
    score candidates through the gathered-scan tiling);
  * two identical op sequences must serialize byte-identically.

Op sequences are generated as abstract tokens (op kind + integer seeds) and
materialized through RandomState, so hypothesis shrinking stays cheap and
every example is replayable.
"""

import tempfile

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from tests.lifecycle_harness import (apply_ops, assert_matches_oracle,  # noqa: E402
                                     assert_topk_admissible, build_index,
                                     save_digest)

DIM = 8

_add = st.tuples(st.just("add"), st.integers(0, 2**16),
                 st.integers(min_value=1, max_value=5))
_delete = st.tuples(st.just("delete"),
                    st.lists(st.integers(0, 40), min_size=1, max_size=4))
_compact = st.tuples(st.just("compact"))
op_sequences = st.lists(st.one_of(_add, _delete, _compact),
                        min_size=1, max_size=6)


def _materialize(tokens):
    """Abstract op tokens → concrete ops (pure function of the tokens)."""
    out = []
    for tok in tokens:
        if tok[0] == "add":
            rng = np.random.RandomState(tok[1])
            out.append(("add", rng.randn(tok[2], DIM).astype(np.float32)))
        elif tok[0] == "delete":
            out.append(("delete", list(tok[1])))
        else:
            out.append(("compact",))
    return out


def _base(seed: int, n: int = 24) -> np.ndarray:
    return np.random.RandomState(seed).randn(n, DIM).astype(np.float32)


COMMON = dict(deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])


class TestBruteForceExactEquivalence:
    @settings(max_examples=25, **COMMON)
    @given(tokens=op_sequences,
           metric=st.sampled_from(["cosine", "dot", "l2"]),
           bits=st.sampled_from([4, 2]))
    def test_search_equals_oracle(self, tokens, metric, bits):
        idx = build_index("bruteforce", _base(1), metric=metric, bits=bits)
        apply_ops(idx, _materialize(tokens))
        q = np.random.RandomState(2).randn(3, DIM).astype(np.float32)
        if idx.n_live == 0:
            return
        assert_matches_oracle(idx, q, 8, "bruteforce", use_kernel=False)

    @settings(max_examples=10, **COMMON)
    @given(tokens=op_sequences)
    def test_kernel_interpret_path(self, tokens):
        idx = build_index("bruteforce", _base(3))
        apply_ops(idx, _materialize(tokens))
        q = np.random.RandomState(4).randn(2, DIM).astype(np.float32)
        if idx.n_live == 0:
            return
        assert_matches_oracle(idx, q, 6, "bruteforce",
                              use_kernel=True, interpret=True)


class TestIndexedBackendEquivalence:
    @settings(max_examples=6, **COMMON)
    @given(tokens=op_sequences, metric=st.sampled_from(["cosine", "l2"]))
    def test_ivf_admissible(self, tokens, metric):
        idx = build_index("ivf", _base(5), metric=metric, nlist=3)
        apply_ops(idx, _materialize(tokens))
        q = np.random.RandomState(6).randn(2, DIM).astype(np.float32)
        if idx.n_live == 0:
            return
        assert_topk_admissible(idx, q, 6, "ivf", use_kernel=False)

    @settings(max_examples=6, **COMMON)
    @given(tokens=op_sequences, metric=st.sampled_from(["cosine", "l2"]))
    def test_hnsw_admissible(self, tokens, metric):
        idx = build_index("hnsw", _base(7), metric=metric, m=4,
                          ef_construction=24)
        apply_ops(idx, _materialize(tokens))
        q = np.random.RandomState(8).randn(2, DIM).astype(np.float32)
        if idx.n_live == 0:
            return
        assert_topk_admissible(idx, q, 6, "hnsw", use_kernel=False)


class TestReplayByteIdentity:
    @settings(max_examples=12, **COMMON)
    @given(tokens=op_sequences,
           kind=st.sampled_from(["bruteforce", "ivf"]),
           metric=st.sampled_from(["cosine", "l2"]))
    def test_identical_sequences_identical_bytes(self, tokens, kind, metric):
        ops_list = _materialize(tokens)
        digests = []
        with tempfile.TemporaryDirectory() as d:
            for run in range(2):
                idx = build_index(kind, _base(9), metric=metric)
                apply_ops(idx, ops_list)
                digests.append(save_digest(idx, d, f"run{run}.mvec"))
        assert digests[0] == digests[1]

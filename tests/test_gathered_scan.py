"""Gathered candidate-scan contract (DESIGN.md §5).

Pins the three properties the IVF/HNSW refactor restored:
  * ``use_kernel`` is honored — the jnp path and the interpret-mode kernel
    path return bit-identical (scores, ids) for both backends;
  * the search path never materializes a dequantized f32 copy of the
    candidates (``quantize.decode`` is dead code during search);
  * the gathered scan matches the old dequant-einsum scoring numerically.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Allowlist, HnswIndex, IvfFlatIndex
from repro.core import quantize as qz
from repro.core.allowlist import NEG
from repro.core.scoring import adjust_scores, topk
from repro.data.synthetic import embedding_corpus, queries_from_corpus
from repro.kernels import ops


@pytest.fixture(scope="module")
def corpus():
    return embedding_corpus(7, 900, 128)


@pytest.fixture(scope="module")
def queries(corpus):
    return queries_from_corpus(corpus, 8, 9)


class TestUseKernelContract:
    """search(use_kernel=False) ≡ search(use_kernel=True, interpret=True),
    bit for bit — the contract the old IVF search silently dropped."""

    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    @pytest.mark.parametrize("bits", [4, 2])
    def test_ivf_bit_identical(self, metric, bits, corpus, queries):
        idx = IvfFlatIndex.build(jnp.asarray(corpus), metric=metric,
                                 bits=bits, nlist=16)
        s_jnp, i_jnp = idx.search(jnp.asarray(queries), 10, nprobe=4,
                                  use_kernel=False)
        s_krn, i_krn = idx.search(jnp.asarray(queries), 10, nprobe=4,
                                  use_kernel=True, interpret=True)
        np.testing.assert_array_equal(s_jnp, s_krn)
        np.testing.assert_array_equal(i_jnp, i_krn)

    @pytest.mark.parametrize("metric", ["cosine", "l2"])
    @pytest.mark.parametrize("bits", [4, 2])
    def test_hnsw_bit_identical(self, metric, bits, corpus, queries):
        idx = HnswIndex.build(jnp.asarray(corpus[:400]), metric=metric,
                              bits=bits, m=8, ef_construction=40)
        s_jnp, i_jnp = idx.search(jnp.asarray(queries), 5, ef=24,
                                  use_kernel=False)
        s_krn, i_krn = idx.search(jnp.asarray(queries), 5, ef=24,
                                  use_kernel=True, interpret=True)
        np.testing.assert_array_equal(s_jnp, s_krn)
        np.testing.assert_array_equal(i_jnp, i_krn)


class TestNoDequantMaterialization:
    """The candidate scan reads packed bytes directly: a search must succeed
    even when full-corpus dequantization is impossible."""

    def _poison(self, monkeypatch):
        def boom(*a, **k):  # pragma: no cover - called only on regression
            raise AssertionError(
                "quantize.decode called on the search path — the gathered "
                "scan must score packed bytes directly"
            )
        monkeypatch.setattr(qz, "decode", boom)
        monkeypatch.setattr(qz, "decode_mixed", boom)

    def test_ivf_search_never_decodes(self, corpus, queries, monkeypatch):
        # Distinctive shapes -> fresh jit traces while decode is poisoned.
        idx = IvfFlatIndex.build(jnp.asarray(corpus[:713]), metric="cosine",
                                 nlist=11)
        self._poison(monkeypatch)
        _, ids = idx.search(jnp.asarray(queries[:5]), 7, nprobe=3,
                            use_kernel=False)
        assert ids.shape == (5, 7)

    def test_hnsw_search_never_decodes(self, corpus, queries, monkeypatch):
        idx = HnswIndex.build(jnp.asarray(corpus[:311]), metric="cosine",
                              m=8, ef_construction=40)
        self._poison(monkeypatch)
        _, ids = idx.search(jnp.asarray(queries[:5]), 3, ef=17,
                            use_kernel=False)
        assert ids.shape == (5, 3)


class TestAgainstDequantEinsum:
    """(scores, ids) match the pre-refactor dequant-einsum reference."""

    def _reference(self, idx, queries, k, nprobe, allow=None):
        """The old IvfFlatIndex.search scoring, as shipped before DESIGN §5."""
        q_rot = qz.encode_query(jnp.atleast_2d(queries), idx.enc)
        metric = idx.enc.metric
        if metric == "l2":
            cs = (q_rot @ idx.centroids.T
                  - 0.5 * jnp.sum(idx.centroids ** 2, axis=1)[None, :])
        else:
            cs = q_rot @ idx.centroids.T
        _, probe = topk(cs, nprobe)
        probe = np.asarray(probe)
        b = q_rot.shape[0]
        max_cand = int(np.max(idx.offsets[1:] - idx.offsets[:-1])) * nprobe
        cand = np.full((b, max_cand), -1, dtype=np.int64)
        for i in range(b):
            rows = np.concatenate(
                [idx.order[idx.offsets[c]: idx.offsets[c + 1]]
                 for c in probe[i]]
            )
            cand[i, : len(rows)] = rows
        cand_j = jnp.asarray(np.maximum(cand, 0))
        packed_c = jnp.take(idx.enc.packed, cand_j, axis=0)
        deq = qz.decode(dataclasses.replace(
            idx.enc, packed=packed_c.reshape(-1, packed_c.shape[-1])
        )).reshape(b, max_cand, -1)
        raw = jnp.einsum("bd,bmd->bm", q_rot, deq)
        scores = adjust_scores(raw, jnp.take(idx.enc.qnorms, cand_j, axis=0),
                               metric)
        ok = jnp.asarray(cand >= 0)
        if allow is not None:
            ok = ok & jnp.asarray(allow.mask)[cand_j]
        scores = jnp.where(ok, scores, NEG)
        vals, pos = topk(scores, k)
        rows = np.take_along_axis(cand, np.asarray(pos), axis=1)
        return np.asarray(vals), idx.ids[np.maximum(rows, 0)]

    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    def test_matches_reference(self, metric, corpus, queries):
        idx = IvfFlatIndex.build(jnp.asarray(corpus), metric=metric, nlist=16)
        vals, ids = idx.search(jnp.asarray(queries), 10, nprobe=4)
        ref_vals, ref_ids = self._reference(idx, jnp.asarray(queries), 10, 4)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_allclose(vals, ref_vals, rtol=2e-5, atol=1e-5)

    def test_no_result_sentinel(self, corpus, queries):
        """Fewer admissible candidates than k: the tail carries the same
        0xFFFF... sentinel as HNSW, never a real row id."""
        idx = IvfFlatIndex.build(jnp.asarray(corpus), metric="cosine",
                                 nlist=8)
        allow = Allowlist.from_ids([3, 11], idx.ids)
        vals, ids = idx.search(jnp.asarray(queries), 10, nprobe=8,
                               allow=allow)
        sentinel = np.uint64(0xFFFFFFFFFFFFFFFF)
        valid = ids != sentinel
        np.testing.assert_array_equal(valid.sum(axis=1),
                                      np.full(len(queries), 2))
        assert set(ids[valid].tolist()) <= {3, 11}
        assert (np.asarray(vals)[~valid] == NEG).all()

    def test_allowlist_pre_topk(self, corpus, queries):
        """Selective allowlist: exactly k allowed rows, matching the
        reference with the mask applied before its top-k."""
        idx = IvfFlatIndex.build(jnp.asarray(corpus), metric="cosine",
                                 nlist=8)
        allow = Allowlist.from_ids(range(0, 900, 3), idx.ids)
        vals, ids = idx.search(jnp.asarray(queries), 10, nprobe=8, allow=allow)
        assert (ids.astype(np.int64) % 3 == 0).all()
        ref_vals, ref_ids = self._reference(idx, jnp.asarray(queries), 10, 8,
                                            allow=allow)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_allclose(vals, ref_vals, rtol=2e-5, atol=1e-5)


class TestScoreGatheredOps:
    """ops.score_gathered against the pure oracles, including mixed bits."""

    def test_mixed_bits_matches_oracle(self, rng):
        from repro.kernels import ref
        corpus = rng.randn(300, 768).astype(np.float32)
        enc = qz.encode_mixed(jnp.asarray(corpus), avg_bits=3.0, seed=4)
        q = qz.encode_query(
            jnp.asarray(rng.randn(5, 768).astype(np.float32)), enc)
        cand = jnp.asarray(rng.randint(0, 300, size=(5, 40)))
        out = ops.score_gathered_raw(enc.packed, q, cand, bits=3,
                                     n4_dims=enc.n4_dims, use_kernel=False)
        expected = ref.gather_mixed_dot_ref(enc.packed, q, cand, enc.n4_dims)
        err = float(jnp.max(jnp.abs(out - expected))
                    / (jnp.max(jnp.abs(expected)) + 1e-9))
        assert err < 2e-5

    def test_sentinel_and_allow_mask(self, rng):
        corpus = rng.randn(64, 128).astype(np.float32)
        enc = qz.encode(jnp.asarray(corpus), metric="dot", seed=2)
        q = qz.encode_query(
            jnp.asarray(rng.randn(2, 128).astype(np.float32)), enc)
        cand = jnp.asarray([[0, 5, -1, 7], [3, -1, -1, 9]])
        allow = jnp.zeros((64,), bool).at[jnp.asarray([0, 3, 9])].set(True)
        out = ops.score_gathered(enc.packed, q, cand, bits=4,
                                 qnorms=enc.qnorms, metric="dot",
                                 allow_mask=allow, use_kernel=False)
        got_neg = np.asarray(out) == NEG
        # -1 sentinels and disallowed rows are NEG; allowed real rows are not.
        expect_neg = np.array([[False, True, True, True],
                               [False, True, True, False]])
        np.testing.assert_array_equal(got_neg, expect_neg)

"""Cross-shard merge contract of repro.dist.retrieval (DESIGN.md §3).

In-process tests run on the (1,1) local mesh; the multi-shard cases spawn a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count so jax sees
a real multi-device mesh (device count is fixed at first jax import, so it
cannot be changed inside this process).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz
from repro.data import synthetic as syn
from repro.dist.partition import pad_rows, partition_bounds, shard_sizes
from repro.dist.retrieval import (make_scan_topk_f32_shardmap,
                                  make_scan_topk_shardmap, scan_topk_f32,
                                  scan_topk_pjit)


def local_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestPartition:
    def test_shard_sizes_and_bounds(self):
        per, n_pad = shard_sizes(1021, 4)
        assert per == 256 and n_pad == 1024
        assert partition_bounds(1021, 4, 0) == (0, 256)
        assert partition_bounds(1021, 4, 3) == (768, 1021)   # hi clamped

    def test_pad_rows_noop_and_fill(self):
        x = jnp.ones((3, 2))
        assert pad_rows(x, 3) is x
        y = pad_rows(x, 5, fill=7.0)
        assert y.shape == (5, 2) and float(y[4, 0]) == 7.0


class TestSingleShardMerge:
    """(1,1) mesh: the merge path with exactly one shard."""

    @pytest.mark.parametrize("n", [512, 509])     # divisible / non-divisible
    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    def test_quantized_matches_pjit(self, n, metric):
        corpus = syn.embedding_corpus(11, n, 128)
        enc = qz.encode(jnp.asarray(corpus), metric=metric, seed=5)
        q = qz.encode_query(jnp.asarray(corpus[:3] + 0.02), enc)
        mesh = local_mesh()
        with mesh:
            v1, i1 = scan_topk_pjit(q, enc.packed, enc.qnorms,
                                    metric=metric, k=10)
            fn = make_scan_topk_shardmap(mesh, metric=metric, k=10)
            v2, i2 = fn(q, enc.packed, enc.qnorms)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)

    def test_mixed_precision_corpus(self):
        corpus = syn.embedding_corpus(12, 300, 128)
        enc = qz.encode_mixed(jnp.asarray(corpus), metric="cosine", seed=5,
                              avg_bits=3.0)
        q = qz.encode_query(jnp.asarray(corpus[:3]), enc)
        mesh = local_mesh()
        with mesh:
            v1, i1 = scan_topk_pjit(q, enc.packed, enc.qnorms,
                                    metric="cosine", k=7, bits=enc.bits,
                                    n4_dims=enc.n4_dims)
            fn = make_scan_topk_shardmap(mesh, metric="cosine", k=7,
                                         bits=enc.bits, n4_dims=enc.n4_dims)
            v2, i2 = fn(q, enc.packed, enc.qnorms)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)

    def test_f32_matches(self, rng):
        cand = rng.randn(333, 64).astype(np.float32)
        q = rng.randn(2, 64).astype(np.float32)
        mesh = local_mesh()
        with mesh:
            v1, i1 = scan_topk_f32(jnp.asarray(q), jnp.asarray(cand), k=9)
            v2, i2 = make_scan_topk_f32_shardmap(mesh, k=9)(
                jnp.asarray(q), jnp.asarray(cand))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


_MULTI_SHARD_SCRIPT = textwrap.dedent("""
    import jax, numpy as np, jax.numpy as jnp
    assert jax.device_count() == {devices}, jax.device_count()
    from repro.core import quantize as qz
    from repro.core.api import MonaVec
    from repro.data import synthetic as syn
    from repro.dist.retrieval import (make_scan_topk_shardmap, scan_topk_pjit,
                                      make_scan_topk_f32_shardmap,
                                      scan_topk_f32)
    from repro.dist.sharded_index import ShardedMonaVec

    mesh = jax.make_mesh(({devices}, 1), ("data", "model"))
    for n in (1024, 1021):           # divisible and n % shards != 0
        corpus = syn.embedding_corpus(0, n, 128)
        enc = qz.encode(jnp.asarray(corpus), metric="cosine", seed=3)
        q = qz.encode_query(jnp.asarray(corpus[:4] + 0.05), enc)
        with mesh:
            v1, i1 = scan_topk_pjit(q, enc.packed, enc.qnorms,
                                    metric="cosine", k=10)
            fn = make_scan_topk_shardmap(mesh, metric="cosine", k=10)
            v2, i2 = fn(q, enc.packed, enc.qnorms)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
        assert int(np.asarray(i2).max()) < n    # padding never surfaces

        idx = MonaVec.build(corpus, metric="cosine")
        sv, sids = idx.search(corpus[:3], 7)
        dv, dids = ShardedMonaVec.shard(idx, mesh).search(corpus[:3], 7)
        np.testing.assert_array_equal(sids, dids)
        np.testing.assert_allclose(sv, dv, rtol=1e-6)

    rng = np.random.RandomState(0)
    cand = rng.randn(515, 64).astype(np.float32)   # 515 % 4 != 0
    user = rng.randn(3, 64).astype(np.float32)
    with mesh:
        a = scan_topk_f32(jnp.asarray(user), jnp.asarray(cand), k=5)
        b = make_scan_topk_f32_shardmap(mesh, k=5)(jnp.asarray(user),
                                                   jnp.asarray(cand))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    print("MULTI_SHARD_OK")
""")


class TestMultiShardMerge:
    def test_four_shard_mesh_identical(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4").strip()
        env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        res = subprocess.run(
            [sys.executable, "-c", _MULTI_SHARD_SCRIPT.format(devices=4)],
            env=env, capture_output=True, text=True, timeout=600)
        assert res.returncode == 0, res.stderr[-3000:]
        assert "MULTI_SHARD_OK" in res.stdout

"""Segmented mutable lifecycle (DESIGN.md §6): deterministic suite.

Pins the acceptance contract of the segment subsystem: for scripted
add/delete/compact interleavings across metric × bits × backend,
``search()`` (``use_kernel`` both ways) matches the per-segment brute-force
oracle, tombstones are masked pre-top-k, and replaying the same op sequence
serializes byte-identically.  The hypothesis suite
(`test_lifecycle_props.py`) drives the same harness over random sequences.
"""

import numpy as np
import pytest

from repro.core import Allowlist, MonaVec, SENTINEL_ID, derive_segment_seed
from tests.lifecycle_harness import (apply_ops, assert_matches_oracle,
                                     build_index, save_digest)


def _vecs(rng, n, dim=16):
    return rng.randn(n, dim).astype(np.float32)


def _scripted_ops(seed: int, dim: int = 16, n_ops: int = 6):
    """Deterministic pseudo-random interleaving of add/delete/compact."""
    rng = np.random.RandomState(seed)
    ops_list, next_id = [], 1000
    for _ in range(n_ops):
        r = rng.rand()
        if r < 0.5:
            ops_list.append(("add", _vecs(rng, int(rng.randint(1, 6)), dim)))
        elif r < 0.85:
            ops_list.append(("delete", rng.randint(0, 40, size=3).tolist()))
        else:
            ops_list.append(("compact",))
    return ops_list


class TestSeedDerivation:
    def test_ordinal_zero_is_root(self):
        assert derive_segment_seed(0x6D6F6E61, 0) == 0x6D6F6E61

    def test_distinct_and_deterministic(self):
        seeds = [derive_segment_seed(7, i) for i in range(64)]
        assert len(set(seeds)) == 64
        assert seeds == [derive_segment_seed(7, i) for i in range(64)]
        assert all(0 <= s <= 0xFFFFFFFFFFFFFFFF for s in seeds)

    def test_root_sensitivity(self):
        assert derive_segment_seed(1, 3) != derive_segment_seed(2, 3)


class TestLifecycleEquivalence:
    """search() == per-segment brute-force oracle after scripted op mixes."""

    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    def test_interleaving_matches_oracle(self, kind, metric):
        if kind == "hnsw" and metric == "dot":
            pytest.skip("HNSW build is cosine/l2 in this repo's test surface")
        rng = np.random.RandomState(3)
        idx = build_index(kind, _vecs(rng, 40), metric=metric)
        apply_ops(idx, _scripted_ops(seed=17))
        q = _vecs(rng, 5)
        assert_matches_oracle(idx, q, 10, kind, use_kernel=False)

    @pytest.mark.parametrize("bits", [4, 2])
    def test_bits_modes_bruteforce_exact(self, bits):
        rng = np.random.RandomState(5)
        idx = build_index("bruteforce", _vecs(rng, 30), bits=bits)
        apply_ops(idx, _scripted_ops(seed=23))
        assert_matches_oracle(idx, _vecs(rng, 4), 8, "bruteforce",
                              use_kernel=False)

    def test_mixed_precision_segments(self):
        rng = np.random.RandomState(6)
        idx = MonaVec.build(_vecs(rng, 30), metric="cosine", avg_bits=3.0)
        idx.add(_vecs(rng, 7))
        idx.delete([1, 33])
        assert idx.mut.extras[0].enc.n4_dims == idx.backend.enc.n4_dims
        assert_matches_oracle(idx, _vecs(rng, 4), 8, "bruteforce",
                              use_kernel=False)

    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    def test_use_kernel_both_ways(self, kind):
        """The kernel-dispatch contract survives mutation: interpret-mode
        kernel and pure-jnp agree with their own-dispatch oracle."""
        rng = np.random.RandomState(8)
        idx = build_index(kind, _vecs(rng, 24))
        idx.add(_vecs(rng, 6))
        idx.delete([2, 25])
        q = _vecs(rng, 3)
        assert_matches_oracle(idx, q, 6, kind, use_kernel=False)
        assert_matches_oracle(idx, q, 6, kind, use_kernel=True, interpret=True)


class TestReplayDeterminism:
    """Two identical op sequences → byte-identical .mvec + identical search."""

    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    def test_replay_serializes_byte_identically(self, kind, tmp_path):
        rng = np.random.RandomState(9)
        base = _vecs(rng, 30)
        ops_list = _scripted_ops(seed=31)
        q = _vecs(rng, 4)
        digests, results = [], []
        for run in range(2):
            idx = build_index(kind, base)
            apply_ops(idx, ops_list)
            digests.append(save_digest(idx, str(tmp_path), f"run{run}.mvec"))
            results.append(idx.search(q, 5, use_kernel=False,
                                      **({"nprobe": idx.backend.nlist}
                                         if kind == "ivf" else {})))
        assert digests[0] == digests[1]
        np.testing.assert_array_equal(results[0][1], results[1][1])
        np.testing.assert_array_equal(results[0][0], results[1][0])

    def test_save_load_preserves_segment_structure(self, tmp_path):
        rng = np.random.RandomState(10)
        idx = build_index("bruteforce", _vecs(rng, 20))
        idx.add(_vecs(rng, 5))
        idx.add(_vecs(rng, 3))
        idx.delete([0, 21])
        p = str(tmp_path / "s.mvec")
        idx.save(p)
        idx2 = MonaVec.load(p)
        assert len(idx2.mut.extras) == 2
        assert idx2.mut.next_ordinal == 3
        assert [s.enc.seed for s in idx2.mut.extras] == \
               [s.enc.seed for s in idx.mut.extras]
        np.testing.assert_array_equal(idx2.mut.base_tombs, idx.mut.base_tombs)
        q = _vecs(rng, 3)
        np.testing.assert_array_equal(idx.search(q, 7, use_kernel=False)[1],
                                      idx2.search(q, 7, use_kernel=False)[1])

    def test_compact_then_add_reuses_ordinals(self):
        """After compact the store is a fresh single segment: the next add
        derives ordinal 1 again — a pure function of current state."""
        rng = np.random.RandomState(12)
        idx = build_index("bruteforce", _vecs(rng, 12))
        idx.add(_vecs(rng, 3))
        idx.compact()
        assert idx.mut.next_ordinal == 1
        idx.add(_vecs(rng, 3))
        assert idx.mut.extras[0].enc.seed == \
            derive_segment_seed(idx.backend.enc.seed, 1)


class TestTombstoneSemantics:
    def test_deleted_rows_never_returned(self):
        rng = np.random.RandomState(13)
        idx = build_index("bruteforce", _vecs(rng, 20))
        dead = [0, 3, 7, 11]
        assert idx.delete(dead) == 4
        assert idx.delete(dead) == 0              # idempotent
        _, ids = idx.search(_vecs(rng, 6), 16, use_kernel=False)
        assert not np.isin(ids, dead).any()
        assert idx.n_live == 16

    def test_underflow_returns_sentinels(self):
        rng = np.random.RandomState(14)
        idx = build_index("bruteforce", _vecs(rng, 8))
        idx.delete(range(6))
        vals, ids = idx.search(_vecs(rng, 2), 5, use_kernel=False)
        assert (ids[:, 2:] == SENTINEL_ID).all()
        assert (ids[:, :2] != SENTINEL_ID).all()

    def test_static_bruteforce_underflow_matches_mutated(self):
        """The static BF path honors the same no-result contract as the
        segmented one: a selective allowlist smaller than k yields sentinels,
        never disallowed filler rows, before AND after mutation."""
        rng = np.random.RandomState(30)
        idx = build_index("bruteforce", _vecs(rng, 10))
        q = _vecs(rng, 2)
        allow = Allowlist.from_ids([1, 4], idx.ids)
        _, ids_static = idx.search(q, 5, allow=allow, use_kernel=False)
        assert set(ids_static[:, :2].ravel().tolist()) == {1, 4}
        assert (ids_static[:, 2:] == SENTINEL_ID).all()
        idx.delete([7])                      # flip to the segmented path
        allow2 = Allowlist.from_ids([1, 4], idx.ids)
        _, ids_mut = idx.search(q, 5, allow=allow2, use_kernel=False)
        np.testing.assert_array_equal(ids_static, ids_mut)

    def test_bruteforce_rejects_backend_knobs_both_states(self):
        """Misplaced IVF/HNSW knobs fail consistently whether or not the
        BruteForce index has been mutated."""
        rng = np.random.RandomState(31)
        idx = build_index("bruteforce", _vecs(rng, 10))
        q = _vecs(rng, 2)
        with pytest.raises(TypeError):
            idx.search(q, 3, ef=64, use_kernel=False)
        idx.add(_vecs(rng, 2))
        with pytest.raises(TypeError):
            idx.search(q, 3, ef=64, use_kernel=False)

    @pytest.mark.parametrize("kind", ["ivf", "hnsw"])
    def test_prefilter_allowlist_on_mutated_index(self, kind):
        """§3.5 survives mutation: exactly min(k, live∩allowed) real rows."""
        rng = np.random.RandomState(15)
        idx = build_index(kind, _vecs(rng, 30))
        idx.add(_vecs(rng, 10))
        idx.delete([4, 32])
        allowed = [2, 4, 8, 31, 32, 35]            # 4 and 32 are tombstoned
        allow = Allowlist.from_ids(allowed, idx.ids)
        skw = {"nprobe": idx.backend.nlist} if kind == "ivf" else {"ef": 64}
        _, ids = idx.search(_vecs(rng, 3), 4, allow=allow,
                            use_kernel=False, **skw)
        real = ids[ids != SENTINEL_ID]
        assert set(real.tolist()) <= {2, 8, 31, 35}
        assert (ids != SENTINEL_ID).sum(axis=1).tolist() == [4, 4, 4]


class TestCompaction:
    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    def test_compact_reclaims_and_matches_oracle(self, kind):
        rng = np.random.RandomState(16)
        idx = build_index(kind, _vecs(rng, 24))
        idx.add(_vecs(rng, 8))
        idx.delete([1, 2, 25])
        reclaimed = idx.compact()
        assert reclaimed == 3
        assert idx.n_total == idx.n_live == 29
        assert not idx.mut.extras and not idx.mut.base_tombs.any()
        assert_matches_oracle(idx, _vecs(rng, 4), 8, kind, use_kernel=False)

    def test_compact_is_deterministic(self, tmp_path):
        rng = np.random.RandomState(18)
        base, extra = _vecs(rng, 20), _vecs(rng, 6)
        digests = []
        for run in range(2):
            idx = build_index("bruteforce", base)
            idx.add(extra)
            idx.delete([3, 21])
            idx.compact()
            digests.append(save_digest(idx, str(tmp_path), f"c{run}.mvec"))
        assert digests[0] == digests[1]

    def test_compact_noop_on_static(self):
        rng = np.random.RandomState(19)
        idx = build_index("bruteforce", _vecs(rng, 10))
        assert idx.compact() == 0

    def test_compacted_single_segment_saves_as_v6(self, tmp_path):
        rng = np.random.RandomState(20)
        idx = build_index("bruteforce", _vecs(rng, 10))
        idx.add(_vecs(rng, 3))
        idx.delete([0])
        p = str(tmp_path / "v8.mvec")
        idx.save(p)
        assert open(p, "rb").read()[4] == 8
        idx.compact()
        idx.save(p)
        assert open(p, "rb").read()[4] == 6        # back to the static layout

    def test_hnsw_compact_keeps_ef_construction(self, tmp_path):
        rng = np.random.RandomState(21)
        idx = build_index("hnsw", _vecs(rng, 16), ef_construction=48)
        idx.add(_vecs(rng, 4))
        p = str(tmp_path / "h.mvec")
        idx.save(p)
        idx2 = MonaVec.load(p)
        assert idx2.backend.ef_construction == 48
        idx2.compact()
        assert idx2.backend.ef_construction == 48

    def test_hnsw_ef_construction_survives_static_save(self, tmp_path):
        """param2 rides in every version (it was a reserved-zero field), so
        a STATIC v6 save/load round-trip must not reset compact()'s rebuild
        beam width to the default."""
        rng = np.random.RandomState(27)
        idx = build_index("hnsw", _vecs(rng, 16), ef_construction=48)
        p = str(tmp_path / "static.mvec")
        idx.save(p)
        assert open(p, "rb").read()[4] == 6
        assert MonaVec.load(p).backend.ef_construction == 48

    def test_replay_across_save_load_compacts_identically(self, tmp_path):
        """In-memory replay and save/load-interrupted replay of the same op
        sequence must compact to byte-identical files (the round-trip must
        not lose any state compact() depends on)."""
        rng = np.random.RandomState(28)
        base, extra = _vecs(rng, 16), _vecs(rng, 4)

        def run(through_disk: bool) -> str:
            idx = build_index("hnsw", base, ef_construction=40)
            idx.add(extra)
            idx.delete([1, 17])
            if through_disk:
                p = str(tmp_path / "mid.mvec")
                idx.save(p)
                idx = MonaVec.load(p)
            idx.compact()
            return save_digest(idx, str(tmp_path), f"end{through_disk}.mvec")

        assert run(False) == run(True)


class TestGuards:
    def test_shard_rejects_mutated(self):
        rng = np.random.RandomState(22)
        idx = build_index("bruteforce", _vecs(rng, 10))
        idx.add(_vecs(rng, 2))
        with pytest.raises(TypeError, match="compact"):
            idx.shard()

    def test_add_dim_mismatch(self):
        rng = np.random.RandomState(24)
        idx = build_index("bruteforce", _vecs(rng, 10))
        with pytest.raises(ValueError, match="dim"):
            idx.add(rng.randn(2, 9).astype(np.float32))

    def test_add_duplicate_ids_in_batch(self):
        rng = np.random.RandomState(25)
        idx = build_index("bruteforce", _vecs(rng, 10))
        with pytest.raises(ValueError, match="duplicate"):
            idx.add(_vecs(rng, 2), ids=[50, 50])

    def test_empty_add_is_noop(self):
        rng = np.random.RandomState(26)
        idx = build_index("bruteforce", _vecs(rng, 10))
        out = idx.add(np.zeros((0, 16), np.float32))
        assert out.shape == (0,)
        assert idx.mut.is_static

"""Regenerate the golden `.mvec` fixtures + SHA-256 digests.

    PYTHONPATH=src python tests/golden/make_fixtures.py

The fixtures pin the paper's §3.8 byte-identity claim: building the same
index from the same inputs must produce the same file, byte for byte, on
any platform (jax threefry + Lloyd-Max codes are platform-deterministic).
`tests/test_mvec_golden.py` asserts (a) the checked-in bytes still hash to
`digests.json`, (b) `load → save` reproduces them exactly, and (c) a fresh
build reproduces them exactly.  Regenerate ONLY on a deliberate format
change, and say so in the commit message.
"""

from __future__ import annotations

import hashlib
import json
import os

import jax.numpy as jnp
import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))


def _data(n: int, dim: int, seed: int) -> np.ndarray:
    # Plain RandomState gaussians: stable across numpy versions by contract.
    return np.random.RandomState(seed).randn(n, dim).astype(np.float32)


def build_v6_bruteforce():
    """Minimal v6: BruteForce, cosine, pure 4-bit."""
    from repro.core import MonaVec
    return MonaVec.build(_data(32, 16, 100), metric="cosine", seed=7)


def build_v7_perm_bruteforce():
    """v7: mixed 4/2-bit with the persisted variance permutation."""
    from repro.core import BruteForceIndex, MonaVec
    from repro.core import quantize as qz
    from repro.core.rhdh import rhdh_apply
    from repro.core.standardize import prepare
    x = _data(24, 16, 101) * np.exp(-np.arange(16) / 4).astype(np.float32)
    rot = rhdh_apply(prepare(jnp.asarray(x), "cosine"), 7, normalized=False)
    perm = qz.variance_permutation(rot)
    enc = qz.encode_mixed(jnp.asarray(x), metric="cosine", seed=7,
                          avg_bits=3.0, perm=perm)
    return MonaVec(BruteForceIndex(enc=enc, ids=np.arange(24, dtype=np.uint64)))


def build_v8_segmented_ivf():
    """v8: IVF base + two add() segments + tombstones in base and extras."""
    from repro.core import MonaVec
    idx = MonaVec.build(_data(20, 16, 102), metric="l2", index="ivf",
                        seed=7, nlist=3, train_iters=5)
    idx.add(_data(6, 16, 103))
    idx.add(_data(4, 16, 104))
    idx.delete([2, 5, 21, 27])
    return idx


def build_v9_meta_bruteforce():
    """v9: metadata columns (i64 / f64 / interned str) over a mutated index —
    per-segment value blocks, vocab grown by add(), tombstones present."""
    from repro.core import MonaVec
    idx = MonaVec.build(
        _data(20, 16, 105), metric="cosine", seed=7,
        meta={"price": np.arange(20, dtype=np.int64) * 3 - 10,
              "score": np.arange(20, dtype=np.float64) / 4 - 2.0,
              "cat": np.array(["red", "green", "blue", "red"] * 5)})
    idx.add(_data(6, 16, 106),
            meta={"price": np.arange(6, dtype=np.int64) + 100,
                  "score": np.linspace(-1.0, 1.0, 6).astype(np.float64),
                  "cat": np.array(["green", "violet"] * 3)})
    idx.delete([3, 8, 22])
    return idx


def build_v10_coarse_bruteforce():
    """v10: per-segment coarse CODE blocks (crumb planes) over a mutated
    index WITH metadata — the COARSE_KIND and HAS_META header bytes are
    both set, and every segment (base + one add()) persists its code."""
    from repro.core import MonaVec
    idx = MonaVec.build(
        _data(20, 16, 107), metric="cosine", seed=7, coarse="crumb",
        meta={"price": np.arange(20, dtype=np.int64) * 2 - 5,
              "cat": np.array(["red", "green"] * 10)})
    idx.add(_data(6, 16, 108),
            meta={"price": np.arange(6, dtype=np.int64) + 50,
                  "cat": np.array(["blue", "red"] * 3)})
    idx.delete([1, 4, 21])
    return idx


def build_v11_tuned_ivf():
    """v11: TUNE block (knobs + ladder + boost curve) over an IVF index
    with metadata — autotuned with seeded sample queries against the exact
    quantized oracle, smallest-rung tie-break, so the persisted envelope is
    byte-stable (DESIGN.md §12)."""
    from repro.core import MonaVec
    idx = MonaVec.build(
        _data(24, 16, 109), metric="cosine", index="ivf", seed=7, nlist=3,
        train_iters=5,
        meta={"price": np.arange(24, dtype=np.int64) - 6,
              "cat": np.array(["red", "green", "blue"] * 8)})
    idx.autotune(recall_target=0.9, k=4, n_queries=8, seed=11)
    return idx


FIXTURES = {
    "v6_bruteforce.mvec": build_v6_bruteforce,
    "v7_perm_bruteforce.mvec": build_v7_perm_bruteforce,
    "v8_segmented_ivf.mvec": build_v8_segmented_ivf,
    "v9_meta_bruteforce.mvec": build_v9_meta_bruteforce,
    "v10_coarse_bruteforce.mvec": build_v10_coarse_bruteforce,
    "v11_tuned_ivf.mvec": build_v11_tuned_ivf,
}


def main() -> None:
    digests = {}
    for name, builder in FIXTURES.items():
        path = os.path.join(HERE, name)
        builder().save(path)
        digests[name] = hashlib.sha256(open(path, "rb").read()).hexdigest()
        print(f"{name}: {digests[name]}")
    with open(os.path.join(HERE, "digests.json"), "w") as fh:
        json.dump(digests, fh, indent=2, sort_keys=True)
        fh.write("\n")


if __name__ == "__main__":
    main()

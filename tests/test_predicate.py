"""Predicate compiler (DESIGN.md §8): the three views of one predicate —
host numpy oracle, structure fingerprint, compiled u64-key stage — must
agree exactly, and filtered search must equal the mask-to-NEG brute-force
oracle.

Layers:
  * hand-checked semantics per operator (including the i64/f64 boundary
    values the u64 key map exists for: int64 min/max, ±0.0, ±inf);
  * seeded random-AST agreement between ``evaluate`` (host, exact values)
    and ``build_stage_fn`` + ``flatten_args`` (device, key planes) — the
    deterministic twin of tests/test_predicate_props.py;
  * validation errors surface eagerly, named;
  * filtered search vs ``oracle_search(allow_mask=evaluate(p))`` across
    backend x metric x bits x {static, mutated, sharded} — exact for the
    BruteForce scan (the search IS the oracle computation), admissible-set
    for IVF/HNSW (gathered-scan tiling, same precedent as the lifecycle
    suites).
"""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Allowlist, And, Eq, Ge, Gt, In, Le, Lt, MonaVec, Ne,
                        Not, Or, SENTINEL_ID)
from repro.core import metadata as md
from repro.core import predicate as pred
from tests.lifecycle_harness import oracle_search

DIM = 16

I64_MIN, I64_MAX = np.iinfo(np.int64).min, np.iinfo(np.int64).max


def _store(n: int, seed: int) -> md.MetaStore:
    rng = np.random.RandomState(seed)
    i64 = rng.randint(-1000, 1000, n).astype(np.int64)
    i64[:4] = [I64_MIN, I64_MAX, -1, 0]
    f64 = rng.randn(n) * 10.0
    f64[:4] = [-0.0, 0.0, np.inf, -np.inf]
    strs = np.array(["red", "green", "blue", "cyan"])[rng.randint(0, 4, n)]
    return md.MetaStore.build({"i": i64, "f": f64, "s": strs}, n)


def _device_mask(p: pred.Predicate, store: md.MetaStore) -> np.ndarray:
    """Run the compiled stage exactly as the plan does: key-plane args."""
    fn = pred.build_stage_fn(p)
    args = tuple(jnp.asarray(a) for a in pred.flatten_args(p, store))
    live = jnp.ones(store.n_rows, dtype=bool)
    return np.asarray(fn(live, *args))


def _assert_agree(p: pred.Predicate, store: md.MetaStore) -> None:
    host = pred.evaluate(p, store)
    dev = _device_mask(p, store)
    np.testing.assert_array_equal(dev, host, err_msg=str(p))


class TestHostSemantics:
    """evaluate() against hand-computed numpy masks."""

    def test_comparisons_i64(self):
        store = md.MetaStore.build(
            {"x": np.array([-3, 0, 5, 5, 9], dtype=np.int64)}, 5)
        x = store["x"].values
        for P, op in [(Eq, np.equal), (Ne, np.not_equal), (Lt, np.less),
                      (Le, np.less_equal), (Gt, np.greater),
                      (Ge, np.greater_equal)]:
            np.testing.assert_array_equal(
                pred.evaluate(P("x", 5), store), op(x, 5))

    def test_in_and_boolean_algebra(self):
        store = _store(32, 3)
        i = store["i"].values
        np.testing.assert_array_equal(
            pred.evaluate(In("i", (0, -1)), store), np.isin(i, [0, -1]))
        p = And(Ge("i", 0), Not(Eq("s", "red")))
        want = (i >= 0) & ~(store["s"].decoded() == "red")
        np.testing.assert_array_equal(pred.evaluate(p, store),
                                      want.astype(bool))
        # operator sugar builds the same AST
        assert (Ge("i", 0) & ~Eq("s", "red")) == p
        assert (Lt("i", 2) | Eq("s", "blue")) == Or(Lt("i", 2),
                                                    Eq("s", "blue"))

    def test_str_out_of_vocab(self):
        store = _store(16, 4)
        assert not pred.evaluate(Eq("s", "missing"), store).any()
        assert pred.evaluate(Ne("s", "missing"), store).all()
        _assert_agree(Eq("s", "missing"), store)
        _assert_agree(Ne("s", "missing"), store)


class TestKeyLowering:
    """The u64 key map preserves order/equality at exactly the values where
    a naive x64-disabled lowering would truncate or flip."""

    def test_i64_extremes(self):
        store = _store(24, 5)
        for c in (I64_MIN, I64_MIN + 1, -1, 0, 1, I64_MAX - 1, I64_MAX):
            for P in (Eq, Ne, Lt, Le, Gt, Ge):
                _assert_agree(P("i", int(c)), store)

    def test_f64_total_order(self):
        store = _store(24, 6)
        for c in (-np.inf, -1e300, -1.5, -0.0, 0.0, 1e-300, 2.5, np.inf):
            for P in (Eq, Ne, Lt, Le, Gt, Ge):
                _assert_agree(P("f", float(c)), store)
        # -0.0 and +0.0 are ONE key: equality and ordering are total
        z = md.MetaStore.build({"f": np.array([-0.0, 0.0, 1.0])}, 3)
        np.testing.assert_array_equal(pred.evaluate(Eq("f", -0.0), z),
                                      [True, True, False])
        _assert_agree(Eq("f", -0.0), z)
        _assert_agree(Lt("f", 0.0), z)

    def test_in_on_every_kind(self):
        store = _store(24, 7)
        for p in (In("i", (I64_MIN, 0, 77)),
                  In("f", (0.0, -np.inf, 3.25)),
                  In("s", ("red", "missing", "cyan"))):
            _assert_agree(p, store)

    def test_random_asts_agree(self):
        """Seeded random predicate trees: host oracle == compiled stage.
        (The hypothesis twin shrinks counterexamples; this one always runs.)"""
        for seed in range(40):
            rng = np.random.RandomState(1000 + seed)
            store = _store(48, seed)
            p = _random_pred(rng, store)
            _assert_agree(p, store)


def _random_pred(rng, store, depth: int = 0) -> pred.Predicate:
    if depth < 3 and rng.rand() < 0.45:
        c = rng.randint(3)
        if c == 0:
            return And(_random_pred(rng, store, depth + 1),
                       _random_pred(rng, store, depth + 1))
        if c == 1:
            return Or(_random_pred(rng, store, depth + 1),
                      _random_pred(rng, store, depth + 1))
        return Not(_random_pred(rng, store, depth + 1))
    col = ("i", "f", "s")[rng.randint(3)]
    kind = store[col].kind

    def const():
        if kind == "i64":
            pool = [int(v) for v in store["i"].values[:6]] + \
                [I64_MIN, I64_MAX, -7, 0, 1 << 62]
        elif kind == "f64":
            pool = [float(v) for v in store["f"].values[:6]] + \
                [0.0, -0.0, 2.5, -np.inf, np.inf]
        else:
            pool = ["red", "green", "blue", "cyan", "missing"]
        return pool[rng.randint(len(pool))]

    if rng.rand() < 0.25:
        return In(col, tuple(const() for _ in range(rng.randint(1, 4))))
    ops = (Eq, Ne) if kind == "str" else (Eq, Ne, Lt, Le, Gt, Ge)
    return ops[rng.randint(len(ops))](col, const())


class TestValidation:
    def test_errors_are_eager_and_named(self):
        store = _store(8, 8)
        with pytest.raises(KeyError, match="nope"):
            pred.validate(Eq("nope", 1), store)
        with pytest.raises(TypeError, match="ordering.*str"):
            pred.validate(Lt("s", "red"), store)
        with pytest.raises(TypeError, match="i64.*int"):
            pred.validate(Eq("i", "red"), store)
        with pytest.raises(TypeError, match="NaN"):
            pred.validate(Eq("f", float("nan")), store)
        with pytest.raises(TypeError, match="string"):
            pred.validate(Eq("s", 3), store)
        with pytest.raises(ValueError, match="at least one"):
            In("i", ())

    def test_search_without_meta_rejected(self):
        rng = np.random.RandomState(9)
        idx = MonaVec.build(rng.randn(12, DIM).astype(np.float32),
                            metric="cosine")
        with pytest.raises(ValueError, match="metadata"):
            idx.search(rng.randn(1, DIM).astype(np.float32), 3,
                       where=Eq("x", 1))


class TestStructureSharing:
    def test_constants_are_not_structure(self):
        store = _store(8, 10)
        a = And(Eq("s", "red"), Lt("f", 1.0))
        b = And(Eq("s", "blue"), Lt("f", -99.0))
        assert pred.structure(a, store) == pred.structure(b, store)

    def test_shape_changes_are_structure(self):
        store = _store(8, 11)
        base = pred.structure(Eq("i", 1), store)
        assert pred.structure(Ne("i", 1), store) != base        # op
        assert pred.structure(Eq("f", 1.0), store) != base      # column
        assert pred.structure(In("i", (1,)), store) != base     # node type
        # In-set size is a traced shape, hence structure
        assert pred.structure(In("i", (1, 2)), store) != \
            pred.structure(In("i", (1, 2, 3)), store)
        assert pred.structure(In("i", (4, 5)), store) == \
            pred.structure(In("i", (8, 9)), store)


# ---------------------------------------------------------------------------
# Filtered search vs the mask-to-NEG oracle.
# ---------------------------------------------------------------------------

def _meta_for(n: int, rng) -> dict:
    return {"attr": rng.randint(0, 100, n).astype(np.int64),
            "tag": np.array(["x", "y", "z"])[rng.randint(0, 3, n)]}


def _build(kind: str, n: int, rng, metric="cosine", bits=4):
    kw = {"nlist": 3, "train_iters": 5} if kind == "ivf" else (
        {"m": 4, "ef_construction": 32} if kind == "hnsw" else {})
    return MonaVec.build(rng.randn(n, DIM).astype(np.float32), metric=metric,
                         index=kind, bits=bits, meta=_meta_for(n, rng), **kw)


def _mutate(idx, rng, n_add=7):
    idx.add(rng.randn(n_add, DIM).astype(np.float32),
            meta=_meta_for(n_add, rng))
    idx.delete(idx.ids[::5])


def _live_mask(idx) -> np.ndarray:
    return np.concatenate([~idx.mut.base_tombs]
                          + [~s.tombs for s in idx.mut.extras])


PRED = And(Lt("attr", 55), Ne("tag", "z"))


class TestFilteredSearchOracle:
    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    @pytest.mark.parametrize("bits", [4, 2])
    @pytest.mark.parametrize("mutated", [False, True])
    def test_bruteforce_exact(self, metric, bits, mutated):
        rng = np.random.RandomState(20)
        idx = _build("bruteforce", 40, rng, metric=metric, bits=bits)
        if mutated:
            _mutate(idx, rng)
        q = rng.randn(3, DIM).astype(np.float32)
        mask = pred.evaluate(PRED, idx.meta)
        got_s, got_i = idx.search(q, 8, use_kernel=False, where=PRED)
        want_s, want_i = oracle_search(idx, q, 8, allow_mask=mask)
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_s, want_s)    # bit-identical

    @pytest.mark.parametrize("kind", ["ivf", "hnsw"])
    @pytest.mark.parametrize("mutated", [False, True])
    def test_candidate_backends_admissible(self, kind, mutated):
        """Full-beam IVF/HNSW under a predicate: exactly min(k, n_matching)
        distinct real results, all admissible vs the masked oracle."""
        rng = np.random.RandomState(21)
        idx = _build(kind, 40, rng)
        if mutated:
            _mutate(idx, rng)
        q = rng.randn(2, DIM).astype(np.float32)
        mask = pred.evaluate(PRED, idx.meta)
        skw = {"nprobe": idx.backend.nlist} if kind == "ivf" else \
            {"ef": max(idx.n_total, 8)}
        got_s, got_i = idx.search(q, 8, use_kernel=False, where=PRED, **skw)
        want_s, want_i = oracle_search(idx, q, idx.n_total, allow_mask=mask)
        r = min(8, int((_live_mask(idx) & mask).sum()))
        tol = 1e-4
        for row in range(got_i.shape[0]):
            real = got_i[row][got_i[row] != SENTINEL_ID]
            assert real.shape[0] == r
            assert len(set(real.tolist())) == r
            kth = want_s[row][r - 1]
            admissible = set(want_i[row][want_s[row] >= kth - tol].tolist())
            assert set(real.tolist()) <= admissible
            np.testing.assert_allclose(np.sort(got_s[row][:r]),
                                       np.sort(want_s[row][:r]),
                                       rtol=2e-5, atol=tol)

    def test_sharded_matches_single_device(self):
        """Sharded filtered scan == single-device filtered engine result
        (ids exact, scores to merge tolerance) == masked oracle ids."""
        rng = np.random.RandomState(22)
        idx = _build("bruteforce", 48, rng)
        q = rng.randn(4, DIM).astype(np.float32)
        s1, i1 = idx.search(q, 6, use_kernel=False, where=PRED)
        sharded = idx.shard()
        s2, i2 = sharded.search(q, 6, where=PRED)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_allclose(s1, s2, rtol=1e-6)
        mask = pred.evaluate(PRED, idx.meta)
        _, want_i = oracle_search(idx, q, 6, allow_mask=mask)
        np.testing.assert_array_equal(i2, want_i)

    def test_allowlist_and_predicate_compose(self):
        """where= fuses with the §3.5 allowlist: results satisfy BOTH, and
        equal the oracle over the conjunction of the masks."""
        rng = np.random.RandomState(23)
        idx = _build("bruteforce", 40, rng)
        ids = np.asarray(idx.ids)
        allow = Allowlist.from_ids(ids[::2], idx.ids)
        q = rng.randn(2, DIM).astype(np.float32)
        got_s, got_i = idx.search(q, 6, use_kernel=False, where=PRED,
                                  allow=allow)
        mask = pred.evaluate(PRED, idx.meta)
        amask = np.zeros(len(ids), dtype=bool)
        amask[::2] = True
        want_s, want_i = oracle_search(idx, q, 6, allow_mask=mask & amask)
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_s, want_s)

    def test_no_matching_rows_all_sentinels(self):
        rng = np.random.RandomState(24)
        idx = _build("bruteforce", 20, rng)
        q = rng.randn(2, DIM).astype(np.float32)
        _, i = idx.search(q, 4, use_kernel=False, where=Eq("tag", "missing"))
        assert (i == SENTINEL_ID).all()

    def test_filtered_results_survive_roundtrip(self):
        """save -> load (v9) preserves columns, vocab, and the exact
        filtered results."""
        rng = np.random.RandomState(25)
        idx = _build("bruteforce", 30, rng)
        _mutate(idx, rng)
        q = rng.randn(3, DIM).astype(np.float32)
        s1, i1 = idx.search(q, 5, use_kernel=False, where=PRED)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "m.mvec")
            idx.save(p)
            assert open(p, "rb").read()[4] == 9
            idx2 = MonaVec.load(p)
        assert idx2.meta.schema == idx.meta.schema
        np.testing.assert_array_equal(idx2.meta["attr"].values,
                                      idx.meta["attr"].values)
        assert idx2.meta["tag"].vocab == idx.meta["tag"].vocab
        s2, i2 = idx2.search(q, 5, use_kernel=False, where=PRED)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(s1, s2)

    def test_compact_carries_columns(self):
        """compact() gathers the live rows' metadata: same filtered results
        before and after (modulo the rows that were tombstoned)."""
        rng = np.random.RandomState(26)
        idx = _build("bruteforce", 30, rng)
        _mutate(idx, rng)
        q = rng.randn(2, DIM).astype(np.float32)
        s1, i1 = idx.search(q, 5, use_kernel=False, where=PRED)
        idx.compact()
        assert idx.meta.n_rows == idx.n_total == idx.n_live
        s2, i2 = idx.search(q, 5, use_kernel=False, where=PRED)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(s1, s2)

    def test_add_schema_enforced(self):
        rng = np.random.RandomState(27)
        idx = _build("bruteforce", 16, rng)
        v = rng.randn(3, DIM).astype(np.float32)
        with pytest.raises(ValueError, match="meta"):
            idx.add(v)                              # schema requires meta
        with pytest.raises(ValueError, match="do not match"):
            idx.add(v, meta={"attr": np.zeros(3, np.int64)})   # missing col
        plain = MonaVec.build(v, metric="cosine")
        with pytest.raises(ValueError, match="without metadata"):
            plain.add(v, meta={"attr": np.zeros(3, np.int64)})

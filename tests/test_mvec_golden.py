"""Golden-file determinism for the `.mvec` format (paper §3.8, DESIGN.md §6).

Three layers of byte-identity, each pinned against checked-in fixtures:
  1. fixture integrity — the committed bytes hash to `golden/digests.json`;
  2. `load → save` is the identity on every supported version (6/7/8);
  3. a fresh build from the same inputs reproduces the committed bytes —
     the paper's "same inputs, same file, any platform" claim, which until
     now had zero golden coverage.

Plus the truncation/garbage bugfix: every prefix of a valid file and every
garbage-tailed file must raise ValueError naming the short block —
previously `np.frombuffer` either crashed with an opaque message or
silently misparsed short reads.
"""

import hashlib
import json
import os
import struct

import numpy as np
import pytest

from repro.core import MonaVec
from repro.core import mvec_format as fmt
from tests.golden import make_fixtures as gold

GOLD = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")

with open(os.path.join(GOLD, "digests.json")) as fh:
    DIGESTS = json.load(fh)


def _sha(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()


class TestGoldenFixtures:
    @pytest.mark.parametrize("name", sorted(DIGESTS))
    def test_fixture_integrity(self, name):
        raw = open(os.path.join(GOLD, name), "rb").read()
        assert _sha(raw) == DIGESTS[name], f"checked-in fixture {name} changed"

    @pytest.mark.parametrize("name", sorted(DIGESTS))
    def test_load_save_is_identity(self, name, tmp_path):
        """save(load(f)) == f byte-for-byte, version preserved."""
        src = os.path.join(GOLD, name)
        out = str(tmp_path / "resaved.mvec")
        MonaVec.load(src).save(out)
        raw_in = open(src, "rb").read()
        raw_out = open(out, "rb").read()
        assert raw_in == raw_out
        assert raw_out[4] == raw_in[4]          # VERSION byte round-trips

    @pytest.mark.parametrize("name", sorted(gold.FIXTURES))
    def test_rebuild_reproduces_digest(self, name, tmp_path):
        """§3.8: the same inputs build the same file, byte for byte."""
        out = str(tmp_path / "rebuilt.mvec")
        gold.FIXTURES[name]().save(out)
        assert _sha(open(out, "rb").read()) == DIGESTS[name]

    def test_versions_as_committed(self):
        assert open(os.path.join(GOLD, "v6_bruteforce.mvec"), "rb").read()[4] == 6
        assert open(os.path.join(GOLD, "v7_perm_bruteforce.mvec"), "rb").read()[4] == 7
        assert open(os.path.join(GOLD, "v8_segmented_ivf.mvec"), "rb").read()[4] == 8
        assert open(os.path.join(GOLD, "v9_meta_bruteforce.mvec"), "rb").read()[4] == 9
        assert open(os.path.join(GOLD, "v10_coarse_bruteforce.mvec"), "rb").read()[4] == 10
        assert open(os.path.join(GOLD, "v11_tuned_ivf.mvec"), "rb").read()[4] == 11

    def test_v9_meta_survives_roundtrip(self, tmp_path):
        """The v9 fixture's columns load with exact values and survive a
        search: the metadata block is data, not decoration."""
        idx = MonaVec.load(os.path.join(GOLD, "v9_meta_bruteforce.mvec"))
        assert idx.meta is not None
        assert idx.meta.schema == (("price", "i64"), ("score", "f64"),
                                   ("cat", "str"))
        assert idx.meta.n_rows == idx.n_total == 26
        np.testing.assert_array_equal(
            idx.meta["price"].values[:3], np.array([-10, -7, -4]))
        assert idx.meta["cat"].vocab == ["red", "green", "blue", "violet"]


    def test_v10_coarse_survives_roundtrip(self):
        """The v10 fixture's CODE blocks load on every segment, and the
        persisted bytes equal a fresh derivation from the packed codes —
        the 'v10 is a cache' clause of DESIGN.md §11."""
        from repro.core import binary
        idx = MonaVec.load(os.path.join(GOLD, "v10_coarse_bruteforce.mvec"))
        enc = idx.backend.enc
        assert enc.coarse == "crumb" and enc.ccodes is not None
        assert all(s.enc.ccodes is not None for s in idx.mut.extras)
        for e in [enc] + [s.enc for s in idx.mut.extras]:
            rederived = binary.derive_codes(
                e.packed, bits=e.bits, n4_dims=e.n4_dims,
                dim_pad=e.dim_pad, kind="crumb")
            np.testing.assert_array_equal(np.asarray(e.ccodes), rederived)
        # The loaded codes are live: a cascade search runs and returns k ids.
        q = np.random.RandomState(5).randn(3, 16).astype(np.float32)
        scores, ids = idx.search(q, k=4, rescore_mult=2)
        assert ids.shape == (3, 4)

    def test_v11_tune_survives_roundtrip(self):
        """The v11 fixture's TUNE block loads as a full TuneResult — chosen
        knobs, the swept ladder with its measured recalls, and the boost
        curve — and the tuned knob is what resolved_knobs() serves by
        default (DESIGN.md §12)."""
        idx = MonaVec.load(os.path.join(GOLD, "v11_tuned_ivf.mvec"))
        t = idx.tuned
        assert t is not None and t.met_target
        assert (t.recall_target, t.k, t.n_queries, t.seed) == (0.9, 4, 8, 11)
        assert t.knobs == {"nprobe": 3}
        assert [r.value for r in t.ladder["nprobe"]] == [1, 2, 3]
        recalls = [r.recall for r in t.ladder["nprobe"]]
        assert recalls == sorted(recalls) and recalls[-1] == 1.0
        assert t.boost is not None and len(t.boost.points) >= 1
        assert idx.resolved_knobs(4) == {"nprobe": 3}

    def test_unknown_version_names_highest_supported(self, tmp_path):
        """Bugfix regression: the unknown-version error must tell the user
        the highest version this build reads, not just echo the bad byte."""
        raw = bytearray(open(os.path.join(GOLD, "v6_bruteforce.mvec"), "rb").read())
        raw[4] = 99
        p = str(tmp_path / "future.mvec")
        with open(p, "wb") as fh:
            fh.write(bytes(raw))
        with pytest.raises(ValueError, match=r"version 99.*highest supported "
                                             r"version is 11"):
            fmt.load(p)


class TestSaveLoadFixedPoint:
    """build → save → load → save is a fixed point for fresh indexes of
    every backend, mutated and not."""

    @pytest.mark.parametrize("index,kw", [
        ("bruteforce", {}),
        ("ivf", {"nlist": 4, "train_iters": 5}),
        ("hnsw", {"m": 4, "ef_construction": 24}),
    ])
    @pytest.mark.parametrize("mutate", [False, True])
    def test_fixed_point(self, index, kw, mutate, tmp_path):
        rng = np.random.RandomState(11)
        idx = MonaVec.build(rng.randn(18, 8).astype(np.float32),
                            metric="cosine", index=index, **kw)
        if mutate:
            idx.add(rng.randn(5, 8).astype(np.float32))
            idx.delete([0, 19])
        p1, p2 = str(tmp_path / "a.mvec"), str(tmp_path / "b.mvec")
        idx.save(p1)
        MonaVec.load(p1).save(p2)
        raw1 = open(p1, "rb").read()
        assert raw1 == open(p2, "rb").read()
        assert raw1[4] == (8 if mutate else 6)


class TestTruncationFuzz:
    """`mvec_format.load` on damaged files: explicit ValueError naming the
    short block at EVERY truncation offset, never an np.frombuffer misparse."""

    @pytest.mark.parametrize("name", ["v6_bruteforce.mvec",
                                      "v8_segmented_ivf.mvec",
                                      "v9_meta_bruteforce.mvec",
                                      "v10_coarse_bruteforce.mvec",
                                      "v11_tuned_ivf.mvec"])
    def test_every_truncation_offset_raises(self, name, tmp_path):
        raw = open(os.path.join(GOLD, name), "rb").read()
        p = str(tmp_path / "cut.mvec")
        for cut in range(len(raw)):
            with open(p, "wb") as fh:
                fh.write(raw[:cut])
            with pytest.raises(ValueError):
                fmt.load(p)

    def test_truncation_error_names_the_block(self, tmp_path):
        raw = open(os.path.join(GOLD, "v6_bruteforce.mvec"), "rb").read()
        p = str(tmp_path / "cut.mvec")
        with open(p, "wb") as fh:          # cut inside the VECTORS payload
            fh.write(raw[:fmt.HEADER_LEN + 8 + 10])
        with pytest.raises(ValueError, match="truncated.*vectors"):
            fmt.load(p)
        with open(p, "wb") as fh:          # header alone is also short
            fh.write(raw[:20])
        with pytest.raises(ValueError, match="header"):
            fmt.load(p)

    def test_garbage_tail_rejected(self, tmp_path):
        raw = open(os.path.join(GOLD, "v8_segmented_ivf.mvec"), "rb").read()
        p = str(tmp_path / "tail.mvec")
        with open(p, "wb") as fh:
            fh.write(raw + b"\xde\xad\xbe\xef")
        with pytest.raises(ValueError, match="garbage tail"):
            fmt.load(p)

    def test_garbage_inside_index_blob_rejected(self, tmp_path):
        """Junk hidden INSIDE the INDEX_DATA region (blob length prefix
        inflated to cover it) passes the file-level EOF check — the backend
        blob readers must reject it themselves."""
        rng = np.random.RandomState(33)
        idx = MonaVec.build(rng.randn(16, 8).astype(np.float32),
                            metric="cosine", index="ivf", nlist=2,
                            train_iters=3)
        p = str(tmp_path / "ivf.mvec")
        idx.save(p)
        raw = open(p, "rb").read()
        blob_len = len(fmt.load(p).index_data)
        pos = len(raw) - blob_len - 8              # blob is the final section
        assert struct.unpack("<Q", raw[pos:pos + 8])[0] == blob_len
        junk = b"\xde\xad\xbe\xef"
        doctored = (raw[:pos] + struct.pack("<Q", blob_len + len(junk))
                    + raw[pos + 8:] + junk)
        with open(p, "wb") as fh:
            fh.write(doctored)
        fmt.load(p)                                 # file-level parse passes
        with pytest.raises(ValueError, match="garbage tail"):
            MonaVec.load(p)                         # blob reader rejects

    def test_oversized_length_prefix_rejected(self, tmp_path):
        """A corrupt block length that claims more bytes than the file has
        must error, not frombuffer whatever is left."""
        raw = bytearray(open(os.path.join(GOLD, "v6_bruteforce.mvec"), "rb").read())
        raw[fmt.HEADER_LEN:fmt.HEADER_LEN + 8] = struct.pack("<Q", 1 << 40)
        p = str(tmp_path / "huge.mvec")
        with open(p, "wb") as fh:
            fh.write(bytes(raw))
        with pytest.raises(ValueError, match="truncated"):
            fmt.load(p)

"""Hypothesis property suite for exact selectivity estimation (DESIGN.md §12).

``repro.tune.selectivity.estimate_matches`` claims an EXACT popcount of the
compiled predicate mask — the same ``build_stage_fn`` lowering the engine
fuses into plans, reduced to an int32 count.  The property: for ANY random
predicate AST over random typed columns, any live mask, and any mutation of
the backing store, the device count equals the host numpy oracle
``np.count_nonzero(predicate.evaluate(p, store) & live)`` bit for bit.

The count cache is keyed by column version tokens, so the suite also pins
the staleness contract: mutating the store (append/gather) must never serve
a stale count.

AST generation mirrors tests/test_predicate_props.py (abstract tokens,
deterministic materialization) so shrinking stays cheap.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import metadata as md  # noqa: E402
from repro.core import predicate as pred  # noqa: E402
from repro.tune.selectivity import clear_caches, estimate_matches  # noqa: E402

I64_POOL = [np.iinfo(np.int64).min, np.iinfo(np.int64).max, -1, 0, 1,
            -7, 42, 1 << 62]
F64_POOL = [0.0, -0.0, 1.5, -2.25, 1e300, -1e300, 1e-300, float("inf"),
            float("-inf")]
STR_POOL = ["red", "green", "blue", "cyan", "missing", ""]

_cmp = st.tuples(st.sampled_from(["eq", "ne", "lt", "le", "gt", "ge"]),
                 st.sampled_from(["i", "f", "s"]),
                 st.integers(0, 8))
_in = st.tuples(st.just("in"), st.sampled_from(["i", "f", "s"]),
                st.lists(st.integers(0, 8), min_size=1, max_size=3))
leaf_tokens = st.one_of(_cmp, _in)
ast_tokens = st.recursive(
    leaf_tokens,
    lambda inner: st.one_of(
        st.tuples(st.just("and"), inner, inner),
        st.tuples(st.just("or"), inner, inner),
        st.tuples(st.just("not"), inner)),
    max_leaves=6)

_OPS = {"eq": pred.Eq, "ne": pred.Ne, "lt": pred.Lt, "le": pred.Le,
        "gt": pred.Gt, "ge": pred.Ge}


def _const(col: str, idx: int, store: md.MetaStore):
    if col == "i":
        pool = I64_POOL + [int(v) for v in store["i"].values[:4]]
        return int(pool[idx % len(pool)])
    if col == "f":
        pool = F64_POOL + [float(v) for v in store["f"].values[:4]]
        return float(pool[idx % len(pool)])
    return STR_POOL[idx % len(STR_POOL)]


def _materialize(tok, store: md.MetaStore) -> pred.Predicate:
    if tok[0] == "and":
        return pred.And(_materialize(tok[1], store),
                        _materialize(tok[2], store))
    if tok[0] == "or":
        return pred.Or(_materialize(tok[1], store),
                       _materialize(tok[2], store))
    if tok[0] == "not":
        return pred.Not(_materialize(tok[1], store))
    if tok[0] == "in":
        _, col, idxs = tok
        return pred.In(col, tuple(_const(col, i, store) for i in idxs))
    op, col, idx = tok
    if col == "s" and op in ("lt", "le", "gt", "ge"):
        op = "eq"                     # ordering on str is rejected by design
    return _OPS[op](col, _const(col, idx, store))


def _store(seed: int, n: int = 32) -> md.MetaStore:
    rng = np.random.RandomState(seed)
    i64 = rng.randint(-50, 50, n).astype(np.int64)
    i64[: min(4, n)] = I64_POOL[: min(4, n)]
    f64 = rng.randn(n) * 5.0
    f64[: min(4, n)] = F64_POOL[: min(4, n)]
    strs = np.array(STR_POOL[:4])[rng.randint(0, 4, n)]
    return md.MetaStore.build({"i": i64, "f": f64, "s": strs}, n)


def _oracle(p: pred.Predicate, store: md.MetaStore, live=None) -> int:
    m = pred.evaluate(p, store)
    if live is not None:
        m = m & live
    return int(np.count_nonzero(m))


COMMON = dict(deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])


class TestPopcountOracleAgreement:
    @settings(max_examples=60, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**16))
    def test_count_equals_host_oracle(self, tok, seed):
        store = _store(seed)
        p = _materialize(tok, store)
        assert estimate_matches(p, store) == _oracle(p, store), str(tok)

    @settings(max_examples=30, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**16),
           live_seed=st.integers(0, 2**16))
    def test_count_respects_live_mask(self, tok, seed, live_seed):
        store = _store(seed)
        p = _materialize(tok, store)
        live = np.random.RandomState(live_seed).rand(store.n_rows) < 0.5
        got = estimate_matches(p, store, live)
        assert got == _oracle(p, store, live), str(tok)

    @settings(max_examples=20, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**14))
    def test_mutated_store_never_serves_stale_counts(self, tok, seed):
        """append() and gather() mint new Column version tokens, so a count
        cached against the old store must not be returned for the new one
        (and vice versa) — both must equal their own oracle."""
        s1 = _store(seed, n=24)
        p1 = _materialize(tok, s1)
        before = estimate_matches(p1, s1)
        assert before == _oracle(p1, s1)
        extra = _store(seed + 1, n=8)
        s1.append({"i": extra["i"].values, "f": extra["f"].values,
                   "s": extra["s"].decoded().astype(str)}, 8)
        p2 = _materialize(tok, s1)
        assert estimate_matches(p2, s1) == _oracle(p2, s1), str(tok)
        keep = np.arange(s1.n_rows) % 3 != 0
        s2 = s1.gather(keep)
        p3 = _materialize(tok, s2)
        assert estimate_matches(p3, s2) == _oracle(p3, s2), str(tok)

    @settings(max_examples=20, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**16))
    def test_cache_hit_equals_miss(self, tok, seed):
        """The LRU must be a pure memo: a cold call (caches cleared) and a
        warm repeat return the same exact count."""
        store = _store(seed)
        p = _materialize(tok, store)
        clear_caches()
        cold = estimate_matches(p, store)
        warm = estimate_matches(p, store)
        assert cold == warm == _oracle(p, store), str(tok)

"""End-to-end behaviour of the paper's system (replaces the scaffold stub)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import MonaVec, TenantRegistry
from repro.core.scoring import score_f32, topk
from repro.data import synthetic as syn


class TestPaperPipelineEndToEnd:
    """AG News surrogate: clustered 1024-dim embeddings, the paper's primary
    setting (§4.2) at reduced scale."""

    @pytest.fixture(scope="class")
    def setup(self):
        # 400 clusters / 4000 docs ~ BGE-M3-like neighbour separation (the
        # paper's corpora are real semantic embeddings, not iid noise).
        corpus = syn.embedding_corpus(7, 4000, 1024, n_clusters=400, noise=0.1)
        queries = syn.queries_from_corpus(corpus, 8, 30, noise=0.05)
        gt = np.asarray(topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                                       "cosine"), 10)[1])
        return corpus, queries, gt

    def test_bruteforce_beats_090_recall(self, setup):
        corpus, queries, gt = setup
        idx = MonaVec.build(corpus, metric="cosine")
        _, ids = idx.search(queries, 10)
        rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                       for a, b in zip(ids.astype(np.int64), gt)])
        assert rec > 0.9, rec       # paper: 0.960 on AG News

    def test_memory_footprint_8x(self, setup):
        corpus, _, _ = setup
        idx = MonaVec.build(corpus, metric="cosine")
        packed_bytes = idx.backend.enc.packed.size
        assert packed_bytes == corpus.nbytes // 8    # 4-bit vs f32

    def test_full_stack_tenancy_rag(self, setup):
        corpus, queries, _ = setup
        reg = TenantRegistry()
        reg.put("team-a", "kb", MonaVec.build(corpus[:1000], metric="cosine"))
        reg.put("team-b", "kb", MonaVec.build(corpus[1000:2000], metric="cosine"))
        idx_a = reg.get("team-a", "kb")
        idx_b = reg.get("team-b", "kb")
        _, ids_a = idx_a.search(queries[:2], 5)
        _, ids_b = idx_b.search(queries[:2], 5)
        assert not np.array_equal(ids_a, ids_b)      # namespaces isolated

    def test_quantized_vs_exact_agreement_by_margin(self, setup):
        """Score error is bounded by quantization noise: where the true margin
        is large, 4-bit agrees with exact top-1."""
        corpus, queries, _ = setup
        idx = MonaVec.build(corpus, metric="cosine")
        s, ids = idx.search(queries, 2)
        gt_scores = score_f32(jnp.asarray(queries), jnp.asarray(corpus), "cosine")
        gv, gi = topk(gt_scores, 2)
        margin = np.asarray(gv[:, 0] - gv[:, 1])
        big_margin = margin > 0.05
        agree = ids[:, 0].astype(np.int64) == np.asarray(gi[:, 0])
        assert agree[big_margin].all()


class TestDryRunCellConstruction:
    """Every assigned (arch x shape) cell must BUILD (struct-level) on a mesh
    with the production axis names; full compiles run via launch.dryrun."""

    def test_all_cells_build(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.dist.steps import build_cell
        built = 0
        for arch, shape in C.cells():
            if arch.family == "retrieval":
                continue
            cell = build_cell(arch, shape, mesh)
            assert cell.model_flops > 0
            assert cell.args
            built += 1
        assert built == 36          # 40 assigned minus 4 documented skips

    def test_skips_documented(self):
        skipped = [(a.arch_id, s.name) for a, s in C.cells(include_skipped=True)
                   if s.name in a.skips]
        assert len(skipped) == 4
        assert all(s == "long_500k" for _, s in skipped)
        # gemma2 (local+global hybrid) must NOT be skipped
        assert ("gemma2-2b", "long_500k") not in skipped


class TestDeterminismSystemLevel:
    def test_same_build_same_bytes(self):
        corpus = syn.embedding_corpus(3, 500, 256)
        a = MonaVec.build(corpus, metric="cosine", seed=99)
        b = MonaVec.build(corpus, metric="cosine", seed=99)
        np.testing.assert_array_equal(np.asarray(a.backend.enc.packed),
                                      np.asarray(b.backend.enc.packed))

    def test_seed_changes_rotation_not_recall(self):
        corpus = syn.embedding_corpus(3, 1500, 256)
        queries = syn.queries_from_corpus(corpus, 4, 20)
        gt = np.asarray(topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                                       "cosine"), 10)[1])
        recalls = []
        for seed in (1, 2, 3):
            idx = MonaVec.build(corpus, metric="cosine", seed=seed)
            _, ids = idx.search(queries, 10)
            recalls.append(np.mean([len(set(x.tolist()) & set(y.tolist())) / 10
                                    for x, y in zip(ids.astype(np.int64), gt)]))
        assert np.std(recalls) < 0.05    # data-oblivious: any seed works

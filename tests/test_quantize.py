"""Unit + property tests for the MonaVec quantization core."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import lloydmax, quantize as qz, scoring
from repro.core.rhdh import (fwht, hadamard_matrix, next_pow2, rhdh_apply,
                             rhdh_inverse)
from repro.core.standardize import GlobalStd, PerDimWhiten, prepare


class TestLloydMax:
    def test_frozen_tables_match_generator(self):
        """The compiled-in constants are the Lloyd-Max fixed point (paper:
        2000 iters, tol 1e-12; we regenerate at tol 1e-13)."""
        for bits in (2, 4):
            c, b = lloydmax.generate_tables(bits)
            np.testing.assert_allclose(lloydmax.centroids(bits), c, atol=1e-7)
            np.testing.assert_allclose(lloydmax.boundaries(bits), b, atol=1e-7)

    def test_boundaries_are_midpoints(self):
        for bits in (2, 4):
            c = lloydmax.centroids(bits)
            np.testing.assert_allclose(lloydmax.boundaries(bits),
                                       (c[:-1] + c[1:]) / 2, atol=1e-6)

    def test_lloydmax_beats_uniform_mse(self):
        """Optimality on N(0,1): the reason for the +3.6% recall (Table 7)."""
        g = np.random.RandomState(0).randn(200_000).astype(np.float32)
        for bits in (2, 4):
            lm = lloydmax.dequantize(lloydmax.quantize(jnp.asarray(g), bits), bits)
            un = lloydmax.dequantize(
                lloydmax.quantize(jnp.asarray(g), bits, table="uniform"),
                bits, table="uniform")
            mse_lm = float(jnp.mean((lm - g) ** 2))
            mse_un = float(jnp.mean((un - g) ** 2))
            assert mse_lm < mse_un
            # and matches the closed-form expected distortion
            assert abs(mse_lm - lloydmax.expected_distortion(bits)) < 5e-3

    @given(st.lists(st.floats(-6, 6), min_size=1, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_quantize_nearest_centroid(self, vals):
        """Property: chosen centroid is (tie-tolerantly) the nearest one."""
        x = np.asarray(vals, np.float32)
        codes = np.asarray(lloydmax.quantize(jnp.asarray(x), 4))
        c = lloydmax.centroids(4)
        chosen = np.abs(x - c[codes])
        best = np.min(np.abs(x[:, None] - c[None, :]), axis=1)
        # Exactly on a boundary both neighbours are optimal; allow f32 eps.
        np.testing.assert_allclose(chosen, best, atol=1e-5)


class TestRHDH:
    @pytest.mark.parametrize("d", [8, 64, 256, 1024])
    def test_fwht_matches_matrix(self, d, rng):
        x = rng.randn(4, d).astype(np.float32)
        np.testing.assert_allclose(np.asarray(fwht(jnp.asarray(x))),
                                   x @ hadamard_matrix(d).T, rtol=2e-4, atol=1e-3)

    def test_orthogonality_preserves_geometry(self, rng):
        x = rng.randn(64, 300).astype(np.float32)
        r = np.asarray(rhdh_apply(jnp.asarray(x), seed=7))
        np.testing.assert_allclose(np.linalg.norm(r, axis=1),
                                   np.linalg.norm(x, axis=1), rtol=1e-4)
        np.testing.assert_allclose(r @ r.T, x @ x.T, atol=5e-3 * 300)

    def test_inverse_roundtrip(self, rng):
        x = rng.randn(10, 200).astype(np.float32)
        y = rhdh_apply(jnp.asarray(x), seed=3)
        back = np.asarray(rhdh_inverse(y, seed=3, d_orig=200))
        np.testing.assert_allclose(back, x, atol=1e-4)

    def test_gaussianization_of_unit_vectors(self, rng):
        """Unit vectors -> quantizer-space coords ~ N(0,1) (paper §3.1.2)."""
        x = rng.randn(500, 768).astype(np.float32)
        x /= np.linalg.norm(x, axis=1, keepdims=True)
        z = np.asarray(rhdh_apply(jnp.asarray(x), seed=1, normalized=False))
        assert abs(z.mean()) < 0.01
        assert abs(z.std() - 1.0) < 0.02

    def test_seed_determinism_and_sensitivity(self, rng):
        x = jnp.asarray(rng.randn(4, 128).astype(np.float32))
        a = np.asarray(rhdh_apply(x, seed=42))
        b = np.asarray(rhdh_apply(x, seed=42))
        c = np.asarray(rhdh_apply(x, seed=43))
        np.testing.assert_array_equal(a, b)
        assert np.abs(a - c).max() > 1e-3

    @given(st.integers(1, 3000))
    @settings(max_examples=30, deadline=None)
    def test_next_pow2(self, d):
        p = next_pow2(d)
        assert p >= d and p & (p - 1) == 0 and (p == 1 or p // 2 < d)


class TestPacking:
    @given(st.integers(2, 128), st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_pack4_roundtrip(self, half_d, seed):
        g = np.random.RandomState(seed % 2**31)
        codes = g.randint(0, 16, size=(3, half_d * 2)).astype(np.uint8)
        packed = qz.pack_4bit(jnp.asarray(codes))
        assert packed.shape[-1] == half_d
        np.testing.assert_array_equal(np.asarray(qz.unpack_4bit(packed)), codes)

    @given(st.integers(1, 64), st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_pack2_roundtrip(self, quarter_d, seed):
        g = np.random.RandomState(seed % 2**31)
        codes = g.randint(0, 4, size=(2, quarter_d * 4)).astype(np.uint8)
        packed = qz.pack_2bit(jnp.asarray(codes))
        np.testing.assert_array_equal(np.asarray(qz.unpack_2bit(packed)), codes)

    def test_compression_ratio(self, rng):
        """d=1024 -> 512 B payload/vector: the paper's 8x over float32."""
        x = jnp.asarray(rng.randn(16, 1024).astype(np.float32))
        enc = qz.encode(x, metric="cosine")
        assert enc.bytes_per_vector() == 512
        encm = qz.encode_mixed(x, metric="cosine", avg_bits=3.0)
        assert encm.bytes_per_vector() == 384       # 10.67x (Fig 3)


class TestStandardize:
    def test_global_std_preserves_l2_ordering(self, rng):
        """Paper contribution #2: uniform scaling preserves ranking EXACTLY."""
        corpus = (rng.rand(500, 64) * 100 + 5).astype(np.float32)
        q = (rng.rand(8, 64) * 100 + 5).astype(np.float32)
        std = GlobalStd.fit(corpus)
        d_raw = -scoring.score_f32(jnp.asarray(q), jnp.asarray(corpus), "l2")
        d_std = -scoring.score_f32(std.transform(jnp.asarray(q)),
                                   std.transform(jnp.asarray(corpus)), "l2")
        # The scale relation ||a-b||_std^2 = ||a-b||^2 * inv_std^2 (exact in
        # real arithmetic; rtol covers f32 rounding, which is also the only
        # thing that can perturb the ordering — at near-ties).
        np.testing.assert_allclose(np.asarray(d_std),
                                   np.asarray(d_raw) * std.inv_std ** 2, rtol=1e-3)
        _, t_raw = scoring.topk(-d_raw, 10)
        _, t_std = scoring.topk(-d_std, 10)
        np.testing.assert_array_equal(np.asarray(t_raw), np.asarray(t_std))

    def test_perdim_whitening_breaks_ordering(self, rng):
        """The ablation the paper runs: Mahalanobis != Euclidean ranking."""
        corpus = rng.rand(300, 32).astype(np.float32) * np.linspace(1, 50, 32)
        q = rng.rand(4, 32).astype(np.float32) * np.linspace(1, 50, 32)
        w = PerDimWhiten.fit(corpus)
        d_raw = np.asarray(-scoring.score_f32(jnp.asarray(q), jnp.asarray(corpus), "l2"))
        d_w = np.asarray(-scoring.score_f32(w.transform(jnp.asarray(q)),
                                            w.transform(jnp.asarray(corpus)), "l2"))
        assert (np.argsort(d_raw, axis=1)[:, 0] != np.argsort(d_w, axis=1)[:, 0]).any()

    def test_prepare_metric_dispatch(self, rng):
        x = jnp.asarray(rng.randn(8, 33).astype(np.float32) * 10)
        cos = prepare(x, "cosine")
        np.testing.assert_allclose(np.linalg.norm(np.asarray(cos), axis=1), 1.0,
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(prepare(x, "dot")), np.asarray(x))


class TestEncodeScore:
    def test_determinism_bitwise(self, rng):
        """Same inputs -> same packed bytes (the paper's portable determinism)."""
        x = jnp.asarray(rng.randn(64, 200).astype(np.float32))
        a = qz.encode(x, metric="cosine", seed=5)
        b = qz.encode(x, metric="cosine", seed=5)
        np.testing.assert_array_equal(np.asarray(a.packed), np.asarray(b.packed))
        np.testing.assert_array_equal(np.asarray(a.qnorms), np.asarray(b.qnorms))

    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    def test_scores_approximate_exact(self, metric, rng):
        """Queries near corpus points: 4-bit must recover the true NN.
        (iid-Gaussian random queries are near-tie order statistics — any
        quantizer fails there; the paper's corpora are clustered.)"""
        corpus = rng.randn(400, 256).astype(np.float32)
        q = corpus[:8] + 0.05 * rng.randn(8, 256).astype(np.float32)
        std = GlobalStd.fit(corpus) if metric == "l2" else None
        enc = qz.encode(jnp.asarray(corpus), metric=metric, seed=2, std=std)
        qr = qz.encode_query(jnp.asarray(q), enc)
        s = scoring.score_packed_ref(qr, enc)
        gt = scoring.score_f32(jnp.asarray(q), jnp.asarray(corpus), metric)
        _, i1 = scoring.topk(s, 1)
        _, i2 = scoring.topk(gt, 1)
        agree = (np.asarray(i1)[:, 0] == np.asarray(i2)[:, 0]).mean()
        assert agree >= 0.85, f"{metric}: top-1 agreement {agree}"

    def test_gaussian_recall_matches_paper_band(self, rng):
        """Table 7 reproduction: 4-bit Lloyd-Max recall@10 ~0.88 on Gaussian."""
        corpus = rng.randn(2000, 768).astype(np.float32)
        q = rng.randn(50, 768).astype(np.float32)
        enc = qz.encode(jnp.asarray(corpus), metric="cosine", seed=1)
        qr = qz.encode_query(jnp.asarray(q), enc)
        _, pred = scoring.topk(scoring.score_packed_ref(qr, enc), 10)
        _, gt = scoring.topk(scoring.score_f32(jnp.asarray(q), jnp.asarray(corpus), "cosine"), 10)
        rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                       for a, b in zip(np.asarray(pred), np.asarray(gt))])
        assert rec > 0.82, rec

    def test_mixed_precision_layout(self, rng):
        x = jnp.asarray(rng.randn(32, 512).astype(np.float32))
        enc = qz.encode_mixed(x, avg_bits=3.0, seed=9)
        assert enc.n4_dims == qz.allocate_bits(512, 3.0) == 256
        deq = qz.decode_mixed(enc)
        assert deq.shape == (32, 512)

    @given(st.floats(2.0, 4.0))
    @settings(max_examples=20, deadline=None)
    def test_allocate_bits_budget(self, avg):
        n4 = qz.allocate_bits(1024, avg)
        achieved = (4 * n4 + 2 * (1024 - n4)) / 1024
        assert abs(achieved - avg) < 0.02 and n4 % 4 == 0

"""benchmarks.trajectory: the perf-trajectory gate.

Synthetic BENCH_*.json run/baseline directories drive every branch of the
gate: clean pass, each metric's regression direction (qps down, recall
down, bytes up), tolerance behavior, coverage regressions (a baseline
record the current run stopped reporting), new-coverage records, and
--write-baseline re-seeding.
"""

import json

import pytest

from benchmarks import trajectory


def _write_bench(dirpath, bench, records, status="ok"):
    payload = {"bench": bench, "status": status, "smoke": True,
               "csv_rows": [{"name": f"{bench}/x", "us_per_call": 1.0,
                             "derived": "noise"}],
               "records": records}
    path = dirpath / f"BENCH_{bench}.json"
    path.write_text(json.dumps(payload))
    return path


def _rec(qps=1000.0, recall=0.9, bpv=32, **identity):
    base = {"backend": "bruteforce", "n": 2048, "k": 10}
    base.update(identity)
    base.update(qps=qps, recall_at_10=recall, bytes_per_vector=bpv)
    return base


@pytest.fixture
def dirs(tmp_path):
    run = tmp_path / "run"
    base = tmp_path / "base"
    run.mkdir()
    base.mkdir()
    return run, base


def _gate(run, base, *extra):
    return trajectory.run(["--run-dir", str(run),
                           "--baseline-dir", str(base), *extra])


class TestGate:
    def test_identical_run_passes(self, dirs, capsys):
        run, base = dirs
        _write_bench(run, "filtered", [_rec(), _rec(n=4096)])
        _write_bench(base, "filtered", [_rec(), _rec(n=4096)])
        assert _gate(run, base) == 0
        assert "no regressions" in capsys.readouterr().out

    def test_qps_regression_fails(self, dirs, capsys):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(qps=1000)])
        _write_bench(run, "filtered", [_rec(qps=500)])
        assert _gate(run, base, "--qps-tol", "0.85") == 1
        assert "qps regressed" in capsys.readouterr().err

    def test_qps_tolerance_absorbs_noise(self, dirs):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(qps=1000)])
        _write_bench(run, "filtered", [_rec(qps=900)])
        assert _gate(run, base, "--qps-tol", "0.85") == 0
        assert _gate(run, base, "--qps-tol", "0.95") == 1

    def test_qps_improvement_passes(self, dirs):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(qps=1000)])
        _write_bench(run, "filtered", [_rec(qps=5000)])
        assert _gate(run, base) == 0

    def test_any_recall_drop_fails_by_default(self, dirs, capsys):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(recall=0.925)])
        _write_bench(run, "filtered", [_rec(recall=0.924)])
        assert _gate(run, base) == 1
        assert "recall_at_10 regressed" in capsys.readouterr().err

    def test_recall_tol_allows_epsilon(self, dirs):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(recall=0.925)])
        _write_bench(run, "filtered", [_rec(recall=0.920)])
        assert _gate(run, base, "--recall-tol", "0.01") == 0

    def test_bytes_increase_fails(self, dirs, capsys):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(bpv=32)])
        _write_bench(run, "filtered", [_rec(bpv=33)])
        assert _gate(run, base) == 1
        assert "bytes_per_vector regressed" in capsys.readouterr().err

    def test_bytes_decrease_passes(self, dirs):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(bpv=32)])
        _write_bench(run, "filtered", [_rec(bpv=16)])
        assert _gate(run, base) == 0

    def test_missing_record_is_coverage_regression(self, dirs, capsys):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(), _rec(n=4096)])
        _write_bench(run, "filtered", [_rec()])
        assert _gate(run, base) == 1
        assert "record missing" in capsys.readouterr().err

    def test_new_record_is_noted_not_gated(self, dirs, capsys):
        run, base = dirs
        _write_bench(base, "filtered", [_rec()])
        _write_bench(run, "filtered", [_rec(), _rec(n=4096, qps=1.0)])
        assert _gate(run, base) == 0
        assert "no baseline" in capsys.readouterr().out

    def test_empty_baseline_dir_is_setup_error(self, dirs, capsys):
        run, base = dirs
        _write_bench(run, "filtered", [_rec()])
        assert _gate(run, base) == 2
        assert "--write-baseline" in capsys.readouterr().err


class TestMatching:
    def test_identity_excludes_metric_fields(self, dirs):
        """Same identity, different metric values -> matched and compared
        (not treated as a new record)."""
        run, base = dirs
        _write_bench(base, "filtered", [_rec(qps=1000)])
        _write_bench(run, "filtered", [_rec(qps=999)])
        assert _gate(run, base, "--qps-tol", "0.99") == 0

    def test_different_identity_not_matched(self, dirs):
        run, base = dirs
        _write_bench(base, "filtered", [_rec(n=2048)])
        _write_bench(run, "filtered", [_rec(n=4096)])
        assert _gate(run, base) == 1   # baseline n=2048 went missing

    def test_us_per_call_never_gated(self, dirs):
        """Raw wall time is machine noise: 100x slower must still pass."""
        run, base = dirs
        _write_bench(base, "filtered",
                     [dict(_rec(), us_per_call=100.0)])
        _write_bench(run, "filtered",
                     [dict(_rec(), us_per_call=10_000.0)])
        assert _gate(run, base) == 0

    def test_records_without_metrics_skipped(self, dirs):
        run, base = dirs
        _write_bench(base, "engine", [{"backend": "b", "note": "no metrics"}])
        _write_bench(run, "engine", [])
        # The baseline record carried nothing gateable -> empty baseline.
        assert _gate(run, base) == 2


class TestWriteBaseline:
    def test_seeds_records_only(self, dirs):
        run, base = dirs
        _write_bench(run, "filtered", [_rec()])
        _write_bench(run, "empty", [])   # record-less files are not seeded
        assert _gate(run, base, "--write-baseline") == 0
        files = sorted(p.name for p in base.iterdir())
        assert files == ["BENCH_filtered.json"]
        payload = json.loads((base / "BENCH_filtered.json").read_text())
        assert payload["records"] == [_rec()]
        assert "csv_rows" not in payload   # timing noise stays out of git

    def test_reseeded_baseline_gates_clean(self, dirs):
        run, base = dirs
        _write_bench(run, "filtered", [_rec(), _rec(n=4096)])
        assert _gate(run, base, "--write-baseline") == 0
        assert _gate(run, base) == 0


class TestCommittedBaselines:
    def test_repo_baselines_exist_and_parse(self):
        """The committed benchmarks/baselines/ seed is non-empty and every
        record carries at least one gateable metric."""
        records = trajectory.load_records(trajectory._BASELINE_DIR)
        assert records, "benchmarks/baselines/ must be seeded"
        for key, metrics in records.items():
            assert any(m in metrics for m in trajectory.GATED_METRICS), key

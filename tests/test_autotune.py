"""Training-free autotuner (DESIGN.md §12): determinism, knob resolution,
persistence, and the selectivity boost.

The contract under test:
  * tuning is a pure function of (index bytes, recall_target, k, n_queries,
    seed) — two runs agree exactly and the persisted v11 file is
    byte-identical across save→load→save;
  * the chosen knob is the SMALLEST ladder rung meeting the target against
    the exact quantized-scan oracle (ladder recalls are monotone data);
  * resolution precedence is explicit kwarg > tuned default > engine
    default, with the engine's clamps applied last and visible through
    ``MonaVec.resolved_knobs``;
  * the tuned boost curve widens filtered candidate budgets by exact
    selectivity, and never touches unfiltered searches.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import Lt, MonaVec, TenantRegistry
from repro.tune import (BoostCurve, BoostPoint, knob_ladder, measure_recall,
                        sample_queries)

DIM = 16


def _corpus(n, seed=5, dim=DIM):
    rng = np.random.RandomState(seed)
    centers = rng.randn(8, dim).astype(np.float32) * 2.0
    return (centers[rng.randint(0, 8, n)]
            + rng.randn(n, dim).astype(np.float32) * 0.3)


def _ivf(n=600, nlist=8, **kw):
    return MonaVec.build(_corpus(n), metric="cosine", index="ivf",
                         nlist=nlist, **kw)


class TestDeterminism:
    def test_same_inputs_same_result(self):
        a = _ivf().autotune(recall_target=0.9, k=5, n_queries=16).tuned
        b = _ivf().autotune(recall_target=0.9, k=5, n_queries=16).tuned
        assert a == b

    def test_save_load_save_byte_identity(self, tmp_path):
        idx = _ivf().autotune(recall_target=0.9, k=5, n_queries=16)
        p1, p2 = str(tmp_path / "a.mvec"), str(tmp_path / "b.mvec")
        idx.save(p1)
        assert open(p1, "rb").read()[4] == 11
        idx2 = MonaVec.load(p1)
        assert idx2.tuned == idx.tuned
        idx2.save(p2)
        assert open(p1, "rb").read() == open(p2, "rb").read()

    def test_sample_queries_seeded(self):
        idx = _ivf()
        q1 = sample_queries(idx, 16, seed=3)
        q2 = sample_queries(idx, 16, seed=3)
        q3 = sample_queries(idx, 16, seed=4)
        np.testing.assert_array_equal(q1, q2)
        assert not np.array_equal(q1, q3)
        assert q1.shape[1] == DIM


class TestKnobChoice:
    def test_smallest_rung_meeting_target(self):
        idx = _ivf()
        t = idx.autotune(recall_target=0.9, k=5, n_queries=16).tuned
        assert t.met_target
        rungs = t.ladder["nprobe"]
        chosen = t.knobs["nprobe"]
        # every smaller rung missed the target; the chosen one met it
        for r in rungs:
            if r.value < chosen:
                assert r.recall < 0.9
            if r.value == chosen:
                assert r.recall >= 0.9

    def test_ladder_is_ascending_and_ends_exact(self):
        idx = _ivf(nlist=8)
        name, rungs = knob_ladder(idx, k=5)
        assert name == "nprobe"
        assert list(rungs) == sorted(rungs)
        assert rungs[-1] == 8          # the always-safe ceiling rung
        t = idx.autotune(recall_target=1.0, k=5).tuned
        assert t.ladder["nprobe"][-1].recall == 1.0   # nprobe=nlist is exact

    def test_unmet_target_falls_back_to_best(self):
        # recall_target=1.0 on a tiny HNSW graph may or may not be met;
        # force un-meetable by demanding 1.0 from nprobe ladder truncated via
        # a target the quantized scan itself satisfies -- so instead check
        # the met_target=False path via a plain BF index with empty ladder.
        idx = MonaVec.build(_corpus(60), metric="cosine")
        t = idx.autotune(recall_target=0.9, k=5, n_queries=8).tuned
        assert t.knobs == {} and t.met_target   # full scan IS the oracle

    def test_validation(self):
        idx = _ivf(n=100, nlist=4)
        with pytest.raises(ValueError):
            idx.autotune(recall_target=0.0)
        with pytest.raises(ValueError):
            idx.autotune(recall_target=1.5)
        with pytest.raises(ValueError):
            idx.autotune(k=0)

    def test_measure_recall_exact(self):
        ids = np.array([[1, 2, 3], [4, 5, 6]], dtype=np.int64)
        oracle = np.array([[1, 2, 9], [7, 8, 9]], dtype=np.int64)
        assert measure_recall(ids, oracle) == pytest.approx(2 / 6)


class TestResolutionPrecedence:
    def test_tuned_becomes_default_explicit_wins(self):
        idx = _ivf()
        idx.autotune(recall_target=0.9, k=5, n_queries=16)
        tuned_np = idx.tuned.knobs["nprobe"]
        assert idx.resolved_knobs(5) == {"nprobe": tuned_np}
        assert idx.resolved_knobs(5, nprobe=2) == {"nprobe": 2}
        # explicit kwarg still passes through the engine clamp
        assert idx.resolved_knobs(5, nprobe=999) == {"nprobe": 8}

    def test_untuned_engine_defaults(self):
        idx = _ivf()
        assert idx.resolved_knobs(5) == {"nprobe": 8}   # min(8, nlist)

    def test_hnsw_ef_widened_to_k(self):
        idx = MonaVec.build(_corpus(300), metric="cosine", index="hnsw",
                            m=4, ef_construction=16)
        idx.autotune(recall_target=0.5, k=4, n_queries=8)
        ef = idx.tuned.knobs["ef"]
        assert idx.resolved_knobs(4) == {"ef": max(ef, 4)}
        assert idx.resolved_knobs(64, ef=4) == {"ef": 64}

    def test_tuned_search_matches_explicit_knob(self):
        idx = _ivf()
        idx.autotune(recall_target=0.9, k=5, n_queries=16)
        npb = idx.tuned.knobs["nprobe"]
        q = _corpus(6, seed=9)
        _, tuned_ids = idx.search(q, 5)
        untuned = _ivf()
        _, explicit_ids = untuned.search(q, 5, nprobe=npb)
        np.testing.assert_array_equal(tuned_ids, explicit_ids)

    def test_tuned_survives_compact_and_registry(self):
        reg = TenantRegistry()
        idx = _ivf()
        t = reg.put(None, "c", idx)
        assert t is not None
        res = reg.autotune(None, "c", recall_target=0.9, k=5, n_queries=16)
        assert res is idx.tuned and res.knobs
        idx.add(_corpus(40, seed=8))
        idx.delete(idx.ids[::7])
        reg.compact(None, "c")
        assert idx.tuned is res        # knobs ride through the lifecycle
        assert "nprobe" in idx.resolved_knobs(5)


class TestBoost:
    def test_boost_curve_semantics(self):
        c = BoostCurve(points=(BoostPoint(0.01, 16, 0.9),
                               BoostPoint(0.1, 4, 0.95)))
        assert c.multiplier(0.005) == 16
        assert c.multiplier(0.05) == 4
        assert c.multiplier(0.5) == 1
        with pytest.raises(ValueError):
            BoostCurve(points=(BoostPoint(0.1, 4, 0.9),
                               BoostPoint(0.01, 16, 0.9)))

    def test_boost_improves_filtered_recall(self):
        n = 1200
        rng = np.random.RandomState(3)
        attr = rng.randint(0, 100, n).astype(np.int64)
        idx = MonaVec.build(_corpus(n), metric="cosine", index="ivf",
                            nlist=16, meta={"attr": attr})
        idx.autotune(recall_target=0.9, k=5, n_queries=16)
        t = idx.tuned
        assert t.boost is not None and len(t.boost.points) >= 1
        q = _corpus(8, seed=13)
        where = Lt("attr", 3)            # ~3% selectivity
        # oracle: sweep every list under the same mask
        _, gt = idx.search(q, 5, where=where, nprobe=16)
        idx.tuned = dataclasses.replace(t, boost=None)
        _, plain = idx.search(q, 5, where=where)
        idx.tuned = t
        _, boosted = idx.search(q, 5, where=where)
        assert measure_recall(boosted, gt) >= measure_recall(plain, gt)

    def test_boost_leaves_unfiltered_knobs_alone(self):
        idx = _ivf()
        idx.autotune(recall_target=0.9, k=5, n_queries=16)
        q = _corpus(4, seed=9)
        _, ids_tuned = idx.search(q, 5)
        _, ids_explicit = idx.search(q, 5, nprobe=idx.tuned.knobs["nprobe"])
        np.testing.assert_array_equal(ids_tuned, ids_explicit)

    def test_tuned_roundtrips_with_boost(self, tmp_path):
        n = 800
        attr = np.arange(n, dtype=np.int64) % 50
        idx = MonaVec.build(_corpus(n), metric="cosine", index="ivf",
                            nlist=8, meta={"attr": attr})
        idx.autotune(recall_target=0.9, k=5, n_queries=16)
        p = str(tmp_path / "t.mvec")
        idx.save(p)
        idx2 = MonaVec.load(p)
        assert idx2.tuned == idx.tuned
        q = _corpus(4, seed=21)
        s1 = idx.search(q, 5, where=Lt("attr", 2))
        s2 = idx2.search(q, 5, where=Lt("attr", 2))
        np.testing.assert_array_equal(s1[1], s2[1])


class TestCascadeLadder:
    def test_rescore_mult_tuned_on_coarse_index(self):
        idx = MonaVec.build(_corpus(400), metric="cosine", coarse="sign")
        t = idx.autotune(recall_target=0.8, k=5, n_queries=16).tuned
        name, rungs = knob_ladder(idx, k=5)
        assert name == "rescore_mult" and list(rungs) == sorted(rungs)
        if t.knobs:                       # may collapse to the full scan
            assert t.knobs["rescore_mult"] in rungs
        assert "rescore_mult" in t.ladder

"""Shared oracle for the cascade suites (deterministic + hypothesis twins
both drive it, so the survivor contract is exercised even where hypothesis
is unavailable — the same split as lifecycle_harness).

The survivor oracle is the ISSUE's "brute-force oracle": stable top-m of
the tombstone-masked integer proxies (ties broken by lowest row — numpy's
``argsort(kind="stable")`` on the negated values), emitted as ASCENDING row
indices with -1 padding.  ``binary.survivor_topk_stage`` must equal this
EXACTLY — it is the canonical ranked prefix, not merely an admissible set —
because the rescore stage's candidate list (and therefore every cascade
search result) is a pure function of it.
"""

from __future__ import annotations

import numpy as np


def survivor_oracle(proxy: np.ndarray, live: np.ndarray, m: int) -> np.ndarray:
    """Stable top-m of the live proxies, ascending, -1 padded (int64 host
    math — the jax stage must reproduce this in int32 exactly)."""
    b, n = proxy.shape
    out = np.full((b, m), -1, np.int32)
    dead = -(np.int64(1) << 62)        # below any proxy, negation-safe
    for r in range(b):
        vals = proxy[r].astype(np.int64).copy()
        vals[~live] = dead
        order = np.argsort(-vals, kind="stable")[:m]
        order = np.sort(order[live[order]])
        out[r, :order.size] = order
    return out

"""Index backend behaviour: recall, determinism, allowlist, persistence."""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Allowlist, BruteForceIndex, GlobalStd, HnswIndex,
                        HybridIndex, IvfFlatIndex, MonaVec, recommended_m)
from repro.core.bm25 import Bm25Index, tokenize
from repro.core.rrf import rrf_fuse
from repro.core.scoring import score_f32, topk
from repro.data.synthetic import embedding_corpus, pixel_corpus, queries_from_corpus


@pytest.fixture(scope="module")
def corpus():
    return embedding_corpus(0, 3000, 128)


@pytest.fixture(scope="module")
def queries(corpus):
    return queries_from_corpus(corpus, 1, 25)


@pytest.fixture(scope="module")
def gt(corpus, queries):
    return np.asarray(topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                                     "cosine"), 10)[1])


def recall10(ids, gt):
    return np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                    for a, b in zip(ids.astype(np.int64), gt)])


class TestBruteForce:
    def test_high_recall_on_clustered(self, corpus, queries, gt):
        idx = BruteForceIndex.build(jnp.asarray(corpus), metric="cosine")
        _, ids = idx.search(jnp.asarray(queries), 10)
        assert recall10(ids, gt) > 0.85   # paper band on semantic embeddings

    def test_reload_reproduces_exactly(self, corpus, queries):
        """The paper's determinism guarantee: load -> search is identical."""
        idx = MonaVec.build(corpus, metric="cosine")
        s1, i1 = idx.search(queries, 10)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "c.mvec")
            idx.save(p)
            s2, i2 = MonaVec.load(p).search(queries, 10)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(s1, s2)      # byte-identical scores

    def test_prefilter_allowlist_exact_k(self, corpus, queries):
        """Pre-filter guarantees exactly k allowed results (paper §3.5)."""
        idx = BruteForceIndex.build(jnp.asarray(corpus), metric="cosine")
        allow = Allowlist.from_ids(range(100), idx.ids)
        _, ids = idx.search(jnp.asarray(queries), 10, allow=allow)
        assert (ids < 100).all()
        assert ids.shape == (len(queries), 10)
        # selective allowlist: recall vs exact filtered search is perfect
        gt_f = score_f32(jnp.asarray(queries), jnp.asarray(corpus[:100]), "cosine")
        _, gt_ids = topk(gt_f, 10)
        enc_gt = np.asarray(gt_ids)
        got = ids.astype(np.int64)
        overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                           for a, b in zip(got, enc_gt)])
        assert overlap > 0.85

    def test_sparse_allowlist_variant(self, corpus):
        idx = BruteForceIndex.build(jnp.asarray(corpus), metric="cosine")
        sparse_ids = [5, 999, 2500]
        allow = Allowlist.from_ids(sparse_ids, idx.ids)
        assert allow.n_allowed == 3
        _, ids = idx.search(jnp.asarray(corpus[:2]), 3, allow=allow)
        assert set(ids.ravel().tolist()) <= set(sparse_ids)


class TestIvf:
    def test_recall_and_determinism(self, corpus, queries, gt):
        idx = IvfFlatIndex.build(jnp.asarray(corpus), metric="cosine", nlist=32)
        _, ids = idx.search(jnp.asarray(queries), 10, nprobe=16)
        r = recall10(ids, gt)
        assert r > 0.75, r
        idx2 = IvfFlatIndex.build(jnp.asarray(corpus), metric="cosine", nlist=32)
        _, ids2 = idx2.search(jnp.asarray(queries), 10, nprobe=16)
        np.testing.assert_array_equal(ids, ids2)

    def test_nprobe_monotone(self, corpus, queries, gt):
        idx = IvfFlatIndex.build(jnp.asarray(corpus), metric="cosine", nlist=32)
        recalls = []
        for nprobe in (1, 4, 16, 32):
            _, ids = idx.search(jnp.asarray(queries), 10, nprobe=nprobe)
            recalls.append(recall10(ids, gt))
        assert recalls == sorted(recalls)
        assert recalls[-1] > 0.85       # nprobe = nlist ~= bruteforce


class TestHnsw:
    def test_fp32_build_4bit_search_recall(self, corpus, queries, gt):
        idx = HnswIndex.build(jnp.asarray(corpus), metric="cosine", m=16,
                              ef_construction=96)
        _, ids = idx.search(jnp.asarray(queries), 10, ef=128)
        assert recall10(ids, gt) > 0.8

    def test_graph_determinism(self, corpus):
        a = HnswIndex.build(jnp.asarray(corpus[:800]), metric="cosine", m=8,
                            ef_construction=40)
        b = HnswIndex.build(jnp.asarray(corpus[:800]), metric="cosine", m=8,
                            ef_construction=40)
        np.testing.assert_array_equal(a.neighbors0, b.neighbors0)
        np.testing.assert_array_equal(a.neighbors_hi, b.neighbors_hi)
        assert a.entry_point == b.entry_point

    def test_auto_m_policy(self):
        assert recommended_m(45_000) == 32
        assert recommended_m(999_999) == 32
        assert recommended_m(1_000_000) == 64
        assert recommended_m(1_180_000) == 64

    def test_l2_metric_aware_build(self):
        """Paper contributions #2/#3 on raw-magnitude L2 data: fit() lifts the
        quantization ceiling, and the metric-aware HNSW build reaches it."""
        pix = pixel_corpus(3, 1200, 64)
        q = queries_from_corpus(pix, 4, 15, noise=2.0)
        std = GlobalStd.fit(pix)
        gt_l2 = np.asarray(topk(score_f32(jnp.asarray(q), jnp.asarray(pix), "l2"), 10)[1])
        bf_fit = BruteForceIndex.build(jnp.asarray(pix), metric="l2", std=std)
        _, ids_bf = bf_fit.search(jnp.asarray(q), 10)
        bf_nofit = BruteForceIndex.build(jnp.asarray(pix), metric="l2")
        _, ids_nf = bf_nofit.search(jnp.asarray(q), 10)
        ceiling = recall10(ids_bf, gt_l2)
        # §4.3: fit() substantially beats the raw-distribution baseline.
        assert ceiling > 1.3 * recall10(ids_nf, gt_l2)
        idx = HnswIndex.build(jnp.asarray(pix), metric="l2", std=std, m=16,
                              ef_construction=96)
        _, ids = idx.search(jnp.asarray(q), 10, ef=128)
        # The graph reaches the scalar-quantization ceiling (paper Table 3:
        # HNSW ef=400 == BF recall).
        assert recall10(ids, gt_l2) >= 0.9 * ceiling

    def test_k_exceeds_ef_auto_raises_beam(self, corpus, queries):
        """k=100 with the default ef=64 must return 100 rows, not 64: the
        beam auto-widens to max(ef, k) instead of silently truncating."""
        idx = HnswIndex.build(jnp.asarray(corpus[:1500]), metric="cosine",
                              m=8, ef_construction=64)
        scores, ids = idx.search(jnp.asarray(queries), 100, ef=64)
        assert ids.shape == (len(queries), 100)
        valid = ids != np.uint64(0xFFFFFFFFFFFFFFFF)
        assert valid.all()
        # distinct results, sorted by score
        assert all(len(set(row.tolist())) == 100 for row in ids)
        assert (np.diff(scores, axis=1) <= 0).all()

    def test_allowlist_traversal_routes_over_blocked(self, corpus, queries):
        idx = HnswIndex.build(jnp.asarray(corpus[:1000]), metric="cosine", m=8,
                              ef_construction=64)
        allow = Allowlist.from_ids(range(0, 1000, 10), idx.ids)   # 10% selective
        _, ids = idx.search(jnp.asarray(queries), 5, ef=128, allow=allow)
        valid = ids != np.uint64(0xFFFFFFFFFFFFFFFF)
        assert valid.mean() > 0.95
        assert (ids[valid].astype(np.int64) % 10 == 0).all()


class TestHybridAndBm25:
    def test_bm25_exact_term_match_wins(self):
        docs = ["alpha beta gamma", "delta epsilon", "alpha alpha zeta",
                "unrelated words here"] * 10
        idx = Bm25Index.build(docs)
        scores, rows = idx.search("alpha", 3)
        assert all("alpha" in docs[r] for r in rows)
        assert scores[0] >= scores[1] >= scores[2]

    def test_rrf_fusion_properties(self):
        a = np.array([1, 2, 3, 4])
        b = np.array([3, 1, 5, 6])
        vals, ids = rrf_fuse([a, b], top_k=4)
        assert ids[0] in (1, 3)                  # appears top in both lists
        assert len(ids) == 4
        v2, i2 = rrf_fuse([a, b], top_k=4)
        np.testing.assert_array_equal(ids, i2)   # deterministic

    def test_hybrid_keyword_sensitivity(self, corpus):
        docs = [f"doc {i} " + ("special keyword" if i == 42 else "ordinary text")
                for i in range(len(corpus))]
        hy = HybridIndex.build(jnp.asarray(corpus), docs, metric="cosine")
        # [1, d] input follows the batched contract: [1, k] output rows.
        _, ids = hy.search(jnp.asarray(corpus[7:8]), ["special keyword"], 10)
        assert 42 in ids[0].tolist()

    def test_bm25_allowlist_prefilters_before_topk(self):
        """§3.5 on the sparse channel: a selective allowlist yields exactly
        min(k, n_allowed) rows, all allowed — not a post-filtered remnant."""
        docs = ["alpha beta"] * 50 + ["alpha gamma"] * 150
        idx = Bm25Index.build(docs)
        mask = np.zeros(200, bool)
        mask[100:130] = True                  # 30 allowed rows, none "beta"
        scores, rows = idx.search("alpha beta", 20, allow_mask=mask)
        assert len(rows) == 20
        assert mask[rows].all()
        # only 5 allowed -> exactly 5 back, never padded with disallowed rows
        mask5 = np.zeros(200, bool)
        mask5[:5] = True
        _, rows5 = idx.search("alpha", 20, allow_mask=mask5)
        assert sorted(rows5.tolist()) == [0, 1, 2, 3, 4]

    def test_hybrid_allowlist_exact_k(self, corpus):
        """Both fusion channels pre-filter: hybrid search under a selective
        allowlist returns exactly k results, every one allowed."""
        docs = [f"doc number {i} common text" for i in range(len(corpus))]
        hy = HybridIndex.build(jnp.asarray(corpus), docs, metric="cosine")
        allow = Allowlist.from_ids(range(0, 3000, 7), hy.dense.ids)
        _, ids = hy.search(jnp.asarray(corpus[5]), "common text", 10,
                           allow=allow)
        assert len(ids) == 10
        assert (ids.astype(np.int64) % 7 == 0).all()

    def test_tokenize_unicode(self):
        """Regression: the old `[a-z0-9]+` pattern silently dropped every
        non-ASCII term; the Unicode word pattern keeps them and still
        tokenizes lowered ASCII identically (splitting at `_`)."""
        assert tokenize("Café au lait") == ["café", "au", "lait"]
        assert tokenize("北京 naïve test_case Hello123") == \
            ["北京", "naïve", "test", "case", "hello123"]
        # ASCII behaviour unchanged vs the old pattern
        assert tokenize("Alpha-Beta_gamma 42") == ["alpha", "beta", "gamma", "42"]

    def test_bm25_non_ascii_docs_retrievable(self):
        """Accented and CJK docs must score > 0 for their own terms — under
        the old tokenizer their postings were empty and every query missed."""
        docs = ["der schnelle braune Fuchs", "café und naïveté",
                "北京 大学 图书馆", "plain ascii filler text"] * 3
        idx = Bm25Index.build(docs)
        for query, row in [("café", 1), ("北京 图书馆", 2), ("Fuchs", 0)]:
            scores, rows = idx.search(query, 3)
            assert scores[0] > 0.0, query
            assert rows[0] % 4 == row, (query, rows)

    def test_hybrid_batched_rows_independent(self, corpus):
        """Regression: the old bypass fused `dense_ids[0]` for EVERY query
        row, so any row past the first got row 0's dense channel.  Each
        batched row must now equal its own solo search exactly."""
        docs = [f"doc {i} " + ("needle term" if i % 11 == 0 else "hay stack")
                for i in range(600)]
        hy = HybridIndex.build(jnp.asarray(corpus[:600]), docs, metric="cosine")
        q = np.asarray(corpus[40:44]) + 0.01
        texts = ["needle term", "hay stack", "needle", "doc stack"]
        vals, ids = hy.search(jnp.asarray(q), texts, 8)
        assert ids.shape == (4, 8) and vals.shape == (4, 8)
        rows = []
        for i in range(4):
            v1, i1 = hy.search(jnp.asarray(q[i]), texts[i], 8)
            rows.append((v1, i1))
            np.testing.assert_array_equal(ids[i, :len(i1)], i1)
            np.testing.assert_array_equal(vals[i, :len(v1)], v1)
            assert (ids[i, len(i1):].astype(np.int64) == -1).all()
        # the rows genuinely differ (the old bug made them share a channel)
        assert not np.array_equal(rows[0][1], rows[1][1])

    def test_hybrid_single_query_contract(self, corpus):
        """A 1-D query returns 1-D results (possibly < k when the fused pool
        is small) — the pre-refactor calling convention, preserved."""
        docs = [f"word{i} text" for i in range(100)]
        hy = HybridIndex.build(jnp.asarray(corpus[:100]), docs, metric="cosine")
        vals, ids = hy.search(jnp.asarray(corpus[3]), "word7 text", 5)
        assert vals.ndim == 1 and ids.ndim == 1
        assert len(vals) == len(ids) == 5
        assert len(set(ids.tolist())) == 5

    def test_hybrid_prerefactor_fixture(self):
        """The engine-routed hybrid path reproduces the PRE-refactor
        `HybridIndex.search` outputs exactly (scores and ids, bit for bit)
        on the pinned fixture — the refactor's bit-identity contract."""
        gold = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "golden")
        data = np.load(os.path.join(gold, "hybrid_prerefactor.npz"))
        docs = open(os.path.join(gold, "hybrid_prerefactor_docs.txt"),
                    encoding="utf-8").read().splitlines()
        texts = open(os.path.join(gold, "hybrid_prerefactor_texts.txt"),
                     encoding="utf-8").read().splitlines()
        hy = HybridIndex.build(jnp.asarray(data["vectors"]), docs,
                               metric="cosine", seed=77)
        allow = Allowlist.from_ids(np.asarray(hy.dense.ids)[::2],
                                   hy.dense.ids)
        for ci, (k, fk, rrf_k, use_allow) in enumerate(data["cases"]):
            kw = dict(k=int(k), rrf_k=int(rrf_k),
                      fetch_k=None if fk < 0 else int(fk),
                      allow=allow if use_allow else None)
            for qi in range(data["queries"].shape[0]):
                vals, ids = hy.search(jnp.asarray(data["queries"][qi]),
                                      texts[qi], **kw)
                np.testing.assert_array_equal(
                    ids, data[f"ids_{ci}_{qi}"], err_msg=f"case {ci} q {qi}")
                np.testing.assert_array_equal(
                    vals, data[f"vals_{ci}_{qi}"], err_msg=f"case {ci} q {qi}")

    def test_hybrid_where_filters_both_channels(self, corpus):
        """A metadata predicate pre-filters the dense AND sparse channels:
        every fused result satisfies it."""
        from repro.core import Eq
        docs = [f"doc {i} shared term" for i in range(300)]
        cat = np.array(["a", "b", "c"])[np.arange(300) % 3]
        hy = HybridIndex.build(jnp.asarray(corpus[:300]), docs,
                               metric="cosine", meta={"cat": cat})
        vals, ids = hy.search(jnp.asarray(corpus[2:5]), ["shared term"] * 3,
                              6, where=Eq("cat", "a"))
        real = ids[ids.astype(np.int64) >= 0]
        assert real.size > 0
        assert (real.astype(np.int64) % 3 == 0).all()


class TestMvecFormat:
    @pytest.mark.parametrize("kind,kw", [
        ("bruteforce", {}), ("ivf", {"nlist": 8}),
        ("hnsw", {"m": 8, "ef_construction": 32}),
    ])
    def test_roundtrip_all_backends(self, kind, kw, corpus, queries):
        idx = MonaVec.build(corpus[:600], metric="cosine", index=kind, **kw)
        s1, i1 = idx.search(queries, 5)
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "x.mvec")
            idx.save(p)
            idx2 = MonaVec.load(p)
            s2, i2 = idx2.search(queries, 5)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_allclose(s1, s2)

    def test_header_fields(self, corpus):
        from repro.core import mvec_format as fmt
        idx = MonaVec.build(corpus[:100], metric="l2",
                            std=GlobalStd.fit(corpus[:100]))
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "x.mvec")
            idx.save(p)
            raw = open(p, "rb").read()
            assert raw[:4] == b"MVEC"
            f = fmt.load(p)
        assert f.enc.metric == "l2" and f.enc.bits == 4
        assert f.enc.std is not None
        assert f.enc.n == 100

    def test_rejects_garbage(self, tmp_path):
        from repro.core import mvec_format as fmt
        p = tmp_path / "bad.mvec"
        p.write_bytes(b"NOPE" + b"\x00" * 100)
        with pytest.raises(ValueError):
            fmt.load(str(p))

    @pytest.mark.parametrize("version", [1, 3, 5, 12])
    def test_rejects_unsupported_versions(self, version, corpus, tmp_path):
        """Versions 1-5 predate the v6 header layout (parsing them against it
        would misread every field) and future versions are unknown: all must
        be rejected with an error naming the version found.  (8 is the
        segmented layout since DESIGN.md §6, 9 adds metadata columns per
        DESIGN.md §8, 10 adds coarse CODE blocks per DESIGN.md §11, 11 adds
        the TUNE envelope per DESIGN.md §12 — none of those is rejected any
        more; the error's ceiling is pinned by test_mvec_golden.)"""
        import struct
        from repro.core import mvec_format as fmt
        p = str(tmp_path / "v.mvec")
        MonaVec.build(corpus[:50], metric="cosine").save(p)
        raw = bytearray(open(p, "rb").read())
        raw[4:8] = struct.pack("<I", version)       # overwrite VERSION field
        open(p, "wb").write(bytes(raw))
        with pytest.raises(ValueError, match=f"version {version}"):
            fmt.load(p)

"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret=True (CPU executes the kernel body; on TPU the
same BlockSpecs compile to Mosaic).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as qz, scoring
from repro.kernels import hadamard, ops, ref

RTOL = 2e-5


def _relerr(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


class TestNibbleDot:
    @pytest.mark.parametrize("n,d,b", [
        (128, 128, 1),       # minimum tile
        (256, 256, 8),
        (512, 1024, 32),     # block-multiple shapes
        (300, 512, 3),       # ragged n/b (padding path)
        (1000, 2048, 5),     # multi-k-block accumulation
        (45, 256, 130),      # n < block, b > block
    ])
    def test_matches_oracle(self, n, d, b, rng):
        packed = jnp.asarray(rng.randint(0, 256, size=(n, d // 2), dtype=np.uint8))
        q = jnp.asarray(rng.randn(b, d).astype(np.float32))
        out = ops.nibble_score_raw(packed, q, use_kernel=True, interpret=True)
        assert _relerr(out, ref.nibble_dot_ref(packed, q)) < RTOL

    def test_all_code_values_dequantize(self, rng):
        """Every nibble value 0..15 hits the right centroid (the NEON affine
        ramp bug of paper §4.6 is exactly this failure)."""
        codes = np.tile(np.arange(16, dtype=np.uint8), 16)[None].repeat(128, 0)
        packed = qz.pack_4bit(jnp.asarray(codes))
        q = jnp.asarray(np.eye(1, 256, dtype=np.float32))   # selects dim 0
        out = ops.nibble_score_raw(packed, q, use_kernel=True, interpret=True)
        expected = ref.nibble_dot_ref(packed, q)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=1e-6)

    def test_determinism_fixed_blocks(self, rng):
        packed = jnp.asarray(rng.randint(0, 256, size=(512, 128), dtype=np.uint8))
        q = jnp.asarray(rng.randn(16, 256).astype(np.float32))
        a = np.asarray(ops.nibble_score_raw(packed, q, interpret=True))
        b = np.asarray(ops.nibble_score_raw(packed, q, interpret=True))
        np.testing.assert_array_equal(a, b)


class TestCrumbDot:
    @pytest.mark.parametrize("n,d,b", [(128, 256, 2), (256, 512, 16), (77, 1024, 9)])
    def test_matches_oracle(self, n, d, b, rng):
        packed = jnp.asarray(rng.randint(0, 256, size=(n, d // 4), dtype=np.uint8))
        q = jnp.asarray(rng.randn(b, d).astype(np.float32))
        out = ops.crumb_score_raw(packed, q, use_kernel=True, interpret=True)
        assert _relerr(out, ref.crumb_dot_ref(packed, q)) < RTOL


class TestMixedScore:
    def test_mixed_matches_oracle(self, rng):
        corpus = rng.randn(300, 768).astype(np.float32)
        enc = qz.encode_mixed(jnp.asarray(corpus), avg_bits=3.0, seed=4)
        q = qz.encode_query(jnp.asarray(rng.randn(6, 768).astype(np.float32)), enc)
        out = ops.score_packed(q, enc, use_kernel=True, interpret=True)
        expected = scoring.score_packed_ref(q, enc)
        assert _relerr(out, expected) < RTOL


class TestGatherDot:
    """Gathered candidate-scan kernel vs oracle, and kernel-vs-mirror
    bit-identity (the use_kernel contract's numeric foundation)."""

    @pytest.mark.parametrize("n,d,b,mc", [
        (200, 256, 1, 8),        # single query, tiny frontier (HNSW shape)
        (500, 512, 9, 300),      # ragged everything (padding path)
        (300, 1024, 16, 640),    # multi-k-block accumulation
    ])
    def test_nibble_matches_oracle(self, n, d, b, mc, rng):
        packed = jnp.asarray(rng.randint(0, 256, size=(n, d // 2), dtype=np.uint8))
        q = jnp.asarray(rng.randn(b, d).astype(np.float32))
        cand = jnp.asarray(rng.randint(0, n, size=(b, mc)))
        out = ops.score_gathered_raw(packed, q, cand, bits=4,
                                     use_kernel=True, interpret=True)
        assert _relerr(out, ref.gather_nibble_dot_ref(packed, q, cand)) < RTOL

    @pytest.mark.parametrize("n,d,b,mc", [(128, 512, 3, 70), (400, 1024, 8, 256)])
    def test_crumb_matches_oracle(self, n, d, b, mc, rng):
        packed = jnp.asarray(rng.randint(0, 256, size=(n, d // 4), dtype=np.uint8))
        q = jnp.asarray(rng.randn(b, d).astype(np.float32))
        cand = jnp.asarray(rng.randint(0, n, size=(b, mc)))
        out = ops.score_gathered_raw(packed, q, cand, bits=2,
                                     use_kernel=True, interpret=True)
        assert _relerr(out, ref.gather_crumb_dot_ref(packed, q, cand)) < RTOL

    @pytest.mark.parametrize("bits", [4, 2])
    def test_kernel_mirror_bit_identical(self, bits, rng):
        """Interpret-mode kernel == pure-jnp mirror, bit for bit: both walk
        the same (b, m, k) tile grid with the same tile function."""
        n, d, b, mc = 350, 512, 11, 410
        dk = d // (8 // bits)
        packed = jnp.asarray(rng.randint(0, 256, size=(n, dk), dtype=np.uint8))
        q = jnp.asarray(rng.randn(b, d).astype(np.float32))
        cand = jnp.asarray(rng.randint(0, n, size=(b, mc)))
        krn = ops.score_gathered_raw(packed, q, cand, bits=bits,
                                     use_kernel=True, interpret=True)
        jnp_ = ops.score_gathered_raw(packed, q, cand, bits=bits,
                                      use_kernel=False)
        np.testing.assert_array_equal(np.asarray(krn), np.asarray(jnp_))

    def test_matches_full_scan_on_identity_gather(self, rng):
        """Gathering ALL rows reproduces the flat scan's scores (same packed
        byte interpretation on both paths — the score_raw invariant)."""
        n, d, b = 160, 256, 4
        packed = jnp.asarray(rng.randint(0, 256, size=(n, d // 2), dtype=np.uint8))
        q = jnp.asarray(rng.randn(b, d).astype(np.float32))
        cand = jnp.tile(jnp.arange(n)[None], (b, 1))
        gathered = ops.score_gathered_raw(packed, q, cand, bits=4,
                                          use_kernel=False)
        flat = ops.score_raw(packed, q, bits=4, use_kernel=False)
        assert _relerr(gathered, flat) < RTOL


class TestHadamardKernel:
    @pytest.mark.parametrize("n,d", [(64, 128), (257, 512), (512, 1024), (33, 4096)])
    def test_matches_direct(self, n, d, rng):
        x = jnp.asarray(rng.randn(n, d).astype(np.float32))
        out = hadamard.fwht_pallas(x, interpret=True)
        assert _relerr(out, ref.hadamard_ref(x)) < RTOL

    def test_involution(self, rng):
        """H(Hx)/d == x (Hadamard is its own inverse up to scale)."""
        x = jnp.asarray(rng.randn(8, 256).astype(np.float32))
        y = hadamard.fwht_pallas(hadamard.fwht_pallas(x, interpret=True),
                                 interpret=True) / 256.0
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


class TestEndToEndKernelPath:
    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    def test_kernel_vs_ref_scoring(self, metric, rng):
        corpus = rng.randn(500, 384).astype(np.float32)
        enc = qz.encode(jnp.asarray(corpus), metric=metric, seed=11)
        q = qz.encode_query(jnp.asarray(rng.randn(7, 384).astype(np.float32)), enc)
        out = ops.score_packed(q, enc, use_kernel=True, interpret=True)
        expected = scoring.score_packed_ref(q, enc)
        assert _relerr(out, expected) < RTOL
        # identical top-k
        _, ik = scoring.topk(out, 10)
        _, ir = scoring.topk(expected, 10)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))

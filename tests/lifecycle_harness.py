"""Shared harness for the segmented-lifecycle tests (deterministic +
hypothesis property suites both drive it, so the oracle logic is exercised
even where hypothesis is unavailable).

The oracle is the ISSUE's "brute-force oracle over the surviving rows'
per-segment codes": every segment's adjusted score matrix computed by the
same ``ops.score_packed`` primitive the BruteForce path uses, tombstoned
rows masked to NEG, one stable top-k over the concatenation.  For the
BruteForce backend the search path IS this computation, so equality is
exact (scores and ids, bit for bit).  IVF (nprobe=nlist) and HNSW (ef ≥ n)
visit every live row but score candidates through the gathered-scan tiling,
which can differ from the full scan in the last ulp — those backends are
compared as per-row id SETS, with scores allclose.
"""

from __future__ import annotations

import hashlib
import os
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import MonaVec, SENTINEL_ID
from repro.core import quantize as qz
from repro.core.allowlist import NEG
from repro.core.scoring import topk
from repro.kernels import ops


def build_index(kind: str, x: np.ndarray, *, metric: str = "cosine",
                bits: int = 4, seed: int = 0x6D6F6E61, **kw) -> MonaVec:
    if kind == "ivf":
        kw.setdefault("nlist", max(2, len(x) // 8))
        kw.setdefault("train_iters", 5)
    elif kind == "hnsw":
        kw.setdefault("m", 4)
        kw.setdefault("ef_construction", 32)
    return MonaVec.build(x, metric=metric, index=kind, bits=bits, seed=seed, **kw)


def apply_ops(idx: MonaVec, ops_list: List[Tuple]) -> None:
    """Replay an op sequence: ("add", vecs) | ("delete", ids) | ("compact",).

    Ops that would empty the index or collide with live ids are skipped —
    the generators below may produce them, and a skip is itself
    deterministic, so replays stay identical.
    """
    for op in ops_list:
        if op[0] == "add":
            try:
                idx.add(op[1])
            except ValueError:
                pass
        elif op[0] == "delete":
            idx.delete(op[1])
        elif op[0] == "compact":
            try:
                idx.compact()
            except ValueError:     # zero live rows: skip, keep replaying
                pass
        else:
            raise AssertionError(f"unknown op {op[0]!r}")


def oracle_search(
    idx: MonaVec,
    queries: np.ndarray,
    k: int,
    *,
    use_kernel: Optional[bool] = False,
    interpret: Optional[bool] = None,
    allow_mask: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-segment brute-force scan of the CURRENT codes, stable top-k."""
    encs = [idx.backend.enc] + [s.enc for s in idx.mut.extras]
    all_ids = np.concatenate([idx.backend.ids] + [s.ids for s in idx.mut.extras])
    live = np.concatenate([~idx.mut.base_tombs] + [~s.tombs for s in idx.mut.extras])
    if allow_mask is not None:
        live = live & allow_mask
    cols = []
    for enc in encs:
        q_rot = qz.encode_query(jnp.asarray(queries), enc)
        cols.append(ops.score_packed(q_rot, enc, use_kernel=use_kernel,
                                     interpret=interpret))
    scores = np.array(jnp.concatenate(cols, axis=1))
    scores[:, ~live] = NEG
    if scores.shape[1] < k:   # k > n: sentinel-pad to the full [b, k] contract
        scores = np.pad(scores, ((0, 0), (0, k - scores.shape[1])),
                        constant_values=NEG)
        all_ids = np.pad(all_ids, (0, k - all_ids.shape[0]))
    vals, pos = topk(jnp.asarray(scores), k)
    vals, pos = np.asarray(vals), np.asarray(pos)
    out = all_ids[pos].copy()
    out[vals <= NEG] = SENTINEL_ID
    return vals, out


def assert_matches_oracle(
    idx: MonaVec, queries: np.ndarray, k: int, kind: str, *,
    use_kernel: Optional[bool] = False, interpret: Optional[bool] = None,
) -> None:
    if kind == "ivf":
        skw = {"nprobe": idx.backend.nlist}        # probe every cell
    elif kind == "hnsw":
        skw = {"ef": max(idx.n_total, k)}          # full beam
    else:
        skw = {}
    got_s, got_i = idx.search(queries, k, use_kernel=use_kernel,
                              interpret=interpret, **skw)
    want_s, want_i = oracle_search(idx, queries, k, use_kernel=use_kernel,
                                   interpret=interpret)
    if kind == "bruteforce":
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_s, want_s)
        return
    # Gathered-scan scores can differ from the full scan in the last ulp, so
    # compare the result SETS row by row (sentinels included) + score values.
    for gr, wr in zip(got_i.tolist(), want_i.tolist()):
        assert set(gr) == set(wr), (got_i, want_i)
    np.testing.assert_allclose(got_s, want_s, rtol=2e-5, atol=2e-6)


def assert_topk_admissible(
    idx: MonaVec, queries: np.ndarray, k: int, kind: str, *,
    use_kernel: Optional[bool] = False, interpret: Optional[bool] = None,
    tol: float = 1e-4,
) -> None:
    """Tie-robust oracle check for random (hypothesis-generated) corpora.

    Duplicate rows produce exact score ties, and equally-scored rows are
    interchangeable at the k boundary (the HNSW beam's visit order breaks
    ties differently from concatenated row order).  So instead of exact id
    equality, assert: exactly min(k, n_live) distinct real results, every
    one admissible (oracle score ≥ the oracle's k-th live score − tol), and
    the returned score profile matching the oracle's top-k profile.
    """
    if kind == "ivf":
        skw = {"nprobe": idx.backend.nlist}
    elif kind == "hnsw":
        skw = {"ef": max(idx.n_total, k)}
    else:
        skw = {}
    got_s, got_i = idx.search(queries, k, use_kernel=use_kernel,
                              interpret=interpret, **skw)
    want_s, want_i = oracle_search(idx, queries, idx.n_total,
                                   use_kernel=use_kernel, interpret=interpret)
    r = min(k, idx.n_live)
    for row in range(got_i.shape[0]):
        real = got_i[row][got_i[row] != SENTINEL_ID]
        assert real.shape[0] == r, (got_i[row], r)
        assert len(set(real.tolist())) == r
        if r == 0:
            continue
        kth = want_s[row][r - 1]
        admissible = set(want_i[row][want_s[row] >= kth - tol].tolist())
        assert set(real.tolist()) <= admissible, (real, admissible)
        np.testing.assert_allclose(np.sort(got_s[row][:r]),
                                   np.sort(want_s[row][:r]),
                                   rtol=2e-5, atol=tol)


def save_digest(idx: MonaVec, tmpdir: str, name: str = "x.mvec") -> str:
    p = os.path.join(tmpdir, name)
    idx.save(p)
    return hashlib.sha256(open(p, "rb").read()).hexdigest()

"""Query-execution engine (DESIGN.md §7): bucketing bit-identity, plan-cache
hit accounting, the uniform [b, k] contract, and the micro-batcher.

The load-bearing property: executing a batch of b queries inside a padded
power-of-two bucket returns EXACTLY what the direct b-row execution returns
— ids exact, scores to the last ulp — for every backend × metric × bits,
static, mutated, and sharded.  A full-bucket batch is by construction an
unpadded execution of the same plan, so comparing its row prefix against
smaller batches in the same bucket pins the guarantee without any appeal to
a second implementation; the BruteForce paths are additionally pinned
against the eager per-segment oracle (tests/lifecycle_harness.py), which
never goes through the engine.
"""

import numpy as np
import pytest

from repro import engine
from repro.core import (And, Eq, HybridIndex, Lt, MonaVec,
                        SENTINEL_ID, TenantRegistry)
from repro.core import predicate as pred
from tests.lifecycle_harness import assert_matches_oracle, build_index

BUCKET = 8          # queries per full bucket in these tests
DIM = 32


def _vecs(rng, n, dim=DIM):
    return rng.randn(n, dim).astype(np.float32)


def _mutate(idx, rng):
    idx.add(_vecs(rng, 3))
    idx.add(_vecs(rng, 5))
    idx.delete(idx.ids[::7])


def _search_kwargs(kind, idx, k):
    if kind == "ivf":
        return {"nprobe": max(2, idx.backend.nlist // 2)}
    if kind == "hnsw":
        return {"ef": max(16, k)}
    return {}


class TestBucketingBitIdentity:
    """b < bucket executions equal the full-bucket run's row prefix."""

    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    @pytest.mark.parametrize("metric", ["cosine", "l2"])
    @pytest.mark.parametrize("mutated", [False, True])
    def test_prefix_identity(self, kind, metric, mutated):
        rng = np.random.RandomState(11)
        idx = build_index(kind, _vecs(rng, 60), metric=metric)
        if mutated:
            _mutate(idx, rng)
        q = _vecs(rng, BUCKET)
        kw = _search_kwargs(kind, idx, 10)
        s_full, i_full = idx.search(q, 10, use_kernel=False, **kw)
        for b in (1, 3, 5, 7):
            s, i = idx.search(q[:b], 10, use_kernel=False, **kw)
            np.testing.assert_array_equal(i, i_full[:b])
            np.testing.assert_array_equal(s, s_full[:b])    # last-ulp exact

    @pytest.mark.parametrize("bits", [2, 4])
    def test_prefix_identity_across_bits(self, bits):
        rng = np.random.RandomState(12)
        idx = build_index("bruteforce", _vecs(rng, 50), bits=bits)
        _mutate(idx, rng)
        q = _vecs(rng, BUCKET)
        s_full, i_full = idx.search(q, 6, use_kernel=False)
        for b in (2, 6):
            s, i = idx.search(q[:b], 6, use_kernel=False)
            np.testing.assert_array_equal(i, i_full[:b])
            np.testing.assert_array_equal(s, s_full[:b])

    def test_mixed_precision_prefix_identity(self):
        rng = np.random.RandomState(13)
        idx = MonaVec.build(_vecs(rng, 50, 64), metric="cosine", avg_bits=3.0)
        q = _vecs(rng, BUCKET, 64)
        s_full, i_full = idx.search(q, 5, use_kernel=False)
        s, i = idx.search(q[:3], 5, use_kernel=False)
        np.testing.assert_array_equal(i, i_full[:3])
        np.testing.assert_array_equal(s, s_full[:3])

    @pytest.mark.parametrize("metric", ["cosine", "dot", "l2"])
    def test_bucketed_matches_eager_oracle(self, metric):
        """Second witness: the padded engine run equals the NON-engine eager
        per-segment oracle at the unpadded batch size."""
        rng = np.random.RandomState(14)
        idx = build_index("bruteforce", _vecs(rng, 40), metric=metric)
        _mutate(idx, rng)
        assert_matches_oracle(idx, _vecs(rng, 5), 10, "bruteforce",
                              use_kernel=False)

    def test_sharded_prefix_identity(self):
        rng = np.random.RandomState(15)
        idx = MonaVec.build(_vecs(rng, 64), metric="cosine")
        sharded = idx.shard()
        q = _vecs(rng, BUCKET)
        s_full, i_full = sharded.search(q, 7)
        s, i = sharded.search(q[:3], 7)
        np.testing.assert_array_equal(i, i_full[:3])
        np.testing.assert_array_equal(s, s_full[:3])
        # and the sharded scan matches the single-device engine result
        s1, i1 = idx.search(q, 7)
        np.testing.assert_array_equal(i_full, i1)
        np.testing.assert_allclose(s_full, s1, rtol=1e-6)


class TestExactKColumns:
    """k > n returns exactly k columns, SENTINEL/NEG padded — every backend,
    every lifecycle state (the static BruteForce path used to truncate to
    min(k, n))."""

    K, N = 12, 7

    def _assert_contract(self, scores, ids, n_real):
        assert ids.shape == (3, self.K) and scores.shape == (3, self.K)
        assert (ids[:, n_real:] == SENTINEL_ID).all()
        real = ids[:, :n_real]
        assert (real != SENTINEL_ID).all()
        for row in real:
            assert len(set(row.tolist())) == n_real

    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    def test_static(self, kind):
        rng = np.random.RandomState(21)
        idx = build_index(kind, _vecs(rng, self.N))
        kw = {"nprobe": idx.backend.nlist} if kind == "ivf" else (
            {"ef": self.N + self.K} if kind == "hnsw" else {})
        s, i = idx.search(_vecs(rng, 3), self.K, use_kernel=False, **kw)
        self._assert_contract(s, i, self.N)

    @pytest.mark.parametrize("kind", ["bruteforce", "ivf", "hnsw"])
    def test_mutated(self, kind):
        rng = np.random.RandomState(22)
        idx = build_index(kind, _vecs(rng, self.N))
        idx.add(_vecs(rng, 2))
        idx.delete([1, 3])
        kw = {"nprobe": idx.backend.nlist} if kind == "ivf" else (
            {"ef": idx.n_total + self.K} if kind == "hnsw" else {})
        s, i = idx.search(_vecs(rng, 3), self.K, use_kernel=False, **kw)
        self._assert_contract(s, i, idx.n_live)

    def test_sharded(self):
        rng = np.random.RandomState(23)
        sharded = MonaVec.build(_vecs(rng, self.N), metric="cosine").shard()
        s, i = sharded.search(_vecs(rng, 3), self.K)
        self._assert_contract(s, i, self.N)


class TestPlanCache:
    """Same bucket => cache hit => zero retraces; different knobs/shapes =>
    distinct plans."""

    def test_same_bucket_no_retrace(self):
        rng = np.random.RandomState(31)
        idx = build_index("bruteforce", _vecs(rng, 40))
        q = _vecs(rng, BUCKET)
        cache = engine.plan_cache()
        cache.clear()
        idx.search(q, 5, use_kernel=False)
        after_first = cache.stats.snapshot()
        assert after_first.misses == 1 and after_first.traces > 0
        for b in (BUCKET, 7, 5):
            idx.search(q[:b], 5, use_kernel=False)
        d = cache.stats.since(after_first)
        assert d.misses == 0 and d.traces == 0 and d.hits == 3

    def test_searcher_tracks_mutation(self):
        """add() changes the segment signature: the handle re-keys instead of
        serving a stale plan."""
        rng = np.random.RandomState(32)
        idx = build_index("bruteforce", _vecs(rng, 30))
        search = idx.searcher(k=4, use_kernel=False)
        q = _vecs(rng, 4)
        s1, i1 = search(q)
        idx.add(_vecs(rng, 3), ids=[1000, 1001, 1002])
        cache = engine.plan_cache()
        before = cache.stats.snapshot()
        s2, i2 = search(q)
        assert cache.stats.since(before).misses == 1   # new plan, new key
        assert set(map(int, np.unique(i2))) - set(map(int, np.unique(i1))) \
            <= {1000, 1001, 1002}

    def test_distinct_knobs_distinct_plans(self):
        rng = np.random.RandomState(33)
        idx = build_index("ivf", _vecs(rng, 64))
        q = _vecs(rng, 4)
        cache = engine.plan_cache()
        cache.clear()
        idx.search(q, 5, use_kernel=False, nprobe=2)
        idx.search(q, 5, use_kernel=False, nprobe=4)
        assert cache.stats.misses == 2
        idx.search(q, 5, use_kernel=False, nprobe=4)
        assert cache.stats.hits == 1

    def test_knob_normalization_shares_plans(self):
        """nprobe clamps to nlist and ef to max(ef, k) BEFORE keying, so
        equivalent requests share one plan."""
        rng = np.random.RandomState(34)
        idx = build_index("ivf", _vecs(rng, 40))
        nlist = idx.backend.nlist
        q = _vecs(rng, 4)
        cache = engine.plan_cache()
        cache.clear()
        idx.search(q, 5, use_kernel=False, nprobe=nlist)
        idx.search(q, 5, use_kernel=False, nprobe=nlist + 7)
        assert cache.stats.misses == 1 and cache.stats.hits == 1

    def test_tombstones_do_not_invalidate(self):
        """delete() is a dynamic-mask change: same plan, new results."""
        rng = np.random.RandomState(35)
        idx = build_index("bruteforce", _vecs(rng, 30))
        idx.add(_vecs(rng, 4))
        q = _vecs(rng, 4)
        _, i1 = idx.search(q, 3, use_kernel=False)
        cache = engine.plan_cache()
        before = cache.stats.snapshot()
        idx.delete([int(i1[0, 0])])
        _, i2 = idx.search(q, 3, use_kernel=False)
        d = cache.stats.since(before)
        assert d.misses == 0 and d.traces == 0 and d.hits == 1
        assert int(i1[0, 0]) not in i2[0].tolist()


def _meta_index(rng, n=60, mutated=False):
    meta = {"cat": np.array(["a", "b", "c"])[np.arange(n) % 3],
            "price": (rng.rand(n) * 100).astype(np.float64)}
    idx = MonaVec.build(_vecs(rng, n), metric="cosine", meta=meta)
    if mutated:
        m = 9
        idx.add(_vecs(rng, m),
                meta={"cat": np.array(["a", "c", "b"] * 3),
                      "price": (rng.rand(m) * 100).astype(np.float64)})
        idx.delete(idx.ids[::7])
    return idx


class TestFilteredPlans:
    """The predicate compiles into the plan as STRUCTURE: constants are
    dynamic arguments, so repeated same-shape filtered queries are cache
    hits with zero retraces (the ISSUE's acceptance criterion), and the
    compiled mask stage is bit-identical to the host-evaluated mask."""

    def test_same_structure_different_constants_zero_retrace(self):
        rng = np.random.RandomState(51)
        idx = _meta_index(rng)
        q = _vecs(rng, 4)
        cache = engine.plan_cache()
        cache.clear()
        idx.search(q, 5, use_kernel=False,
                   where=And(Eq("cat", "a"), Lt("price", 10.0)))
        warm = cache.stats.snapshot()
        assert warm.misses == 1 and warm.traces > 0
        constants = [("b", 25.0), ("c", 99.0), ("a", 42.5)]
        for cat, cutoff in constants:
            idx.search(q, 5, use_kernel=False,
                       where=And(Eq("cat", cat), Lt("price", cutoff)))
        d = cache.stats.since(warm)
        assert d.misses == 0 and d.traces == 0 and d.hits == len(constants)

    def test_different_structure_distinct_plans(self):
        rng = np.random.RandomState(52)
        idx = _meta_index(rng)
        q = _vecs(rng, 4)
        cache = engine.plan_cache()
        cache.clear()
        idx.search(q, 5, use_kernel=False, where=Eq("cat", "a"))
        idx.search(q, 5, use_kernel=False, where=Lt("price", 10.0))
        idx.search(q, 5, use_kernel=False)            # unfiltered: third plan
        assert cache.stats.misses == 3 and cache.stats.hits == 0

    @pytest.mark.parametrize("mutated", [False, True])
    def test_compiled_mask_equals_host_mask(self, mutated):
        """where= (compiled stage) vs where_mask= (host mask ANDed into
        live): same rows, same scores, to the bit."""
        rng = np.random.RandomState(53)
        idx = _meta_index(rng, mutated=mutated)
        q = _vecs(rng, 5)
        p = And(Eq("cat", "a"), Lt("price", 60.0))
        s1, i1 = idx.search(q, 6, use_kernel=False, where=p)
        mask = pred.evaluate(p, idx.meta)
        s2, i2 = engine.search_backend(idx.backend, idx.mut, q, 6,
                                       use_kernel=False, where_mask=mask)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(s1, s2)

    def test_filtered_prefix_identity(self):
        """Bucketing bit-identity holds under a predicate: smaller batches
        equal the full-bucket run's row prefix."""
        rng = np.random.RandomState(54)
        idx = _meta_index(rng)
        q = _vecs(rng, BUCKET)
        p = Eq("cat", "b")
        s_full, i_full = idx.search(q, 5, use_kernel=False, where=p)
        for b in (2, 5):
            s, i = idx.search(q[:b], 5, use_kernel=False, where=p)
            np.testing.assert_array_equal(i, i_full[:b])
            np.testing.assert_array_equal(s, s_full[:b])

    def test_filtered_searcher_zero_retrace_loop(self):
        """The serving shape: a bound filtered searcher across a measured
        loop reports zero retraces after warm-up."""
        rng = np.random.RandomState(55)
        idx = _meta_index(rng)
        search = idx.searcher(k=4, where=Lt("price", 50.0), use_kernel=False)
        search.warmup(4)
        cache = engine.plan_cache()
        before = cache.stats.snapshot()
        for _ in range(5):
            search(_vecs(rng, 4))
        d = cache.stats.since(before)
        assert d.traces == 0 and d.misses == 0 and d.hits == 5


class TestMicroBatcher:
    def _registry(self, rng, corpora):
        reg = TenantRegistry()
        for tok, x in corpora.items():
            reg.put(tok, "docs", MonaVec.build(x, metric="cosine"))
        return reg

    def test_coalesced_equals_direct(self):
        """Per-request results are bit-identical to solo searches, in
        submission order, while whole groups execute as single plans."""
        rng = np.random.RandomState(41)
        x = _vecs(rng, 60)
        reg = self._registry(rng, {"a": x})
        mb = engine.MicroBatcher(reg, use_kernel=False)
        requests = [_vecs(rng, m) for m in (3, 1, 5, 2)]
        tickets = [mb.submit("a", "docs", q, k=4) for q in requests]
        assert mb.pending == 4
        executions = mb.flush()
        assert executions == 1                      # one coalesced plan call
        direct = reg.get("a", "docs")
        for q, t in zip(requests, tickets):
            s_direct, i_direct = direct.search(q, 4, use_kernel=False)
            s_mb, i_mb = t.result()
            np.testing.assert_array_equal(i_mb, i_direct)
            np.testing.assert_array_equal(s_mb, s_direct)

    def test_namespace_isolation(self):
        """Interleaved submissions from two tenants never mix: each group
        executes against its own index and returns its own corpus' ids."""
        rng = np.random.RandomState(42)
        xa, xb = _vecs(rng, 40), _vecs(rng, 40)
        reg = self._registry(rng, {"a": xa, "b": xb})
        mb = engine.MicroBatcher(reg, use_kernel=False)
        qa, qb = xa[:3] + 0.01, xb[:3] + 0.01
        ta = mb.submit("a", "docs", qa, k=1)
        tb = mb.submit("b", "docs", qb, k=1)
        ta2 = mb.submit("a", "docs", qa, k=1)
        assert mb.flush() == 2                      # one execution per tenant
        np.testing.assert_array_equal(ta.result()[1][:, 0],
                                      np.arange(3, dtype=np.uint64))
        np.testing.assert_array_equal(tb.result()[1][:, 0],
                                      np.arange(3, dtype=np.uint64))
        np.testing.assert_array_equal(ta2.result()[1], ta.result()[1])
        # the two tenants' top-1 scores differ (different corpora served)
        assert not np.array_equal(ta.result()[0], tb.result()[0])

    def test_result_autoflushes(self):
        rng = np.random.RandomState(43)
        reg = self._registry(rng, {"a": _vecs(rng, 20)})
        mb = engine.MicroBatcher(reg, use_kernel=False)
        t = mb.submit("a", "docs", _vecs(rng, 2), k=3)
        assert not t.done()
        s, i = t.result()                           # triggers flush
        assert t.done() and i.shape == (2, 3)
        assert mb.pending == 0

    def test_rejected_token_raises_at_submit(self):
        reg = TenantRegistry(verifier=lambda tok: None)
        mb = engine.MicroBatcher(reg)
        with pytest.raises(PermissionError):
            mb.submit("bad-token", "docs", np.zeros((1, DIM), np.float32))

    def test_missing_collection_raises_at_submit(self):
        rng = np.random.RandomState(45)
        reg = self._registry(rng, {"a": _vecs(rng, 20)})
        mb = engine.MicroBatcher(reg)
        with pytest.raises(KeyError):
            mb.submit("a", "nope", _vecs(rng, 1))
        assert mb.pending == 0

    def test_group_failure_is_isolated(self):
        """A group that fails at execution (knobs its backend rejects)
        reports the error on ITS tickets; other tenants' requests in the
        same flush still succeed."""
        rng = np.random.RandomState(46)
        reg = self._registry(rng, {"a": _vecs(rng, 20), "b": _vecs(rng, 20)})
        mb = engine.MicroBatcher(reg, use_kernel=False)
        bad = mb.submit("a", "docs", _vecs(rng, 2), k=3, ef=9)  # BF rejects ef
        good = mb.submit("b", "docs", _vecs(rng, 2), k=3)
        mb.flush()
        assert good.result()[1].shape == (2, 3)
        with pytest.raises(TypeError):
            bad.result()

    def test_filtered_requests_coalesce_per_predicate(self):
        """Identical predicates share one group/execution; same-structure
        different-constant predicates form separate groups — and every
        request still equals its direct filtered search bit for bit."""
        rng = np.random.RandomState(47)
        idx = _meta_index(rng)
        reg = TenantRegistry()
        reg.put("a", "docs", idx)
        mb = engine.MicroBatcher(reg, use_kernel=False)
        p1 = And(Eq("cat", "a"), Lt("price", 50.0))
        p2 = And(Eq("cat", "b"), Lt("price", 80.0))
        q = _vecs(rng, 6)
        t1 = mb.submit("a", "docs", q[:2], k=4, where=p1)
        t2 = mb.submit("a", "docs", q[2:4], k=4, where=p1)   # same group
        t3 = mb.submit("a", "docs", q[4:6], k=4, where=p2)   # separate group
        assert mb.flush() == 2
        s_d, i_d = idx.search(q[:4], 4, use_kernel=False, where=p1)
        np.testing.assert_array_equal(t1.result()[1], i_d[:2])
        np.testing.assert_array_equal(t2.result()[1], i_d[2:])
        np.testing.assert_array_equal(t1.result()[0], s_d[:2])
        s3, i3 = idx.search(q[4:6], 4, use_kernel=False, where=p2)
        np.testing.assert_array_equal(t3.result()[1], i3)
        np.testing.assert_array_equal(t3.result()[0], s3)

    def test_hybrid_text_requests_coalesce(self):
        """text= routes the group through the hybrid path: coalesced
        execution, per-request rows identical to the direct batched call."""
        rng = np.random.RandomState(48)
        x = _vecs(rng, 50)
        docs = [f"doc {i} " + ("alpha" if i % 2 else "beta")
                for i in range(50)]
        hy = HybridIndex.build(x, docs, metric="cosine")
        reg = TenantRegistry()
        reg.put("a", "docs", hy)
        mb = engine.MicroBatcher(reg, use_kernel=False)
        q = _vecs(rng, 3)
        t1 = mb.submit("a", "docs", q[:2], k=4, text=["alpha", "beta"])
        t2 = mb.submit("a", "docs", q[2:3], k=4, text="alpha doc")
        assert mb.flush() == 1                       # one hybrid execution
        s_d, i_d = hy.search(q, ["alpha", "beta", "alpha doc"], 4)
        np.testing.assert_array_equal(t1.result()[1], i_d[:2])
        np.testing.assert_array_equal(t2.result()[1], i_d[2:])
        np.testing.assert_array_equal(t2.result()[0], s_d[2:])
        # hybrid and dense-only requests never share a group
        ta = mb.submit("a", "docs", q[:1], k=4, text="alpha")
        tb = mb.submit("a", "docs", q[:1], k=4)
        assert mb.flush() == 2
        ta.result()
        with pytest.raises(TypeError):
            tb.result()       # HybridIndex.search requires query_text

    def test_max_batch_splits_whole_requests(self):
        rng = np.random.RandomState(44)
        reg = self._registry(rng, {"a": _vecs(rng, 30)})
        mb = engine.MicroBatcher(reg, use_kernel=False, max_batch=4)
        tickets = [mb.submit("a", "docs", _vecs(rng, 3), k=2)
                   for _ in range(3)]
        # Whole-request packing at max_batch=4: 3-row requests never pair up.
        assert mb.flush() == 3
        for t in tickets:
            assert t.result()[1].shape == (3, 2)

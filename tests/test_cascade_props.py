"""Hypothesis property suite for the binarized cascade (DESIGN.md §11).

Two invariants that the deterministic suite (test_cascade.py) pins at fixed
points, generalized over generated inputs:

  * **Survivor admissibility** — ``survivor_topk_stage`` equals the
    brute-force numpy oracle EXACTLY on every generated (proxy, live, m):
    the admitted set is the stable top-m of the masked proxies (ties broken
    by lowest row), emitted ascending with -1 padding — i.e. survivors are
    always the canonical ranked prefix of the oracle ordering, never an
    arbitrary admissible set.  This is the contract that makes cascade
    results replayable: the rescore stage sees a deterministic candidate
    list, so the whole search is a pure function of (corpus, query, m).
  * **Replay determinism** — two builds from identical inputs produce
    cascade searches whose scores AND ids are byte-identical (``tobytes``
    equality, not allclose), across coarse kinds and budgets.

Ops are generated as integer seeds and materialized through RandomState so
shrinking stays cheap and every failing example replays exactly.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import MonaVec, binary  # noqa: E402
from tests.cascade_harness import survivor_oracle  # noqa: E402

COMMON = dict(deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])

VB = 64     # generated proxies live in [-VB, VB]


class TestSurvivorAdmissibility:
    @settings(max_examples=60, **COMMON)
    @given(seed=st.integers(0, 2**16), b=st.integers(1, 3),
           n=st.integers(1, 48), m=st.integers(1, 52),
           live_frac=st.floats(0.0, 1.0))
    def test_matches_oracle(self, seed, b, n, m, live_frac):
        rng = np.random.RandomState(seed)
        proxy = rng.randint(-VB, VB + 1, size=(b, n)).astype(np.int32)
        live = rng.rand(n) < live_frac
        got = np.asarray(binary.survivor_topk_stage(
            jnp.asarray(proxy), jnp.asarray(live), m=m, vbound=VB))
        np.testing.assert_array_equal(got, survivor_oracle(proxy, live, m))

    @settings(max_examples=20, **COMMON)
    @given(seed=st.integers(0, 2**16), n=st.integers(2, 40),
           m=st.integers(1, 40))
    def test_heavy_ties_break_by_row_order(self, seed, n, m):
        """Proxies drawn from {−1, 0, 1}: nearly everything ties, so the
        whole answer is the tie rule — first rows in row order win."""
        rng = np.random.RandomState(seed)
        proxy = rng.randint(-1, 2, size=(2, n)).astype(np.int32)
        live = rng.rand(n) < 0.8
        got = np.asarray(binary.survivor_topk_stage(
            jnp.asarray(proxy), jnp.asarray(live), m=m, vbound=VB))
        np.testing.assert_array_equal(got, survivor_oracle(proxy, live, m))

    @settings(max_examples=10, **COMMON)
    @given(seed=st.integers(0, 2**16))
    def test_default_vbound_matches_explicit(self, seed):
        """vbound is a convergence-speed knob, never a semantics knob."""
        rng = np.random.RandomState(seed)
        proxy = rng.randint(-VB, VB + 1, size=(2, 30)).astype(np.int32)
        live = rng.rand(30) < 0.7
        a = np.asarray(binary.survivor_topk_stage(
            jnp.asarray(proxy), jnp.asarray(live), m=9, vbound=VB))
        b_ = np.asarray(binary.survivor_topk_stage(
            jnp.asarray(proxy), jnp.asarray(live), m=9))
        np.testing.assert_array_equal(a, b_)


class TestReplayDeterminism:
    @settings(max_examples=8, **COMMON)
    @given(seed=st.integers(0, 2**16),
           kind=st.sampled_from(["sign", "crumb"]),
           rm=st.sampled_from([2, 4]))
    def test_two_builds_byte_identical(self, seed, kind, rm):
        def run():
            rng = np.random.RandomState(seed)
            x = rng.randn(200, 16).astype(np.float32)
            idx = MonaVec.build(x, metric="cosine", coarse=kind)
            idx.delete([int(i) for i in rng.randint(0, 200, size=5)])
            q = rng.randn(3, 16).astype(np.float32)
            return idx.search(q, k=6, rescore_mult=rm)
        s1, i1 = run()
        s2, i2 = run()
        assert s1.tobytes() == s2.tobytes()
        assert i1.tobytes() == i2.tobytes()

"""Oracle-backed cascade test suite (DESIGN.md §11; paper §3.6's
memory-bandwidth cascade made testable).

Four layers of pinning, from bit-exact to statistical:

  1. **Mirror identity** — the Pallas coarse kernels (interpret mode) and
     their jnp mirrors produce the SAME int32 proxy for every metric x
     bit-width x coarse kind.  Integer proxies make this equality exact by
     construction; this is the dispatch contract every other test rides on.
  2. **Exactness pin** — at m = n the cascade IS the full scan: the
     survivor stage enumerates every live row in ascending order, the
     gathered rescore of that enumeration reproduces the packed full-scan
     scores, and the engine collapses ``rescore_mult * k >= n`` (and
     ``rescore_mult=0``) to the plain plan, bit for bit.
  3. **Recall floor** — at real budgets (m = 2k/4k/8k) the crumb cascade's
     top-k overlaps the full scan's top-k above a deterministic floor, on
     static, mutated, and sharded lifecycles (fixed seeds end to end, so
     the floors are replayable numbers, not flaky statistics).
  4. **Edge contract** — fewer live rows than k sentinel-pads exactly like
     the full scan; the ``rescore_mult`` knob is rejected with a precise
     error on backends/indexes that cannot honor it.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MonaVec, SENTINEL_ID
from repro.core import binary
from repro.core import quantize as qz
from repro.core.allowlist import NEG
from repro.data import synthetic as syn
from repro.kernels import ops

K = 10


def _corpus(n, dim, seed=41):
    return syn.embedding_corpus(seed, n, dim)


def _queries(corpus, b, seed=141):
    return np.asarray(syn.queries_from_corpus(corpus, seed, b))


def _recall(got_ids, want_ids):
    """Mean per-row overlap |got ∩ want| / k (the bench's recall@10)."""
    return float(np.mean([
        len(set(g.tolist()) & set(w.tolist())) / len(w)
        for g, w in zip(got_ids, want_ids)]))


# ---------------------------------------------------------------------------
# 1. Kernel / jnp mirror bit-identity
# ---------------------------------------------------------------------------

class TestCoarseMirrorBitIdentity:
    """The integer proxy is identical between the Pallas kernel body
    (interpret mode — the exact arithmetic Mosaic compiles) and the jnp
    mirror, across every metric x bit-layout x coarse kind the engine can
    build.  Equality is ==, not allclose: the proxies are int32."""

    BITS_CFG = [("4bit", {"bits": 4}), ("2bit", {"bits": 2}),
                ("mixed", {"avg_bits": 3.0})]

    @pytest.mark.parametrize("metric", ["cosine", "l2", "dot"])
    @pytest.mark.parametrize("bname,bkw",
                             BITS_CFG, ids=[c[0] for c in BITS_CFG])
    @pytest.mark.parametrize("kind", ["sign", "crumb"])
    def test_kernel_matches_jnp(self, metric, bname, bkw, kind):
        x = _corpus(96, 32, seed=7)
        idx = MonaVec.build(x, metric=metric, coarse=kind, **bkw)
        enc = idx.backend.enc
        q_rot = qz.encode_query(jnp.asarray(_queries(x, 5, seed=9)), enc)
        ref = binary.coarse_scan_stage(q_rot, enc.ccodes, kind=kind,
                                       use_kernel=False)
        ker = binary.coarse_scan_stage(q_rot, enc.ccodes, kind=kind,
                                       use_kernel=True, interpret=True)
        assert ref.dtype == jnp.int32 and ker.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))

    @pytest.mark.parametrize("kind", ["sign", "crumb"])
    def test_odd_shapes_pad_identically(self, kind):
        """Row/batch padding in the dispatch wrapper must never leak into
        the visible [b, n] proxy (257 rows, 3 queries — nothing divides the
        kernel tiles)."""
        x = _corpus(257, 16, seed=11)
        idx = MonaVec.build(x, metric="cosine", coarse=kind)
        enc = idx.backend.enc
        q_rot = qz.encode_query(jnp.asarray(_queries(x, 3, seed=13)), enc)
        ref = binary.coarse_scan_stage(q_rot, enc.ccodes, kind=kind,
                                       use_kernel=False)
        ker = binary.coarse_scan_stage(q_rot, enc.ccodes, kind=kind,
                                       use_kernel=True, interpret=True)
        assert ref.shape == (3, 257)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


# ---------------------------------------------------------------------------
# 2. m = n exactness pin
# ---------------------------------------------------------------------------

class TestExactnessPin:
    """m = n removes the cascade's only approximation (the survivor cut),
    so every remaining stage must reproduce the full scan exactly."""

    def test_stage_cascade_at_m_equals_n_is_full_scan(self):
        """Survivors at m = n enumerate every live row ascending (then -1),
        and the gathered rescore of that enumeration reproduces the packed
        full-scan scores on the live columns (gathered-scan tiling reduces
        in a different order than the full scan, so scores match to the
        harness's ulp tolerance — the id enumeration is exact)."""
        x = _corpus(200, 32)
        idx = MonaVec.build(x, metric="cosine", coarse="crumb")
        idx.delete([3, 17, 99])
        enc = idx.backend.enc
        live = np.asarray(~idx.mut.base_tombs)
        q_rot = qz.encode_query(jnp.asarray(_queries(x, 4)), enc)

        proxy = binary.coarse_scan_stage(q_rot, enc.ccodes, kind="crumb",
                                         use_kernel=False)
        cand = binary.survivor_topk_stage(proxy, jnp.asarray(live), m=200,
                                          vbound=9 * enc.dim_pad)
        want_rows = np.where(live)[0]
        got = np.asarray(cand)
        for row in got:
            np.testing.assert_array_equal(row[:want_rows.size], want_rows)
            assert np.all(row[want_rows.size:] == -1)

        rescored = np.asarray(binary.gathered_rescore_stage(
            q_rot, enc.packed, enc.qnorms, cand, bits=enc.bits,
            n4_dims=enc.n4_dims, metric="cosine", use_kernel=False))
        full = np.asarray(ops.score_packed(q_rot, enc, use_kernel=False))
        np.testing.assert_allclose(rescored[:, :want_rows.size],
                                   full[:, want_rows], rtol=2e-5, atol=2e-6)
        assert np.all(rescored[:, want_rows.size:] <= NEG)

    def test_rescore_mult_collapse_equals_plain_search(self):
        """rescore_mult * k >= n normalizes to the PLAIN plan — same
        fingerprint, same scores, same ids, no coarse pass at all."""
        x = _corpus(300, 32)
        idx = MonaVec.build(x, metric="cosine", coarse="sign")
        q = _queries(x, 6)
        s0, i0 = idx.search(q, k=K)
        s1, i1 = idx.search(q, k=K, rescore_mult=10_000)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(s0, s1)

    def test_rescore_mult_zero_is_plain_search(self):
        x = _corpus(300, 32)
        idx = MonaVec.build(x, metric="l2", coarse="crumb")
        q = _queries(x, 4)
        s0, i0 = idx.search(q, k=K)
        s1, i1 = idx.search(q, k=K, rescore_mult=0)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(s0, s1)


class TestSurvivorOracle:
    """Deterministic twin of the hypothesis suite (test_cascade_props):
    ``survivor_topk_stage`` equals the stable-top-m numpy oracle EXACTLY on
    a seeded grid that forces the hard regimes — heavy ties, sparse live
    masks, m > n, all-dead rows — so the survivor contract is exercised
    even where hypothesis is unavailable (same split as lifecycle_harness)."""

    VB = 64

    def _check(self, proxy, live, m, vbound=None):
        from tests.cascade_harness import survivor_oracle
        got = np.asarray(binary.survivor_topk_stage(
            jnp.asarray(proxy), jnp.asarray(live), m=m, vbound=vbound))
        np.testing.assert_array_equal(got, survivor_oracle(proxy, live, m))

    @pytest.mark.parametrize("seed", range(8))
    def test_seeded_grid(self, seed):
        rng = np.random.RandomState(seed)
        n = int(rng.randint(1, 48))
        m = int(rng.randint(1, n + 5))
        proxy = rng.randint(-self.VB, self.VB + 1,
                            size=(3, n)).astype(np.int32)
        live = rng.rand(n) < rng.rand()
        self._check(proxy, live, m, vbound=self.VB)
        self._check(proxy, live, m)                  # default VBOUND_MAX

    def test_heavy_ties_and_all_dead(self):
        rng = np.random.RandomState(99)
        proxy = rng.randint(-1, 2, size=(2, 30)).astype(np.int32)
        self._check(proxy, rng.rand(30) < 0.8, 12, vbound=self.VB)
        self._check(proxy, np.zeros(30, bool), 12, vbound=self.VB)
        self._check(proxy, np.ones(30, bool), 30, vbound=self.VB)   # m = n


# ---------------------------------------------------------------------------
# 3. Recall floors vs the full-scan oracle
# ---------------------------------------------------------------------------

class TestCascadeRecall:
    """Crumb cascade vs the full 4-bit scan's own top-k (the quantity the
    acceptance bound pins: the cascade can only lose rows the coarse proxy
    misranks).  All inputs are seed-fixed, so the floors below are
    deterministic replays with margin, not statistical hopes.  Floors rise
    with the budget because survivors at m2 > m1 are a SUPERSET of the
    survivors at m1 (top-m by proxy is monotone in m)."""

    FLOORS = {2: 0.55, 4: 0.70, 8: 0.80}

    def _assert_recall(self, idx, q, rm, floor):
        ids_full = idx.search(q, k=K)[1]
        ids_casc = idx.search(q, k=K, rescore_mult=rm)[1]
        rec = _recall(ids_casc, ids_full)
        assert rec >= floor, (rm, rec, floor)
        return rec

    @pytest.mark.parametrize("rm", sorted(FLOORS))
    def test_static(self, rm):
        x = _corpus(4000, 64)
        idx = MonaVec.build(x, metric="cosine", coarse="crumb")
        self._assert_recall(idx, _queries(x, 8), rm, self.FLOORS[rm])

    @pytest.mark.parametrize("rm", sorted(FLOORS))
    def test_mutated(self, rm):
        """add() segments derive their own codes; delete() tombstones must
        never surface through the survivor cut."""
        x = _corpus(3000, 64)
        idx = MonaVec.build(x, metric="cosine", coarse="crumb")
        idx.add(_corpus(600, 64, seed=43))
        idx.delete(list(range(0, 3000, 7)) + list(range(3000, 3060)))
        q = _queries(x, 8)
        self._assert_recall(idx, q, rm, self.FLOORS[rm])
        ids = idx.search(q, k=K, rescore_mult=rm)[1]
        dead = set(range(0, 3000, 7)) | set(range(3000, 3060))
        assert not (set(ids.ravel().tolist()) - {int(SENTINEL_ID)}) & dead

    @pytest.mark.parametrize("rm", sorted(FLOORS))
    def test_sharded(self, rm):
        """The shard_map cascade (local coarse -> local survivors -> local
        rescore -> exact cross-shard merge) meets the same floors."""
        from repro.dist.sharded_index import ShardedMonaVec
        x = _corpus(4000, 64)
        idx = MonaVec.build(x, metric="cosine", coarse="crumb")
        sharded = ShardedMonaVec.shard(idx)
        q = _queries(x, 8)
        ids_full = idx.search(q, k=K)[1]
        ids_casc = sharded.search(q, k=K, rescore_mult=rm)[1]
        rec = _recall(ids_casc, ids_full)
        assert rec >= self.FLOORS[rm], (rm, rec)

    def test_budget_monotonicity(self):
        """Bigger budget, never-worse overlap with the full scan — the
        survivor-superset property made visible end to end."""
        x = _corpus(4000, 64)
        idx = MonaVec.build(x, metric="cosine", coarse="crumb")
        q = _queries(x, 8)
        recs = [self._assert_recall(idx, q, rm, 0.0) for rm in (2, 4, 8)]
        assert recs == sorted(recs), recs


# ---------------------------------------------------------------------------
# 4. Edge contracts: sentinel padding + knob validation
# ---------------------------------------------------------------------------

class TestSentinelPadding:
    def test_fewer_live_rows_than_k(self):
        """5 live rows, k = 10, cascade budget m = 20 < n: every live row
        survives the cut, so the result equals the full scan exactly —
        5 real ids then SENTINEL_ID / NEG padding, exactly k columns (ids
        exact; scores to the gathered-scan ulp tolerance)."""
        x = _corpus(60, 32)
        idx = MonaVec.build(x, metric="cosine", coarse="crumb")
        idx.delete(list(range(55)))
        q = _queries(x, 3)
        s, ids = idx.search(q, k=K, rescore_mult=2)
        assert ids.shape == (3, K) and s.shape == (3, K)
        for row_s, row_i in zip(s, ids):
            real = row_i[row_i != SENTINEL_ID]
            assert sorted(real.tolist()) == [55, 56, 57, 58, 59]
            assert np.all(row_i[5:] == SENTINEL_ID)
            assert np.all(row_s[5:] <= NEG)
        s0, i0 = idx.search(q, k=K)
        np.testing.assert_array_equal(ids, i0)
        np.testing.assert_allclose(s, s0, rtol=2e-5, atol=2e-6)

    def test_exactly_k_real_results_at_tight_budget(self):
        """With n live >> k the cascade must return k REAL ids (the
        survivor stage always yields m >= k live candidates)."""
        x = _corpus(500, 32)
        idx = MonaVec.build(x, metric="cosine", coarse="sign")
        s, ids = idx.search(_queries(x, 4), k=K, rescore_mult=2)
        assert not np.any(ids == SENTINEL_ID)
        assert np.all(s > NEG)


class TestKnobValidation:
    def test_rejected_on_ivf(self):
        x = _corpus(64, 16)
        idx = MonaVec.build(x, metric="cosine", index="ivf", nlist=4,
                            train_iters=3)
        with pytest.raises(TypeError, match="unexpected search kwargs"):
            idx.search(_queries(x, 2), k=5, rescore_mult=2)

    def test_rejected_on_hnsw(self):
        x = _corpus(64, 16)
        idx = MonaVec.build(x, metric="cosine", index="hnsw", m=4,
                            ef_construction=16)
        with pytest.raises(TypeError, match="unexpected search kwargs"):
            idx.search(_queries(x, 2), k=5, rescore_mult=2)

    def test_requires_coarse_codes(self):
        x = _corpus(64, 16)
        idx = MonaVec.build(x, metric="cosine")          # no coarse=
        with pytest.raises(ValueError, match="binarized coarse code"):
            idx.search(_queries(x, 2), k=5, rescore_mult=2)

    def test_negative_rejected(self):
        x = _corpus(64, 16)
        idx = MonaVec.build(x, metric="cosine", coarse="sign")
        with pytest.raises(ValueError, match="rescore_mult must be >= 0"):
            idx.search(_queries(x, 2), k=5, rescore_mult=-1)

    def test_unknown_coarse_kind_rejected_at_build(self):
        with pytest.raises(ValueError, match="unknown coarse kind"):
            MonaVec.build(_corpus(32, 16), metric="cosine", coarse="trit")

    def test_coarse_requires_bruteforce(self):
        with pytest.raises(ValueError, match="requires the bruteforce"):
            MonaVec.build(_corpus(64, 16), metric="cosine", index="ivf",
                          nlist=4, train_iters=3, coarse="sign")

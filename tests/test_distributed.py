"""Distribution + fault-tolerance behaviour on the local (CPU) mesh."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import quantize as qz
from repro.core.scoring import score_f32, topk
from repro.data import synthetic as syn
from repro.dist.retrieval import (make_scan_topk_f32_shardmap,
                                  make_scan_topk_shardmap, scan_topk_f32,
                                  scan_topk_pjit)
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import SimulatedFailure, train
from repro.train.optimizer import (AdamWConfig, adamw_update, compress_int8,
                                   init_opt_state)


def local_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestDistributedRetrieval:
    def test_shardmap_matches_pjit_scan(self, rng):
        corpus = syn.embedding_corpus(0, 1024, 128)
        enc = qz.encode(jnp.asarray(corpus), metric="cosine", seed=3)
        q = qz.encode_query(jnp.asarray(corpus[:4] + 0.05), enc)
        mesh = local_mesh()
        with mesh:
            v1, i1 = scan_topk_pjit(q, enc.packed, enc.qnorms,
                                    metric="cosine", k=10)
            fn = make_scan_topk_shardmap(mesh, metric="cosine", k=10)
            v2, i2 = fn(q, enc.packed, enc.qnorms)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)

    def test_shardmap_f32_matches_direct(self, rng):
        cand = rng.randn(512, 64).astype(np.float32)
        user = rng.randn(3, 64).astype(np.float32)
        mesh = local_mesh()
        with mesh:
            v1, i1 = scan_topk_f32(jnp.asarray(user), jnp.asarray(cand), k=5)
            fn = make_scan_topk_f32_shardmap(mesh, k=5)
            v2, i2 = fn(jnp.asarray(user), jnp.asarray(cand))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_quantized_scan_recall_vs_exact(self):
        corpus = syn.embedding_corpus(1, 2048, 256)
        queries = syn.queries_from_corpus(corpus, 2, 16)
        enc = qz.encode(jnp.asarray(corpus), metric="cosine", seed=3)
        q = qz.encode_query(jnp.asarray(queries), enc)
        v, i = scan_topk_pjit(q, enc.packed, enc.qnorms, metric="cosine", k=10)
        _, gt = topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                               "cosine"), 10)
        rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                       for a, b in zip(np.asarray(i), np.asarray(gt))])
        assert rec > 0.85


class TestGradientCompression:
    def test_int8_ef_roundtrip_bounded_error(self, rng):
        g = jnp.asarray(rng.randn(128, 64).astype(np.float32))
        ef = jnp.zeros_like(g)
        deq, new_ef = compress_int8(g, ef)
        assert float(jnp.max(jnp.abs(deq - g))) <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6
        # error feedback carries the residual
        np.testing.assert_allclose(np.asarray(deq + new_ef), np.asarray(g),
                                   rtol=1e-5, atol=1e-6)

    def test_ef_accumulates_over_steps(self, rng):
        """With error feedback, the SUM of compressed grads tracks the sum of
        true grads (the property that preserves convergence)."""
        true = [jnp.asarray(rng.randn(32).astype(np.float32) * 0.01)
                for _ in range(50)]
        ef = jnp.zeros(32)
        sent = []
        for g in true:
            d, ef = compress_int8(g, ef)
            sent.append(d)
        total_err = np.abs(np.asarray(sum(sent) - sum(true)))
        assert total_err.max() < 0.01 * 50 / 127 + 1e-4

    def test_training_with_compression_converges(self, rng):
        w_true = rng.randn(8).astype(np.float32)
        x = rng.randn(256, 8).astype(np.float32)
        y = x @ w_true
        params = {"w": jnp.zeros(8)}
        cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, compress_grads=True)
        state = init_opt_state(params, cfg)
        for _ in range(150):
            grads = jax.grad(lambda p: jnp.mean((x @ p["w"] - y) ** 2))(params)
            params, state, _ = adamw_update(grads, state, params, cfg)
        assert float(jnp.max(jnp.abs(params["w"] - w_true))) < 0.05


class TestCheckpointRestart:
    def _mk(self, tmp, steps, fail_at=None):
        from repro.models import transformer as tf
        import repro.configs as C
        cfg = C.get("qwen1.5-0.5b").make_smoke()
        ckpt = CheckpointManager(tmp, keep=2)
        return train(
            loss_fn=lambda p, b: tf.lm_loss(p, cfg, b["tokens"]),
            init_params_fn=lambda: tf.init_params(cfg, jax.random.key(0)),
            batch_fn=lambda s: {"tokens": jnp.asarray(
                syn.lm_batch(0, s, 2, 16, cfg.vocab)["tokens"])},
            n_steps=steps, opt_cfg=AdamWConfig(lr=1e-3),
            ckpt=ckpt, ckpt_every=4, simulate_failure_at=fail_at,
        )

    def test_crash_restore_bitwise_identical(self):
        with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
            ref = self._mk(d1, 12)                       # uninterrupted run
            with pytest.raises(SimulatedFailure):
                self._mk(d2, 12, fail_at=9)              # crash at step 9
            resumed = self._mk(d2, 12)                   # restart, same dir
            assert resumed.start_step == 8               # newest complete ckpt
            # losses after resume match the uninterrupted run exactly
            np.testing.assert_allclose(resumed.losses, ref.losses[8:],
                                       rtol=1e-6)
            for a, b in zip(jax.tree.leaves(ref.params),
                            jax.tree.leaves(resumed.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k_gc(self):
        with tempfile.TemporaryDirectory() as d:
            self._mk(d, 12)
            ckpt = CheckpointManager(d, keep=2)
            assert len(ckpt.all_steps()) <= 2

    def test_restore_onto_different_sharding(self):
        """Elastic restart: leaves saved unsharded restore onto any mesh."""
        with tempfile.TemporaryDirectory() as d:
            tree = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones(4)}
            ckpt = CheckpointManager(d)
            ckpt.save(1, tree)
            mesh = local_mesh()
            sh = {"w": NamedSharding(mesh, P("data", None)),
                  "b": NamedSharding(mesh, P())}
            restored, manifest = ckpt.restore(tree, shardings=sh)
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))
            assert restored["w"].sharding == sh["w"]
            assert manifest["step"] == 1

    def test_tmp_dir_never_restored(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt = CheckpointManager(d)
            ckpt.save(5, {"x": jnp.ones(3)})
            os.makedirs(os.path.join(d, "step_00000009.tmp"))   # crashed write
            assert ckpt.latest_step() == 5


class TestOptimizer:
    def test_adamw_matches_reference_impl(self, rng):
        """Against a hand-rolled numpy AdamW for one step."""
        p = {"w": jnp.asarray(rng.randn(5).astype(np.float32))}
        g = {"w": jnp.asarray(rng.randn(5).astype(np.float32) * 0.1)}
        cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
                          clip_norm=1e9)
        state = init_opt_state(p, cfg)
        new_p, state, _ = adamw_update(g, state, p, cfg)
        m = 0.1 * np.asarray(g["w"])
        v = 0.05 * np.asarray(g["w"]) ** 2
        mhat, vhat = m / 0.1, v / 0.05
        expect = (np.asarray(p["w"]) - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8)
                                               + 0.01 * np.asarray(p["w"])))
        np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)

    def test_clip_norm(self, rng):
        p = {"w": jnp.zeros(4)}
        g = {"w": jnp.asarray(np.full(4, 100.0, np.float32))}
        cfg = AdamWConfig(clip_norm=1.0)
        state = init_opt_state(p, cfg)
        _, _, gnorm = adamw_update(g, state, p, cfg)
        assert float(gnorm) == pytest.approx(200.0)

    def test_moment_dtype_bf16(self):
        p = {"w": jnp.ones(4)}
        cfg = AdamWConfig(moment_dtype="bfloat16")
        state = init_opt_state(p, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16

"""Unit tests for the dry-run tooling: HLO collective parser + roofline math.

(The actual 512-device compiles run via `python -m repro.launch.dryrun`; here
we test the analysis layer on synthetic inputs.)
"""


import pytest


def _parse(hlo, default_group=256):
    # import from the module without triggering its XLA_FLAGS side effect
    import importlib.util
    from pathlib import Path
    spec = importlib.util.find_spec("repro.launch.dryrun")
    src = Path(spec.origin).read_text()
    ns = {}
    # execute only the parser part (skip the env mutation + jax import)
    marker = 'import argparse'
    body = src[src.index(marker):src.index("def run_cell")]
    exec("import re\n" + body, ns)
    return ns["parse_collectives"](hlo, default_group)


HLO = """
ENTRY %main {
  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[256,512]{1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %cp = f32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce(%a, %b), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}
"""


class TestCollectiveParser:
    def test_kinds_and_bytes(self):
        stats, top = _parse(HLO)
        assert stats["all-reduce"]["count"] == 2
        # 16*1024*4 = 65536 and tuple 2*8*8*4 = 512
        assert stats["all-reduce"]["result_bytes"] == 65536 + 512
        assert stats["all-gather"]["result_bytes"] == 256 * 512 * 2
        assert stats["reduce-scatter"]["result_bytes"] == 64 * 4
        assert stats["collective-permute"]["result_bytes"] == 128 * 4

    def test_wire_models(self):
        stats, _ = _parse(HLO)
        # all-reduce ring: 2*(g-1)/g * bytes, g=4 -> 1.5x
        assert stats["all-reduce"]["wire_bytes"] == pytest.approx(
            2 * 65536 * 3 / 4 + 2 * 512 * 7 / 8)
        # all-gather: (g-1)/g * result, g=16 from [16,16] grouping
        assert stats["all-gather"]["wire_bytes"] == pytest.approx(
            256 * 512 * 2 * 15 / 16)
        # reduce-scatter: (g-1) * result
        assert stats["reduce-scatter"]["wire_bytes"] == pytest.approx(64 * 4 * 1)

    def test_group_size_from_replica_groups(self):
        _, top = _parse(HLO)
        groups = {t["kind"]: t["group"] for t in top}
        assert groups["all-gather"] == 16
        assert groups["reduce-scatter"] == 2


class TestRooflineMath:
    def test_terms_and_bottleneck(self):
        from benchmarks.roofline import terms
        rec = {"hlo_flops": 197e12, "hlo_bytes": 0.0,
               "collective_wire_bytes": 0.0, "model_flops": 197e12 * 256,
               "n_devices": 256}
        t = terms(rec)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["bottleneck"] == "compute"
        assert t["roofline_fraction"] == pytest.approx(1.0)
        assert t["useful_ratio"] == pytest.approx(1.0)

    def test_collective_bound(self):
        from benchmarks.roofline import terms
        rec = {"hlo_flops": 1e12, "hlo_bytes": 0.0,
               "collective_wire_bytes": 50e9 * 10, "model_flops": 0.0,
               "n_devices": 256}
        t = terms(rec)
        assert t["bottleneck"] == "collective"
        assert t["roofline_fraction"] < 0.01

    def test_extrapolation_linear(self):
        from benchmarks.roofline import _extrapolate
        scan = {"ok": True, "hlo_flops": 0.0, "hlo_bytes": 0.0,
                "collective_wire_bytes": 0.0, "variant": "scan"}
        pa = {"hlo_flops": 10.0, "hlo_bytes": 100.0, "collective_wire_bytes": 5.0}
        pb = {"hlo_flops": 18.0, "hlo_bytes": 180.0, "collective_wire_bytes": 9.0}
        rec = _extrapolate(scan, pa, pb, 5, 9, 61)
        # slope 2/layer from L=5 -> 10 + 2*56 = 122
        assert rec["hlo_flops"] == pytest.approx(122.0)
        assert rec["hlo_bytes"] == pytest.approx(100 + 20 * 56)
        assert rec["collective_wire_bytes"] == pytest.approx(5 + 1 * 56)
        assert rec["variant"] == "baseline"

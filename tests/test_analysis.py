"""repro.analysis: the auditor must (a) stay silent on the real tree,
(b) scream on injected hazards, and (c) hold its allowlist to the
no-rot contract.

The load-bearing cases:
  * mutation self-test — a deliberately hazardous stage (closure-captured
    corpus + unbarriered full-scan dot) run through the REAL CLI must exit
    non-zero and name BOTH findings;
  * clean-grid — real engine stages captured through the plan observer
    produce zero findings (including the rotate stage, pinned rng-free
    after the rademacher_signs staging fix);
  * per-check units — each jaxpr check and each AST lint rule, positive
    and negative;
  * allowlist — reasons are mandatory, stale entries fail strict mode
    (which is what makes CI's tamper test work).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.analysis
from repro.analysis import (Allowlist, Finding, StageCapture, audit_captures,
                            fingerprint, invariant_for_check, load_allowlist,
                            render_report)
from repro.analysis import grid as agrid
from repro.analysis import jaxpr_audit as ja
from repro.analysis import lint as alint
from repro.analysis.audit import (DEFAULT_ALLOWLIST, inject_hazard_capture,
                                  retrace_findings)

SRC_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(repro.analysis.__file__))))


def _audit_fn(fn, *args, n_corpus=0, backend="Unit", stage="stage"):
    cap = StageCapture(backend=backend, stage=stage, fn=fn, args=args,
                      context={"n_corpus": n_corpus})
    return audit_captures([cap])


def _checks(findings):
    return sorted({f.check for f in findings})


# ---------------------------------------------------------------------------
# Findings / fingerprints / allowlist.
# ---------------------------------------------------------------------------

class TestFindings:
    def test_fingerprint_is_stable_and_structural(self):
        fp = fingerprint("const-array", "X/scan", ("const-array", "f32"))
        assert fp == fingerprint("const-array", "X/scan",
                                 ["const-array", "f32"])
        assert len(fp) == 16
        assert fp != fingerprint("const-array", "Y/scan",
                                 ("const-array", "f32"))

    def test_finding_cites_its_invariant(self):
        inv = invariant_for_check("const-array")
        assert inv is not None and inv.id == "INV-ARGS-NOT-CONSTS"
        assert "§" in inv.design_ref
        # every registered check maps to exactly one invariant
        seen = {}
        from repro.analysis.invariants import INVARIANTS
        for i in INVARIANTS:
            for c in i.checks:
                assert c not in seen, f"check {c} claimed by two invariants"
                seen[c] = i.id

    def test_allowlist_reason_is_mandatory(self, tmp_path):
        p = tmp_path / "a.json"
        p.write_text(json.dumps({"entries": [{"fingerprint": "ab" * 8}]}))
        with pytest.raises(ValueError, match="reason"):
            load_allowlist(str(p))

    def test_stale_entry_fails_strict_report(self):
        allow = Allowlist(entries={"f" * 16: "bogus tamper entry"})
        report = render_report([], allow, stale_is_error=True)
        assert not report["ok"]
        assert report["stale_allowlist_entries"] == ["f" * 16]
        # lint mode tolerates (the jaxpr side owns those entries)
        assert render_report([], allow, stale_is_error=False)["ok"]

    def test_matched_entry_passes(self):
        f = Finding(check="c", site="s", detail="d", signature=("c", "x"))
        allow = Allowlist(entries={f.fingerprint(): "accepted"})
        report = render_report([f], allow)
        assert report["ok"]
        assert report["counts"] == {"active": 0, "allowlisted": 1,
                                    "stale_allowlist": 0}

    def test_committed_allowlist_loads(self):
        allow = load_allowlist(DEFAULT_ALLOWLIST)
        assert all(allow.entries.values()), "every entry carries a reason"


# ---------------------------------------------------------------------------
# Const classification policy.
# ---------------------------------------------------------------------------

class TestConstPolicy:
    @pytest.mark.parametrize("value", [
        np.float32(3.0),                               # scalar
        np.zeros(5, np.float32),                       # tiny
        np.full((64,), 7.0, np.float32),               # uniform fill
        np.arange(100, dtype=np.int32),                # iota
        np.arange(5, 105, dtype=np.int32),             # shifted iota
        np.random.RandomState(0).randint(0, 9, 100),   # small int table
        np.sign(np.random.RandomState(0).randn(256)).astype(np.float32),
        np.linspace(-2, 2, 16).astype(np.float32),     # Lloyd-Max size
    ])
    def test_exempt(self, value):
        assert ja._classify_const(value) is None

    @pytest.mark.parametrize("value,cls", [
        (np.random.RandomState(0).randn(64, 16).astype(np.float32),
         "float-array[float32]"),
        (np.random.RandomState(0).randn(17).astype(np.float32),
         "float-array[float32]"),
        (np.random.RandomState(0).randint(0, 9, 2048).astype(np.int32),
         "int-array[int32]"),
    ])
    def test_flagged(self, value, cls):
        assert ja._classify_const(value) == cls


# ---------------------------------------------------------------------------
# Jaxpr checks, one by one.
# ---------------------------------------------------------------------------

class TestJaxprChecks:
    def test_injected_hazard_raises_both(self):
        findings = audit_captures([inject_hazard_capture()])
        assert _checks(findings) == ["const-array", "full-scan-dot"]
        for f in findings:
            assert f.invariant in ("INV-ARGS-NOT-CONSTS", "INV-CHUNKED-DOT")

    def test_full_scan_dot_as_argument_still_flagged(self):
        # passing the corpus as an argument fixes const-array but NOT the
        # unchunked reduction — the checks are independent
        def fn(q, corpus):
            return q @ corpus.T
        q = jnp.zeros((12, 16), jnp.float32)
        c = jnp.zeros((64, 16), jnp.float32)
        assert _checks(_audit_fn(fn, q, c, n_corpus=64)) == ["full-scan-dot"]

    def test_chunked_barrier_dot_is_clean(self):
        from repro.kernels import ref

        def fn(q, corpus_t):
            return ref._chunked_dot(q, corpus_t)
        q = jnp.zeros((12, 16), jnp.float32)
        ct = jnp.zeros((16, 64), jnp.float32)
        assert _audit_fn(fn, q, ct, n_corpus=64) == []

    def test_small_dot_not_corpus_scale(self):
        # nlist-sized centroid dots are legitimate
        def fn(q, cents):
            return q @ cents.T
        q = jnp.zeros((12, 16), jnp.float32)
        cents = jnp.zeros((8, 16), jnp.float32)
        assert _audit_fn(fn, q, cents, n_corpus=64) == []

    def test_gathered_batched_dot_is_clean(self):
        # per-query candidate scoring (batch dims) is tiling-stable by the
        # gathered-scan contract, not a full-corpus scan
        def fn(deq, q):
            return jnp.einsum("bmd,bd->bm", deq, q)
        deq = jnp.zeros((3, 70, 16), jnp.float32)
        q = jnp.zeros((3, 16), jnp.float32)
        assert _audit_fn(fn, deq, q, n_corpus=64) == []

    def test_full_reduce_flagged(self):
        def fn(scores):
            return jnp.sum(scores, axis=-1)
        s = jnp.zeros((3, 128), jnp.float32)
        assert _checks(_audit_fn(fn, s, n_corpus=64)) == ["full-reduce"]

    def test_x64_leak(self):
        jax.config.update("jax_enable_x64", True)
        try:
            def fn(x):
                return x.astype(jnp.float64) * 2.0
            x = jnp.zeros((4,), jnp.float32)
            findings = _audit_fn(fn, x, n_corpus=0)
        finally:
            jax.config.update("jax_enable_x64", False)
        assert "x64-leak" in _checks(findings)

    def test_rng_prims_staged_by_jitted_samplers(self):
        # the rademacher_signs failure mode, reproduced: jax.random samplers
        # are internally jitted, so under an outer trace they STAGE instead
        # of resolving eagerly
        def fn(x):
            key = jax.random.key(1)
            return x * jax.random.rademacher(key, (x.shape[-1],),
                                             dtype=jnp.float32)
        x = jnp.zeros((3, 16), jnp.float32)
        assert "rng-prim" in _checks(_audit_fn(fn, x))

    def test_rotate_stage_regression_rng_free(self):
        # rademacher_signs resolves at trace time (ensure_compile_time_eval):
        # the compiled rotate stage must contain no PRNG primitives and no
        # non-exempt consts — its sign vector folds to a ±1 constant
        from repro.engine.plan import _rotate

        def fn(q):
            return _rotate(q, metric="cosine", std=None,
                           seed=0x6D6F6E61, perm=None)
        q = jnp.zeros((3, 16), jnp.float32)
        assert _audit_fn(fn, q, n_corpus=48) == []

    def test_callback_prim(self):
        def fn(x):
            jax.debug.callback(lambda v: None, x)
            return x * 2
        x = jnp.zeros((4,), jnp.float32)
        assert "callback-prim" in _checks(_audit_fn(fn, x))

    def test_retrace_failure_is_a_finding(self):
        def broken():
            raise RuntimeError("boom")
        cap = StageCapture(backend="Unit", stage="s", fn=broken, args=())
        findings = audit_captures([cap])
        assert _checks(findings) == ["tracer-leak"]


# ---------------------------------------------------------------------------
# Grid capture + coverage.
# ---------------------------------------------------------------------------

class TestGrid:
    def test_clean_points_zero_findings(self):
        # one point per backend family keeps this tier-1-sized; the full
        # grid runs in the CI analysis job
        points = [
            agrid.GridPoint(label="t/bf", index="bruteforce"),
            agrid.GridPoint(label="t/ivf", index="ivf", metric="l2",
                            bits=2),
        ]
        caps = agrid.collect_captures(points)
        assert caps, "observer captured nothing — plan hook is broken"
        assert audit_captures(caps) == []

    def test_hnsw_and_hybrid_stages_const_clean(self):
        # regression pin (satellite): the HNSW beam stage and the hybrid
        # dense-plan stages keep every array an ARGUMENT
        points = [
            agrid.GridPoint(label="t/hnsw", index="hnsw"),
            agrid.GridPoint(label="hybrid/t", hybrid=True, where=True),
        ]
        caps = agrid.collect_captures(points)
        assert any(c.backend == "HnswIndex" and c.stage == "main"
                   for c in caps)
        assert any(str(label).startswith("hybrid")
                   for c in caps for label in c.context.get("labels", ()))
        findings = audit_captures(caps)
        assert [f for f in findings if f.check == "const-array"] == []
        assert findings == []

    def test_coverage_findings_on_empty_capture_set(self):
        findings = agrid.coverage_findings([])
        sites = {f.site for f in findings}
        assert "repro.core.hnsw:search_stage" in sites
        assert "repro.engine.fusion:search_hybrid" in sites
        assert all(f.check == "uncovered-stage" for f in findings)

    def test_observer_restored_after_collect(self):
        from repro.engine import plan as plan_mod
        agrid.collect_captures([agrid.GridPoint(label="t/restore")])
        assert plan_mod._STAGE_OBSERVER is None

    def test_retrace_pass_clean(self):
        assert retrace_findings() == []


# ---------------------------------------------------------------------------
# AST lint rules.
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, rel, src):
    path = tmp_path / os.path.basename(rel)
    path.write_text(textwrap.dedent(src))
    return alint.lint_file(str(path), rel)


class TestLint:
    def test_unseeded_random_flagged_seeded_allowed(self, tmp_path):
        src = """
            import random
            import numpy as np

            def build(seed):
                rng = np.random.RandomState(seed)      # idiom: allowed
                gen = np.random.default_rng(seed)      # allowed
                a = np.random.randn(4)                 # global RNG: flagged
                b = random.random()                    # stdlib: flagged
                return rng, gen, a, b
        """
        findings = _lint_src(tmp_path, "core/thing.py", src)
        assert _checks(findings) == ["unseeded-random"]
        assert len(findings) == 2

    def test_host_time_flagged_in_core_not_launch(self, tmp_path):
        src = """
            import time

            def f():
                return time.perf_counter()
        """
        assert _checks(_lint_src(tmp_path, "core/thing.py", src)) \
            == ["host-time"]
        assert _lint_src(tmp_path, "launch/serve.py", src) == []

    def test_injected_clock_reference_allowed(self, tmp_path):
        src = """
            import time
            import dataclasses

            @dataclasses.dataclass
            class Limiter:
                clock = time.monotonic
        """
        assert _lint_src(tmp_path, "core/tenancy.py", src) == []

    def test_frombuffer_only_inside_reader(self, tmp_path):
        src = """
            import numpy as np

            class _Reader:
                def take(self, b):
                    return np.frombuffer(b, dtype=np.uint8)

            def rogue(b):
                return np.frombuffer(b, dtype=np.uint8)
        """
        findings = _lint_src(tmp_path, os.path.join("core", "mvec_format.py"),
                             src)
        assert len(findings) == 1
        assert findings[0].site.endswith(":rogue")
        # any frombuffer outside that module is flagged, class or not
        assert _checks(_lint_src(tmp_path, "core/other.py", src)) \
            == ["frombuffer-outside-reader"] and len(
                _lint_src(tmp_path, "core/other.py", src)) == 2

    def test_obs_in_jit_via_decorator_and_by_name(self, tmp_path):
        src = """
            import jax
            from repro import obs

            @jax.jit
            def decorated(x):
                obs.inc("n")
                return x

            def wrapper(x):
                obs.inc("m")
                return x
            jitted = jax.jit(wrapper)

            def host_path(x):
                obs.inc("fine")          # not jitted: allowed
                return x
        """
        findings = _lint_src(tmp_path, "engine/thing.py", src)
        assert _checks(findings) == ["obs-in-jit"]
        assert {f.site.split(":")[1] for f in findings} \
            == {"decorated", "wrapper"}

    def test_stage_asarray_of_captured_name(self, tmp_path):
        src = """
            import jax
            import jax.numpy as jnp

            corpus = None

            @jax.jit
            def bad(q):
                return q @ jnp.asarray(corpus).T    # captured: flagged

            @jax.jit
            def good(q, c):
                local = jnp.asarray(c)              # argument: allowed
                other = jnp.asarray(local)          # local: allowed
                return q @ other.T
        """
        findings = _lint_src(tmp_path, "engine/thing.py", src)
        assert _checks(findings) == ["stage-asarray"]
        assert len(findings) == 1 and "corpus" in findings[0].detail

    def test_repo_tree_lint_matches_allowlist_exactly(self):
        findings = alint.lint_tree()
        allow = load_allowlist(DEFAULT_ALLOWLIST)
        active = [f for f in findings if not allow.match(f)]
        assert active == [], \
            "new lint findings: fix them or allowlist with a reason"

    def test_lint_fingerprints_do_not_move_with_lines(self, tmp_path):
        src = "import time\n\ndef f():\n    return time.time()\n"
        shifted = "import time\n\n\n# comment\n\ndef f():\n    return time.time()\n"
        (tmp_path / "a.py").write_text(src)
        (tmp_path / "b.py").write_text(shifted)
        fa = alint.lint_file(str(tmp_path / "a.py"), "core/x.py")
        fb = alint.lint_file(str(tmp_path / "b.py"), "core/x.py")
        assert [f.fingerprint() for f in fa] == [f.fingerprint() for f in fb]


# ---------------------------------------------------------------------------
# The CLI gate (mutation self-test, through the real entry point).
# ---------------------------------------------------------------------------

class TestCLI:
    def _run(self, *argv):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis.audit", *argv],
            capture_output=True, text=True, env=env, timeout=300)

    def test_inject_hazard_exits_nonzero_naming_both(self, tmp_path):
        report_path = tmp_path / "AUDIT_REPORT.json"
        proc = self._run("--inject-hazard", "--quiet",
                         "--report", str(report_path))
        assert proc.returncode != 0, proc.stdout + proc.stderr
        assert "const-array" in proc.stdout
        assert "full-scan-dot" in proc.stdout
        report = json.loads(report_path.read_text())
        assert not report["ok"]
        assert {f["check"] for f in report["findings"]} \
            == {"const-array", "full-scan-dot"}
        assert all(f["invariant"] for f in report["findings"])

    def test_lint_cli_passes_on_tree(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint"],
            capture_output=True, text=True, env=env, timeout=120)
        assert proc.returncode == 0, proc.stdout + proc.stderr

"""Property test: randomly parameterized CLEAN plans produce zero findings.

The deterministic suite (tests/test_analysis.py) checks hand-picked grid
points; here hypothesis draws index/metric/bits/lifecycle combinations the
hand-picked grid may never have tried and asserts the auditor stays silent
on all of them — the auditor's false-positive rate on legitimately-built
engine stages is pinned at zero, not just at the points we thought of.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.analysis import audit_captures
from repro.analysis import grid as agrid

POINTS = st.builds(
    agrid.GridPoint,
    label=st.just("prop"),
    index=st.sampled_from(["bruteforce", "ivf", "hnsw"]),
    metric=st.sampled_from(["cosine", "l2", "dot"]),
    bits=st.sampled_from([4, 2]),
    lifecycle=st.sampled_from(["static", "mutated"]),
    where=st.booleans(),
)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(point=POINTS)
def test_random_clean_plan_has_zero_findings(point):
    point = agrid.GridPoint(
        label=f"prop/{point.index}/{point.metric}/b{point.bits}/"
              f"{point.lifecycle}{'+where' if point.where else ''}",
        index=point.index, metric=point.metric, bits=point.bits,
        lifecycle=point.lifecycle, where=point.where)
    caps = agrid.collect_captures([point])
    assert caps, "plan observer captured nothing"
    findings = audit_captures(caps)
    assert findings == [], [f.to_dict() for f in findings]

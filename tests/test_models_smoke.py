"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
asserting output shapes + no NaNs (assignment requirement f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data import synthetic as syn
from repro.models import gnn as gnn_m
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig, init_opt_state, make_train_step

LM_ARCHS = ["gemma2-2b", "qwen1.5-0.5b", "llama3.2-3b", "deepseek-v3-671b",
            "olmoe-1b-7b"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
class TestLMArchSmoke:
    def _setup(self, arch_id):
        cfg = C.get(arch_id).make_smoke()
        params = tf.init_params(cfg, jax.random.key(0))
        batch = syn.lm_batch(0, 0, 2, 16, cfg.vocab)
        return cfg, params, jnp.asarray(batch["tokens"])

    def test_forward_shapes_no_nans(self, arch_id):
        cfg, params, toks = self._setup(arch_id)
        logits, h, aux, _ = tf.forward(params, cfg, toks)
        assert logits.shape == (2, 16, cfg.vocab)
        assert h.shape == (2, 16, cfg.d_model)
        assert not bool(jnp.isnan(logits).any())

    def test_train_step_reduces_loss(self, arch_id):
        cfg, params, toks = self._setup(arch_id)
        ocfg = AdamWConfig(lr=2e-3)
        step = jax.jit(make_train_step(
            lambda p, b: tf.lm_loss(p, cfg, b), ocfg))
        opt = init_opt_state(params, ocfg)
        losses = []
        for i in range(8):
            params, opt, m = step(params, opt, toks)
            losses.append(float(m["loss"]))
            assert np.isfinite(losses[-1])
        assert losses[-1] < losses[0]

    def test_decode_matches_forward(self, arch_id):
        cfg, params, toks = self._setup(arch_id)
        if cfg.moe:  # avoid capacity-drop mismatch in the parity check
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
        cache = tf.init_decode_cache(cfg, 2, 16)
        for t in range(10):
            lg, cache = tf.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
        fl, _, _, _ = tf.forward(params, cfg, toks[:, :10])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(fl[:, -1]),
                                   rtol=2e-2, atol=2e-4)

    def test_quantized_kv_decode_close(self, arch_id):
        cfg, params, toks = self._setup(arch_id)
        if cfg.mla:
            pytest.skip("MLA keeps the (already 10x-compressed) latent cache")
        cache_f = tf.init_decode_cache(cfg, 2, 16)
        cache_q = tf.init_decode_cache(cfg, 2, 16, quantized=True)
        for t in range(10):
            lf, cache_f = tf.decode_step(params, cfg, cache_f, toks[:, t:t + 1],
                                         jnp.int32(t))
            lq, cache_q = tf.decode_step(params, cfg, cache_q, toks[:, t:t + 1],
                                         jnp.int32(t), quantized=True)
        # 4-bit KV: same argmax most of the time, bounded logit error.
        agree = (np.argmax(np.asarray(lf), -1) == np.argmax(np.asarray(lq), -1)).mean()
        assert agree >= 0.5
        assert float(jnp.max(jnp.abs(lq - lf))) < 2.0

    def test_scan_unroll_equivalence(self, arch_id):
        cfg, params, toks = self._setup(arch_id)
        l1 = tf.lm_loss(params, cfg, toks)
        l2 = tf.lm_loss(params, dataclasses.replace(cfg, unroll=True), toks)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


class TestGINSmoke:
    def test_full_graph(self):
        cfg = C.get("gin-tu").make_smoke()
        params = gnn_m.init_params(cfg, jax.random.key(0))
        g = syn.random_graph(0, 200, 800, cfg.d_feat, cfg.n_classes)
        logits = gnn_m.forward_full(params, cfg, jnp.asarray(g["x"]),
                                    jnp.asarray(g["src"]), jnp.asarray(g["dst"]))
        assert logits.shape == (200, cfg.n_classes)
        assert not bool(jnp.isnan(logits).any())

    def test_training_learns_communities(self):
        cfg = C.get("gin-tu").make_smoke()
        params = gnn_m.init_params(cfg, jax.random.key(0))
        g = syn.random_graph(1, 300, 2400, cfg.d_feat, cfg.n_classes)
        batch = {k: jnp.asarray(v) for k, v in g.items()}
        ocfg = AdamWConfig(lr=5e-3)

        def loss_fn(p, b):
            logits = gnn_m.forward_full(p, cfg, b["x"], b["src"], b["dst"])
            return gnn_m.nll_loss(logits, b["labels"])

        step = jax.jit(make_train_step(loss_fn, ocfg))
        opt = init_opt_state(params, ocfg)
        losses = []
        for _ in range(25):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.5 * losses[0]

    def test_neighbor_sampler_and_sampled_forward(self):
        cfg = dataclasses.replace(C.get("gin-tu").make_smoke(), n_layers=2)
        params = gnn_m.init_params(cfg, jax.random.key(0))
        g = syn.random_graph(2, 500, 4000, cfg.d_feat, cfg.n_classes)
        # CSR
        order = np.argsort(g["src"], kind="stable")
        indices = g["dst"][order]
        counts = np.bincount(g["src"], minlength=500)
        indptr = np.concatenate([[0], np.cumsum(counts)])
        seeds = np.arange(32)
        frontier, blocks = syn.neighbor_sample(0, 0, indptr, indices, seeds, (5, 3))
        feats = jnp.asarray(g["x"][frontier])
        blocks = [(jnp.asarray(s), jnp.asarray(d), n) for s, d, n in blocks]
        out = gnn_m.forward_sampled(params, cfg, feats, blocks)
        assert out.shape == (32, cfg.n_classes)
        assert not bool(jnp.isnan(out).any())
        # determinism of the sampler
        f2, _ = syn.neighbor_sample(0, 0, indptr, indices, seeds, (5, 3))
        np.testing.assert_array_equal(frontier, f2)

    def test_molecule_graph_classification(self):
        cfg = dataclasses.replace(C.get("gin-tu").make_smoke(), readout="graph")
        params = gnn_m.init_params(cfg, jax.random.key(0))
        gmol = syn.random_graph(3, 30 * 8, 64 * 8, cfg.d_feat, cfg.n_classes)
        graph_ids = jnp.repeat(jnp.arange(8), 30)
        logits = gnn_m.forward_full(params, cfg, jnp.asarray(gmol["x"]),
                                    jnp.asarray(gmol["src"]) % 240,
                                    jnp.asarray(gmol["dst"]) % 240,
                                    graph_ids=graph_ids, n_graphs=8)
        assert logits.shape == (8, cfg.n_classes)


RS_ARCHS = ["dlrm-rm2", "dien", "fm", "two-tower-retrieval"]


@pytest.mark.parametrize("arch_id", RS_ARCHS)
class TestRecsysSmoke:
    def test_train_step(self, arch_id):
        from repro.dist.steps import _RS_INIT, _RS_LOSS
        cfg = C.get(arch_id).make_smoke()
        params = _RS_INIT[arch_id](cfg, jax.random.key(0))
        ocfg = AdamWConfig(lr=1e-3)
        loss = _RS_LOSS[arch_id]
        step = jax.jit(make_train_step(lambda p, b: loss(p, cfg, b), ocfg))
        opt = init_opt_state(params, ocfg)
        losses = []
        for i in range(20):
            # two-tower has in-batch labels; others carry learnable labels.
            batch = {k: jnp.asarray(v) for k, v in
                     syn.recsys_batch(0, i % 4, arch_id, cfg, 64).items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
            assert np.isfinite(losses[-1])
        assert np.mean(losses[-4:]) < np.mean(losses[:4])


class TestTwoTowerRetrieval:
    def test_packed_scan_matches_f32_topk(self, rng):
        """retrieval_cand: the MonaVec path approximates exact scoring."""
        from repro.core import quantize as qz
        from repro.core.scoring import score_f32, topk
        from repro.kernels import ops
        cfg = C.get("two-tower-retrieval").make_smoke()
        params = rs.two_tower_init(cfg, jax.random.key(0))
        cand = rs.item_embedding(params, cfg, jnp.arange(400))
        user = rs.user_embedding(params, cfg,
                                 jnp.asarray(rng.randint(0, cfg.user_vocab, (3, 4))))
        enc = qz.encode(cand, metric="cosine", seed=7)
        qr = qz.encode_query(user, enc)
        s_packed = ops.score_packed(qr, enc, use_kernel=True, interpret=True)
        _, top_packed = topk(s_packed, 10)
        _, top_exact = topk(score_f32(user, cand, "cosine"), 10)
        overlap = np.mean([len(set(a.tolist()) & set(b.tolist())) / 10
                           for a, b in zip(np.asarray(top_packed), np.asarray(top_exact))])
        assert overlap > 0.7

    def test_dien_scan_unroll_parity(self, rng):
        cfg = C.get("dien").make_smoke()
        params = rs.dien_init(cfg, jax.random.key(0))
        batch = {k: jnp.asarray(v) for k, v in
                 syn.recsys_batch(0, 0, "dien", cfg, 8).items()}
        a = rs.dien_forward(params, cfg, batch, unroll=False)
        b = rs.dien_forward(params, cfg, batch, unroll=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)

"""repro.obs: the observability contract (DESIGN.md §9).

The two load-bearing guarantees, both asserted here:

  1. *Bit-identity* — a metrics-enabled or actively-traced search returns
     bytes identical to a disabled one (host-side timers wrap compiled
     calls, they never enter a traced function).
  2. *Deterministic snapshot shape* — metric names, label sets, and
     histogram bucket edges are fixed; the edge ladders are pinned as
     golden tuples, so changing them is a visible schema change.

Plus the registry semantics everything else leans on: counter/gauge/
histogram behavior, label isolation, kind/edge conflicts, Prometheus
rendering, trace-span nesting, PlanCache eviction accounting, and the
shared DeltaStats mixin.
"""

import dataclasses
import json
import logging

import numpy as np
import pytest

from repro import engine, obs
from repro.core import MonaVec, TenantRegistry
from repro.engine.plan import PlanCache, PlanKey, SearchPlan, plan_key_digest
from repro.obs.registry import MetricsRegistry


def _index(n=64, dim=16, seed=0):
    rng = np.random.RandomState(seed)
    return MonaVec.build(rng.randn(n, dim).astype(np.float32), metric="cosine")


# ---------------------------------------------------------------------------
# Golden edge ladders: part of the committed snapshot schema.
# ---------------------------------------------------------------------------

class TestGoldenEdges:
    def test_latency_edges_pinned(self):
        assert obs.DEFAULT_LATENCY_EDGES_US == (
            1, 2.5, 5, 10, 25, 50, 100, 250, 500,
            1_000, 2_500, 5_000, 10_000, 25_000, 50_000,
            100_000, 250_000, 500_000,
            1_000_000, 2_500_000, 5_000_000, 10_000_000,
        )

    def test_count_edges_pinned(self):
        assert obs.DEFAULT_COUNT_EDGES == (
            1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

    def test_edges_travel_with_snapshot(self):
        reg = MetricsRegistry()
        reg.histogram("lat").observe(3.0)
        snap = reg.snapshot()
        assert snap["histograms"]["lat"]["edges"] == \
            list(obs.DEFAULT_LATENCY_EDGES_US)


# ---------------------------------------------------------------------------
# Registry semantics.
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_and_label_isolation(self):
        reg = MetricsRegistry()
        reg.counter("req").inc()
        reg.counter("req", ns="a").inc(2)
        reg.counter("req", ns="b").inc(5)
        snap = reg.snapshot()["counters"]
        assert snap == {"req": 1, 'req{ns="a"}': 2, 'req{ns="b"}': 5}

    def test_label_order_is_canonical(self):
        reg = MetricsRegistry()
        reg.counter("m", b="2", a="1").inc()
        reg.counter("m", a="1", b="2").inc()   # same series, any kwarg order
        assert reg.snapshot()["counters"] == {'m{a="1",b="2"}': 2}

    def test_gauge_overwrites(self):
        reg = MetricsRegistry()
        reg.gauge("depth").set(3)
        reg.gauge("depth").set(7)
        assert reg.snapshot()["gauges"] == {"depth": 7.0}

    def test_histogram_bucketing_is_le(self):
        """counts[i] tallies v <= edges[i]: an observation ON an edge lands
        in that edge's bucket (bisect_left), above the last edge overflows."""
        reg = MetricsRegistry()
        h = reg.histogram("h", edges=(1, 10, 100))
        for v in (0.5, 1.0, 1.5, 10.0, 99.0, 1e9):
            h.observe(v)
        assert h.counts == [2, 2, 1, 1]
        assert h.count == 6
        assert h.min == 0.5 and h.max == 1e9
        assert h.total == pytest.approx(0.5 + 1.0 + 1.5 + 10.0 + 99.0 + 1e9)

    def test_histogram_quantile(self):
        reg = MetricsRegistry()
        h = reg.histogram("h", edges=(1, 10, 100))
        for v in [0.5] * 50 + [50.0] * 49 + [1e9]:
            h.observe(v)
        assert h.quantile(0.5) == 1       # upper edge of the median's bucket
        assert h.quantile(0.99) == 100
        assert h.quantile(1.0) == 1e9     # +Inf bucket reports observed max

    def test_unsorted_edges_rejected(self):
        with pytest.raises(ValueError, match="sorted"):
            MetricsRegistry().histogram("h", edges=(10, 1))

    def test_kind_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("m")
        with pytest.raises(ValueError, match="already registered as a"):
            reg.gauge("m")
        with pytest.raises(ValueError, match="already registered as a"):
            reg.histogram("m")

    def test_edge_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.histogram("h", edges=(1, 2))
        reg.histogram("h", edges=(1, 2))   # same edges: fine
        with pytest.raises(ValueError, match="already registered with edges"):
            reg.histogram("h", edges=(1, 3))

    def test_empty_histogram_snapshot(self):
        reg = MetricsRegistry()
        reg.histogram("h", edges=(1,))
        snap = reg.snapshot()["histograms"]["h"]
        assert snap["count"] == 0
        assert snap["min"] is None and snap["max"] is None

    def test_snapshot_json_roundtrips(self):
        reg = MetricsRegistry()
        reg.counter("c", x="1").inc()
        reg.histogram("h", edges=(1, 2)).observe(1.5)
        assert json.loads(reg.snapshot_json()) == reg.snapshot()

    def test_reset(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.reset()
        assert reg.snapshot() == {"counters": {}, "gauges": {},
                                  "histograms": {}}


class TestPrometheus:
    def test_exposition_format(self):
        reg = MetricsRegistry()
        reg.counter("plan_cache.hits").inc(3)
        reg.gauge("queue.depth", ns="a").set(2)
        h = reg.histogram("stage.us", edges=(1, 2.5), stage="scan")
        h.observe(0.5)
        h.observe(2.0)
        h.observe(99.0)
        text = reg.to_prometheus()
        lines = text.splitlines()
        assert "# TYPE plan_cache_hits counter" in lines
        assert "plan_cache_hits 3" in lines
        assert 'queue_depth{ns="a"} 2' in lines
        # Cumulative buckets, +Inf last, then _sum/_count.
        assert 'stage_us_bucket{stage="scan",le="1"} 1' in lines
        assert 'stage_us_bucket{stage="scan",le="2.5"} 2' in lines
        assert 'stage_us_bucket{stage="scan",le="+Inf"} 3' in lines
        assert 'stage_us_count{stage="scan"} 3' in lines
        assert text.endswith("\n")


class TestSnapshotArithmetic:
    def test_counter_deltas_and_family_total(self):
        reg = MetricsRegistry()
        reg.counter("req", ns="a").inc(2)
        before = reg.snapshot()
        reg.counter("req", ns="a").inc(3)
        reg.counter("req", ns="b").inc(1)   # new key counts from zero
        delta = obs.counter_deltas(reg.snapshot(), before)
        assert delta == {'req{ns="a"}': 3, 'req{ns="b"}': 1}
        assert obs.counter_total(delta, "req") == 4
        assert obs.counter_total(delta, "re") == 0   # no prefix false-match

    def test_render_key(self):
        assert obs.render_key("m", ()) == "m"
        assert obs.render_key("m", (("a", "1"), ("b", "2"))) == \
            'm{a="1",b="2"}'


class TestEnableToggle:
    def test_disabled_helpers_are_noops(self):
        before = obs.registry().snapshot()
        prev = obs.enable(False)
        try:
            obs.inc("test_obs.should_not_exist")
            obs.observe("test_obs.should_not_exist_h", 1.0)
            with obs.timed_span("t", histogram="test_obs.should_not_exist_h2"):
                pass
            snap = obs.registry().snapshot()
            assert "test_obs.should_not_exist" not in snap["counters"]
            assert "test_obs.should_not_exist_h" not in snap["histograms"]
            assert "test_obs.should_not_exist_h2" not in snap["histograms"]
            assert obs.counter_deltas(snap, before) == \
                {k: 0 for k in before["counters"]}
        finally:
            obs.enable(prev)


# ---------------------------------------------------------------------------
# Tracing.
# ---------------------------------------------------------------------------

class TestTrace:
    def test_span_nesting(self):
        with obs.trace("query", batch=4) as tr:
            with obs.span("outer"):
                with obs.span("inner", stage="scan"):
                    pass
            with obs.span("sibling"):
                pass
        d = tr.to_dict()
        assert d["name"] == "query" and d["attrs"] == {"batch": 4}
        assert [c["name"] for c in d["children"]] == ["outer", "sibling"]
        assert d["children"][0]["children"][0]["name"] == "inner"
        assert d["children"][0]["children"][0]["attrs"] == {"stage": "scan"}
        # finish() closed everything.
        assert all(c["duration_us"] is not None for c in d["children"])

    def test_trace_restores_outer_trace(self):
        assert obs.current_trace() is None
        with obs.trace("a") as ta:
            assert obs.current_trace() is ta
            with obs.trace("b") as tb:
                assert obs.current_trace() is tb
            assert obs.current_trace() is ta
        assert obs.current_trace() is None

    def test_exception_marks_span_and_unwinds(self):
        with obs.trace("q") as tr:
            with pytest.raises(RuntimeError):
                with obs.span("boom"):
                    raise RuntimeError("x")
            sp = obs.span("after")
            with sp:
                pass
        d = tr.to_dict()
        assert [c["name"] for c in d["children"]] == ["boom", "after"]
        assert d["children"][0]["attrs"]["error"] == "RuntimeError"
        assert d["children"][1]["children"] == []   # no nesting under boom

    def test_timed_span_is_free_when_idle(self):
        """No active trace + no histogram -> the shared null CM."""
        prev = obs.enable(False)
        try:
            cm = obs.timed_span("x", histogram="h")
        finally:
            obs.enable(prev)
        cm2 = obs.span("y") if obs.current_trace() is None else None
        assert cm is (cm2 if cm2 is not None else cm)
        with cm as sp:
            assert sp is None

    def test_timed_span_feeds_histogram(self):
        before = obs.registry().snapshot()
        with obs.timed_span("x", histogram="test_obs.span_us",
                            labels={"stage": "s"}):
            pass
        snap = obs.registry().snapshot()
        h = snap["histograms"]['test_obs.span_us{stage="s"}']
        base = before["histograms"].get(
            'test_obs.span_us{stage="s"}', {"count": 0})
        assert h["count"] == base["count"] + 1

    def test_render_lists_tree(self):
        with obs.trace("q") as tr:
            with obs.span("child", k=10):
                pass
        text = tr.render()
        assert text.splitlines()[0].startswith("q ")
        assert "  child" in text and "k=10" in text

    def test_tracer_samples_one_in_n(self):
        tr = obs.Tracer(sample_every=2)
        captured = []
        for i in range(5):
            with tr.maybe(f"call{i}") as t:
                if t is not None:
                    captured.append(i)
        assert captured == [0, 2, 4]
        names = [t.root.name for t in tr.drain()]
        assert names == ["call0", "call2", "call4"]
        assert tr.drain() == []

    def test_tracer_disabled_and_bounded(self):
        tr = obs.Tracer(sample_every=0)
        with tr.maybe("x") as t:
            assert t is None
        assert tr.drain() == []
        tr = obs.Tracer(sample_every=1, keep=2)
        for i in range(5):
            with tr.maybe(f"c{i}"):
                pass
        assert len(tr.drain()) == 2


# ---------------------------------------------------------------------------
# Bit-identity: instrumentation never changes results.
# ---------------------------------------------------------------------------

class TestBitIdentity:
    def test_enabled_disabled_and_traced_searches_identical(self):
        idx = _index(n=96, dim=24, seed=3)
        rng = np.random.RandomState(7)
        q = rng.randn(5, 24).astype(np.float32)

        vals_on, ids_on = idx.search(q, k=10)
        prev = obs.enable(False)
        try:
            vals_off, ids_off = idx.search(q, k=10)
        finally:
            obs.enable(prev)
        with obs.trace("bit-identity"):
            vals_tr, ids_tr = idx.search(q, k=10)

        assert np.asarray(vals_on).tobytes() == np.asarray(vals_off).tobytes()
        assert np.asarray(ids_on).tobytes() == np.asarray(ids_off).tobytes()
        assert np.asarray(vals_on).tobytes() == np.asarray(vals_tr).tobytes()
        assert np.asarray(ids_on).tobytes() == np.asarray(ids_tr).tobytes()

    def test_trace_captures_engine_stages(self):
        idx = _index(n=64, dim=16, seed=5)
        q = np.random.RandomState(1).randn(3, 16).astype(np.float32)
        idx.search(q, k=5)                      # warm the plan outside
        with obs.trace("q") as tr:
            idx.search(q, k=5)
        names = [c["name"] for c in tr.to_dict()["children"]]
        assert names[0] == "plan_lookup"
        assert "execute" in names and "sync" in names


# ---------------------------------------------------------------------------
# Per-namespace labels through TenantRegistry.
# ---------------------------------------------------------------------------

class TestNamespaceLabels:
    def test_label_isolation_across_namespaces(self):
        reg = TenantRegistry()
        reg.put("team-a", "docs", _index(seed=1))
        reg.put("team-b", "docs", _index(seed=2))
        sa = reg.searcher("team-a", "docs", k=5)
        sb = reg.searcher("team-b", "docs", k=5)
        q = np.random.RandomState(0).randn(2, 16).astype(np.float32)

        before = obs.registry().snapshot()
        sa(q)
        sa(q)
        sb(q)
        delta = obs.counter_deltas(obs.registry().snapshot(), before)
        key_a = 'tenancy.requests{collection="docs",namespace="team-a"}'
        key_b = 'tenancy.requests{collection="docs",namespace="team-b"}'
        assert delta[key_a] == 2
        assert delta[key_b] == 1
        hists = obs.registry().snapshot()["histograms"]
        ha = hists['tenancy.search_us{collection="docs",namespace="team-a"}']
        hb = hists['tenancy.search_us{collection="docs",namespace="team-b"}']
        assert ha["count"] >= 2 and hb["count"] >= 1

    def test_rejection_counts_error(self):
        reg = TenantRegistry()
        reg.put("team-a", "docs", _index(seed=1))
        before = obs.registry().snapshot()
        with pytest.raises(KeyError):
            reg.get("team-a", "nope")
        delta = obs.counter_deltas(obs.registry().snapshot(), before)
        assert obs.counter_total(delta, "tenancy.errors") == 1


# ---------------------------------------------------------------------------
# PlanCache eviction accounting (satellite).
# ---------------------------------------------------------------------------

def _dummy_key(i):
    return PlanKey(fingerprint=("test", i), bucket=8, k=10,
                   dispatch=(False, False), knobs=())


class TestPlanCacheEvictions:
    def test_eviction_counts_and_gauges(self, caplog):
        cache = PlanCache(maxsize=2)
        before = obs.registry().snapshot()
        with caplog.at_level(logging.DEBUG, logger="repro.engine.plan"):
            for i in range(3):
                cache.get_or_build(_dummy_key(i),
                                   lambda: SearchPlan(_dummy_key(i), None))
        assert cache.stats.evictions == 1
        assert cache.stats.misses == 3
        assert len(cache) == 2
        delta = obs.counter_deltas(obs.registry().snapshot(), before)
        assert delta["plan_cache.evictions"] == 1
        assert delta["plan_cache.misses"] == 3
        gauges = obs.registry().snapshot()["gauges"]
        assert gauges["plan_cache.size"] == 2.0
        assert gauges["plan_cache.capacity"] == 2.0
        # The DEBUG log names the evicted key by digest (key 0 was LRU).
        assert plan_key_digest(_dummy_key(0)) in caplog.text

    def test_lru_order_hit_refreshes(self):
        cache = PlanCache(maxsize=2)
        cache.get_or_build(_dummy_key(0), lambda: SearchPlan(_dummy_key(0), None))
        cache.get_or_build(_dummy_key(1), lambda: SearchPlan(_dummy_key(1), None))
        cache.get_or_build(_dummy_key(0), lambda: SearchPlan(_dummy_key(0), None))
        cache.get_or_build(_dummy_key(2), lambda: SearchPlan(_dummy_key(2), None))
        assert cache.stats.hits == 1 and cache.stats.evictions == 1
        # Key 1 (least recently used) was the one evicted.
        assert cache.get_or_build(
            _dummy_key(0), lambda: SearchPlan(_dummy_key(0), None)) is not None
        assert cache.stats.misses == 3   # key 0 still cached

    def test_plan_key_digest_stable(self):
        d = plan_key_digest(_dummy_key(0))
        assert len(d) == 12 and int(d, 16) >= 0
        assert d == plan_key_digest(_dummy_key(0))
        assert d != plan_key_digest(_dummy_key(1))


# ---------------------------------------------------------------------------
# DeltaStats mixin (satellite: shared by PlanStats and BatcherStats).
# ---------------------------------------------------------------------------

class TestDeltaStats:
    def test_generic_snapshot_since(self):
        @dataclasses.dataclass
        class S(obs.DeltaStats):
            a: int = 0
            b: int = 0

        s = S(a=5, b=2)
        before = s.snapshot()
        s.a += 3
        s.b += 1
        d = s.since(before)
        assert (d.a, d.b) == (3, 1)
        assert (before.a, before.b) == (5, 2)   # snapshot is a copy

    def test_type_mismatch_rejected(self):
        @dataclasses.dataclass
        class A(obs.DeltaStats):
            x: int = 0

        @dataclasses.dataclass
        class B(obs.DeltaStats):
            x: int = 0

        with pytest.raises(TypeError):
            A().since(B())

    def test_reexported_from_engine(self):
        assert engine.DeltaStats is obs.DeltaStats
        assert engine.PlanStats().since(engine.PlanStats()).hits == 0

"""TenantRegistry (paper §3.9): identity resolution, caching, degradation,
and per-namespace mutation isolation — previously entirely untested.

The verifier is an injected callable and the clock is an injected monotonic
source, so TTL expiry and outage handling run without sleeping.
"""

import numpy as np
import pytest

from repro.core import MonaVec, TenantRegistry
from repro.core.tenancy import PUBLIC_NAMESPACE


def _index(n=12, dim=8, seed=0):
    rng = np.random.RandomState(seed)
    return MonaVec.build(rng.randn(n, dim).astype(np.float32), metric="cosine")


class FakeClock:
    def __init__(self):
        self.t = 1000.0

    def __call__(self):
        return self.t


class CountingVerifier:
    """token -> user mapping with call counting and scriptable outages."""

    def __init__(self, table):
        self.table = table
        self.calls = 0
        self.down = False

    def __call__(self, token):
        self.calls += 1
        if self.down:
            raise ConnectionError("introspection endpoint unreachable")
        return self.table.get(token)


class TestIdentityResolution:
    def test_no_token_is_public(self):
        reg = TenantRegistry()
        assert reg.resolve_namespace(None) == PUBLIC_NAMESPACE
        assert reg.resolve_namespace("") == PUBLIC_NAMESPACE

    def test_standalone_token_is_namespace(self):
        """No verifier configured: the token IS the namespace key."""
        reg = TenantRegistry()
        assert reg.resolve_namespace("alice-key") == "alice-key"
        reg.put("alice-key", "c", _index())
        assert reg.collections("alice-key") == ["c"]
        assert reg.collections("bob-key") == []

    def test_verifier_maps_token_to_user(self):
        ver = CountingVerifier({"tok-a": "alice"})
        reg = TenantRegistry(verifier=ver)
        assert reg.resolve_namespace("tok-a") == "alice"
        assert reg.resolve_namespace("tok-bad") is None


class TestCacheAndDegradation:
    def test_cache_hit_within_ttl(self):
        clock = FakeClock()
        ver = CountingVerifier({"t": "u"})
        reg = TenantRegistry(verifier=ver, cache_ttl=30.0, _clock=clock)
        assert reg.resolve_namespace("t") == "u"
        clock.t += 29.0
        assert reg.resolve_namespace("t") == "u"
        assert ver.calls == 1                      # second hit served cached

    def test_ttl_expiry_revalidates(self):
        clock = FakeClock()
        ver = CountingVerifier({"t": "u"})
        reg = TenantRegistry(verifier=ver, cache_ttl=30.0, _clock=clock)
        reg.resolve_namespace("t")
        clock.t += 31.0
        ver.table["t"] = "u2"                      # rotation upstream
        assert reg.resolve_namespace("t") == "u2"
        assert ver.calls == 2

    def test_stale_cache_served_on_verifier_outage(self):
        clock = FakeClock()
        ver = CountingVerifier({"t": "u"})
        reg = TenantRegistry(verifier=ver, cache_ttl=30.0, _clock=clock)
        reg.resolve_namespace("t")
        clock.t += 100.0                           # entry is stale
        ver.down = True
        assert reg.resolve_namespace("t") == "u"   # graceful degradation

    def test_outage_with_cold_cache_rejects(self):
        ver = CountingVerifier({"t": "u"})
        ver.down = True
        reg = TenantRegistry(verifier=ver)
        assert reg.resolve_namespace("t") is None


class Test401Paths:
    def test_put_get_collections_reject_bad_token(self):
        ver = CountingVerifier({"good": "u"})
        reg = TenantRegistry(verifier=ver)
        with pytest.raises(PermissionError, match="401"):
            reg.put("bad", "c", _index())
        with pytest.raises(PermissionError, match="401"):
            reg.get("bad", "c")
        with pytest.raises(PermissionError, match="401"):
            reg.collections("bad")

    def test_mutation_endpoints_reject_bad_token(self):
        ver = CountingVerifier({"good": "u"})
        reg = TenantRegistry(verifier=ver)
        reg.put("good", "c", _index())
        with pytest.raises(PermissionError, match="401"):
            reg.add("bad", "c", np.zeros((1, 8), np.float32))
        with pytest.raises(PermissionError, match="401"):
            reg.delete("bad", "c", [1])
        with pytest.raises(PermissionError, match="401"):
            reg.compact("bad", "c")

    def test_missing_collection_names_namespace(self):
        reg = TenantRegistry()
        with pytest.raises(KeyError, match="not found in namespace"):
            reg.get("alice", "nope")


class TestNamespaceMutationIsolation:
    def test_add_delete_isolated_per_namespace(self):
        """Two tenants sharing a collection NAME mutate disjoint indexes."""
        reg = TenantRegistry()
        reg.put("alice", "corpus", _index(seed=1))
        reg.put("bob", "corpus", _index(seed=2))
        new_ids = reg.add("alice", "corpus",
                          np.random.RandomState(3).randn(4, 8).astype(np.float32))
        assert new_ids.tolist() == [12, 13, 14, 15]
        assert reg.delete("alice", "corpus", [0, 13]) == 2
        a = reg.get("alice", "corpus")
        b = reg.get("bob", "corpus")
        assert a.n_total == 16 and a.n_live == 14
        assert b.n_total == b.n_live == 12        # bob untouched
        q = np.random.RandomState(4).randn(2, 8).astype(np.float32)
        _, ids_b = b.search(q, 12, use_kernel=False)
        # bob's namespace still serves ALL 12 original rows (0 was deleted
        # only in alice's), and never alice's added ids
        assert set(ids_b[0].astype(np.int64).tolist()) == set(range(12))
        assert reg.compact("alice", "corpus") == 2
        assert reg.get("alice", "corpus").n_total == 14

    def test_same_token_same_namespace_shares_state(self):
        ver = CountingVerifier({"t1": "alice", "t2": "alice"})
        reg = TenantRegistry(verifier=ver)
        reg.put("t1", "c", _index())
        reg.add("t2", "c", np.random.RandomState(5).randn(2, 8).astype(np.float32))
        assert reg.get("t1", "c").n_total == 14

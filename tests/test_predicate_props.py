"""Hypothesis property suite for the predicate compiler (DESIGN.md §8).

Random predicate ASTs over random typed columns:
  * the compiled u64-key stage (``build_stage_fn`` + ``flatten_args``) must
    reproduce the host numpy oracle (``evaluate``) on every row — the core
    exactness contract of the metadata lowering (x64 is disabled in the
    trace, so only the key planes stand between us and silent truncation);
  * filtered BruteForce search must equal the mask-to-NEG oracle bit for
    bit, for any predicate, after any add/delete interleaving.

ASTs are generated as abstract tokens (op kinds + pool indices) and
materialized deterministically, so hypothesis shrinking stays cheap and
every example is replayable.  The deterministic twin (tests/
test_predicate.py) covers the same properties with pinned seeds where
hypothesis is unavailable.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import (And, Eq, Ge, Gt, In, Le, Lt, MonaVec, Ne, Not,  # noqa: E402
                        Or)
from repro.core import metadata as md  # noqa: E402
from repro.core import predicate as pred  # noqa: E402
from tests.lifecycle_harness import oracle_search  # noqa: E402

DIM = 8

I64_POOL = [np.iinfo(np.int64).min, np.iinfo(np.int64).max, -1, 0, 1,
            -7, 42, 1 << 62]
F64_POOL = [0.0, -0.0, 1.5, -2.25, 1e300, -1e300, 1e-300, float("inf"),
            float("-inf")]
STR_POOL = ["red", "green", "blue", "cyan", "missing", ""]

_cmp = st.tuples(st.sampled_from(["eq", "ne", "lt", "le", "gt", "ge"]),
                 st.sampled_from(["i", "f", "s"]),
                 st.integers(0, 8))
_in = st.tuples(st.just("in"), st.sampled_from(["i", "f", "s"]),
                st.lists(st.integers(0, 8), min_size=1, max_size=3))
leaf_tokens = st.one_of(_cmp, _in)
ast_tokens = st.recursive(
    leaf_tokens,
    lambda inner: st.one_of(
        st.tuples(st.just("and"), inner, inner),
        st.tuples(st.just("or"), inner, inner),
        st.tuples(st.just("not"), inner)),
    max_leaves=6)

_OPS = {"eq": Eq, "ne": Ne, "lt": Lt, "le": Le, "gt": Gt, "ge": Ge}


def _const(col: str, idx: int, store: md.MetaStore):
    if col == "i":
        pool = I64_POOL + [int(v) for v in store["i"].values[:4]]
        return int(pool[idx % len(pool)])
    if col == "f":
        pool = F64_POOL + [float(v) for v in store["f"].values[:4]]
        return float(pool[idx % len(pool)])
    return STR_POOL[idx % len(STR_POOL)]


def _materialize(tok, store: md.MetaStore) -> pred.Predicate:
    if tok[0] == "and":
        return And(_materialize(tok[1], store), _materialize(tok[2], store))
    if tok[0] == "or":
        return Or(_materialize(tok[1], store), _materialize(tok[2], store))
    if tok[0] == "not":
        return Not(_materialize(tok[1], store))
    if tok[0] == "in":
        _, col, idxs = tok
        return In(col, tuple(_const(col, i, store) for i in idxs))
    op, col, idx = tok
    if col == "s" and op in ("lt", "le", "gt", "ge"):
        op = "eq"                     # ordering on str is rejected by design
    return _OPS[op](col, _const(col, idx, store))


def _store(seed: int, n: int = 32) -> md.MetaStore:
    rng = np.random.RandomState(seed)
    i64 = rng.randint(-50, 50, n).astype(np.int64)
    i64[: min(4, n)] = I64_POOL[: min(4, n)]
    f64 = rng.randn(n) * 5.0
    f64[: min(4, n)] = F64_POOL[: min(4, n)]
    strs = np.array(STR_POOL[:4])[rng.randint(0, 4, n)]
    return md.MetaStore.build({"i": i64, "f": f64, "s": strs}, n)


COMMON = dict(deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])


class TestStageOracleAgreement:
    @settings(max_examples=60, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**16))
    def test_compiled_stage_equals_host_oracle(self, tok, seed):
        store = _store(seed)
        p = _materialize(tok, store)
        host = pred.evaluate(p, store)
        fn = pred.build_stage_fn(p)
        args = tuple(jnp.asarray(a) for a in pred.flatten_args(p, store))
        dev = np.asarray(fn(jnp.ones(store.n_rows, dtype=bool), *args))
        np.testing.assert_array_equal(dev, host, err_msg=str(tok))

    @settings(max_examples=20, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**16))
    def test_structure_is_constant_free(self, tok, seed):
        """Re-materializing the same token tree against a different store
        only shifts constants — the structure fingerprint must not move."""
        s1, s2 = _store(seed), _store(seed + 1)
        assert pred.structure(_materialize(tok, s1), s1) == \
            pred.structure(_materialize(tok, s2), s2)


class TestFilteredSearchProperty:
    @settings(max_examples=15, **COMMON)
    @given(tok=ast_tokens, seed=st.integers(0, 2**10),
           mutate=st.booleans())
    def test_bruteforce_filtered_equals_masked_oracle(self, tok, seed,
                                                      mutate):
        rng = np.random.RandomState(seed)
        n = 20
        idx = MonaVec.build(
            rng.randn(n, DIM).astype(np.float32), metric="cosine",
            meta={"i": _store(seed, n)["i"].values,
                  "f": _store(seed, n)["f"].values,
                  "s": _store(seed, n)["s"].decoded().astype(str)})
        if mutate:
            m = 5
            idx.add(rng.randn(m, DIM).astype(np.float32),
                    meta={"i": _store(seed + 2, m)["i"].values,
                          "f": _store(seed + 2, m)["f"].values,
                          "s": _store(seed + 2, m)["s"].decoded().astype(str)})
            idx.delete(idx.ids[::6])
        p = _materialize(tok, idx.meta)
        q = rng.randn(2, DIM).astype(np.float32)
        got_s, got_i = idx.search(q, 6, use_kernel=False, where=p)
        mask = pred.evaluate(p, idx.meta)
        want_s, want_i = oracle_search(idx, q, 6, allow_mask=mask)
        np.testing.assert_array_equal(got_i, want_i, err_msg=str(tok))
        np.testing.assert_array_equal(got_s, want_s)

"""Quickstart: the SQLite deployment model — one file, one call, runs anywhere.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core import Allowlist, GlobalStd, MonaVec
from repro.data.synthetic import (embedding_corpus, pixel_corpus,
                                  queries_from_corpus)


def main() -> None:
    # --- Cosine semantic embeddings (the paper's primary setting) -----------
    corpus = embedding_corpus(seed=0, n=20_000, dim=1024)
    queries = queries_from_corpus(corpus, seed=1, n_q=5)

    index = MonaVec.build(corpus, metric="cosine")        # data-oblivious, 4-bit
    scores, ids = index.search(queries, k=5)
    print("cosine top-5 ids:\n", ids)

    # one file ...
    path = os.path.join(tempfile.gettempdir(), "quickstart.mvec")
    index.save(path)
    print(f"saved {os.path.getsize(path) / 2**20:.1f} MiB "
          f"(f32 would be {corpus.nbytes / 2**20:.0f} MiB)")

    # ... one call, byte-identical results
    index2 = MonaVec.load(path)
    scores2, ids2 = index2.search(queries, k=5)
    assert np.array_equal(ids, ids2) and np.array_equal(scores, scores2)
    print("reload => byte-identical top-K: OK")

    # --- Pre-filter allowlist ------------------------------------------------
    allow = Allowlist.from_ids(range(1000), index.backend.ids)
    _, ids_f = index.search(queries, k=5, allow=allow)
    assert (ids_f < 1000).all()
    print("pre-filter allowlist (exactly k allowed results): OK")

    # --- Serving: the compiled-plan searcher handle (DESIGN.md §7) -----------
    # search() compiles one reusable plan per (backend, shape bucket, k);
    # a bound searcher + warmup() keeps jit compilation out of the serving
    # (or measurement) window, and every later call is a plan-cache hit.
    search = index.searcher(k=5).warmup(len(queries))
    scores3, ids3 = search(queries)
    assert np.array_equal(ids3, ids)           # same plan, same results
    from repro import engine
    st = engine.plan_cache().stats
    print(f"searcher handle: plan cache hits={st.hits} "
          f"retraces={st.traces} (compile paid once, then cache hits): OK")

    # --- L2 raw-magnitude data: single-pass fit() ----------------------------
    pixels = pixel_corpus(seed=2, n=5_000, dim=784)
    std = MonaVec.fit(pixels)                              # global (mu, sigma)
    l2_index = MonaVec.build(pixels, metric="l2", std=std)
    _, ids_l2 = l2_index.search(pixels[:3], k=3)
    assert (ids_l2[:, 0] == np.arange(3).astype(np.uint64)).all()
    print("L2 + fit(): self-NN recovered: OK")

    # --- HNSW for larger corpora (auto-M policy) ------------------------------
    print("auto-M:", MonaVec.recommended_m(45_000), "->",
          MonaVec.recommended_m(1_200_000))
    hnsw = MonaVec.build(corpus[:5000], metric="cosine", index="hnsw",
                         m=16, ef_construction=64)
    _, ids_h = hnsw.search(queries, k=5, ef=64)
    print("hnsw top-5 ids:\n", ids_h)

    # --- Mutable lifecycle: the corpus grows and churns between sessions ----
    delta = embedding_corpus(seed=3, n=2_000, dim=1024)
    new_ids = index.add(delta)                 # new quantized segment, no rebuild
    index.delete(new_ids[::2])                 # tombstones, codes untouched
    _, ids_m = index.search(queries, k=5)      # scans base + segment, pre-top-k mask
    index.save(path)                           # v8 multi-segment layout
    assert np.array_equal(MonaVec.load(path).search(queries, k=5)[1], ids_m)
    reclaimed = index.compact()                # deterministic rewrite, back to v6
    print(f"lifecycle: +{len(new_ids)} rows, compact reclaimed {reclaimed}: OK")
    os.unlink(path)


if __name__ == "__main__":
    main()

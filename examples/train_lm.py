"""Train a small LM end to end with checkpointing + crash recovery.

Default is laptop-scale; --big trains a ~110M-param llama-style model for a
few hundred steps (hours on this 1-core container; the shape the framework
targets is the dry-run mesh).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--big]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lm_batch
from repro.models.transformer import TransformerConfig, init_params, lm_loss
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import train
from repro.train.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--big", action="store_true",
                    help="~110M params (slow on CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.big:
        cfg = TransformerConfig(
            name="llama-110m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
            dtype="float32")
    else:
        cfg = TransformerConfig(
            name="llama-8m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
            head_dim=32, d_ff=688, vocab=8_192, dtype="float32")
    n_params = cfg.param_count()
    print(f"[train_lm] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x {args.seq_len}")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="mvlm_")
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    res = train(
        loss_fn=lambda p, b: lm_loss(p, cfg, b["tokens"]),
        init_params_fn=lambda: init_params(cfg, jax.random.key(0)),
        batch_fn=lambda s: {"tokens": jnp.asarray(
            lm_batch(0, s, args.batch, args.seq_len, cfg.vocab)["tokens"])},
        n_steps=args.steps,
        opt_cfg=AdamWConfig(lr=3e-4),
        ckpt=ckpt, ckpt_every=50,
    )
    print(f"[train_lm] loss {res.losses[0]:.3f} -> "
          f"{np.mean(res.losses[-10:]):.3f}; checkpoints in {ckpt_dir} "
          f"(restart me with --ckpt-dir to resume exactly)")


if __name__ == "__main__":
    main()

"""The paper's technique as a distributed serving workload + arch integration.

1. Distributed 4-bit scan: corpus sharded over the local mesh via shard_map
   (the same code path the 512-chip dry-run lowers), validated against the
   single-device scan.
2. Arch integration: a trained GIN's node embeddings and a two-tower item
   tower, indexed by MonaVec — retrieval over learned representations.

    PYTHONPATH=src python examples/retrieval_at_scale.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import MonaVec, quantize as qz
from repro.core.scoring import score_f32, topk
from repro.data.synthetic import embedding_corpus, queries_from_corpus, random_graph
from repro.dist.retrieval import make_scan_topk_shardmap, scan_topk_pjit
from repro.models import gnn as gnn_m
from repro.models import recsys as rs


def distributed_scan() -> None:
    corpus = embedding_corpus(0, 65_536, 512)
    queries = queries_from_corpus(corpus, 1, 16)
    enc = qz.encode(jnp.asarray(corpus), metric="cosine")
    q_rot = qz.encode_query(jnp.asarray(queries), enc)

    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    with mesh:
        fn = make_scan_topk_shardmap(mesh, metric="cosine", k=10)
        vals_sm, ids_sm = fn(q_rot, enc.packed, enc.qnorms)
        vals_pj, ids_pj = scan_topk_pjit(q_rot, enc.packed, enc.qnorms,
                                         metric="cosine", k=10)
    assert np.array_equal(np.asarray(ids_sm), np.asarray(ids_pj))
    gt = np.asarray(topk(score_f32(jnp.asarray(queries), jnp.asarray(corpus),
                                   "cosine"), 10)[1])
    rec = np.mean([len(set(a.tolist()) & set(g.tolist())) / 10
                   for a, g in zip(np.asarray(ids_sm), gt)])
    print(f"[dist-scan] shard_map == pjit top-10; Recall@10={rec:.3f} "
          f"over 65K x 512 corpus")


def gin_embedding_index() -> None:
    """GIN is the one assigned arch the paper's technique can't accelerate
    directly (DESIGN.md §4) — but its OUTPUT embeddings are index-able."""
    cfg = C.get("gin-tu").make_smoke()
    params = gnn_m.init_params(cfg, jax.random.key(0))
    g = random_graph(5, 2000, 12_000, cfg.d_feat, cfg.n_classes)
    x = jnp.asarray(g["x"])
    for lp in params["layers"]:
        x = gnn_m.gin_layer(lp, x, jnp.asarray(g["src"]), jnp.asarray(g["dst"]),
                            2000)
    node_embs = np.asarray(x)
    idx = MonaVec.build(node_embs, metric="cosine")
    _, ids = idx.search(node_embs[:5], k=5)
    same_comm = np.mean(g["labels"][ids[:, 1:].astype(np.int64)] ==
                        g["labels"][:5, None])
    print(f"[gin-index] neighbours share the query's community "
          f"{100 * same_comm:.0f}% of the time (homophily recovered)")


def two_tower_candidates() -> None:
    """retrieval_cand at example scale: MonaVec scan over tower outputs."""
    cfg = C.get("two-tower-retrieval").make_smoke()
    params = rs.two_tower_init(cfg, jax.random.key(1))
    cand = np.asarray(rs.item_embedding(params, cfg, jnp.arange(50_000) % cfg.item_vocab))
    users = np.asarray(rs.user_embedding(
        params, cfg, jax.random.randint(jax.random.key(2), (8, cfg.n_user_feats),
                                        0, cfg.user_vocab)))
    idx = MonaVec.build(cand, metric="dot")
    _, ids = idx.search(users, k=10)
    gt = np.asarray(topk(score_f32(jnp.asarray(users), jnp.asarray(cand), "dot"),
                         10)[1])
    rec = np.mean([len(set(a.tolist()) & set(g.tolist())) / 10
                   for a, g in zip(ids.astype(np.int64), gt)])
    print(f"[two-tower] 4-bit candidate scan Recall@10={rec:.3f} over 50K items")


if __name__ == "__main__":
    distributed_scan()
    gin_embedding_index()
    two_tower_candidates()

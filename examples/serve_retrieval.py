"""End-to-end serving driver (the paper's kind: retrieval, not training).

Builds a 100K x 1024 index, then serves batched query traffic through the
full stack: dense 4-bit scan + BM25 hybrid fusion + pre-filter allowlists +
multi-tenant namespaces, measuring throughput.

    PYTHONPATH=src python examples/serve_retrieval.py [--n 100000] [--batches 20]
"""

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.core import Allowlist, HybridIndex, MonaVec, TenantRegistry
from repro.core.scoring import score_f32, topk
from repro.data.synthetic import embedding_corpus, queries_from_corpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=32)
    args = ap.parse_args()

    print(f"[build] corpus {args.n} x {args.dim} ...")
    t0 = time.time()
    corpus = embedding_corpus(0, args.n, args.dim)
    index = MonaVec.build(corpus, metric="cosine")
    print(f"[build] 4-bit index in {time.time() - t0:.1f}s "
          f"({index.backend.enc.packed.size / 2**20:.0f} MiB packed, "
          f"{corpus.nbytes / 2**20:.0f} MiB f32 equivalent)")

    # Multi-tenancy: per-team namespaces over the same stack.  The bound
    # searcher carries {namespace, collection} metric labels, so the whole
    # serving window lands in the process-wide registry (DESIGN.md §9) —
    # the QPS line below is derived from the metrics, not a stopwatch.
    reg = TenantRegistry()
    reg.put("team-search", "docs", index)
    search = reg.searcher("team-search", "docs", k=10)
    search.warmup(args.batch_size)   # compile outside the measured window

    before = obs.registry().snapshot()
    recalls = []
    for b in range(args.batches):
        q = queries_from_corpus(corpus, 100 + b, args.batch_size)
        scores, ids = search(q)
        if b % 5 == 0:   # spot-check recall vs exact
            gt = np.asarray(topk(score_f32(
                jax.numpy.asarray(q), jax.numpy.asarray(corpus), "cosine"), 10)[1])
            recalls.append(np.mean([
                len(set(a.tolist()) & set(g.tolist())) / 10
                for a, g in zip(ids.astype(np.int64), gt)]))
    snap = obs.registry().snapshot()
    lat = snap["histograms"][
        'tenancy.search_us{collection="docs",namespace="team-search"}']
    served = obs.counter_total(
        obs.counter_deltas(snap, before), "engine.query_rows")
    qps = served / (lat["sum"] / 1e6)
    print(f"[serve] {served} queries, search latency sum "
          f"{lat['sum'] / 1e6:.2f}s -> {qps:.0f} QPS "
          f"(single CPU core; Recall@10={np.mean(recalls):.3f})")

    # Filtered retrieval: pre-filter allowlist keeps exactly k results.
    allow = Allowlist.from_ids(range(0, args.n, 100), index.backend.ids)
    q = queries_from_corpus(corpus, 999, 8)
    _, ids = index.search(q, k=10, allow=allow)
    assert (ids.astype(np.int64) % 100 == 0).all()
    print(f"[filter] 1% allowlist -> exactly {ids.shape[1]} allowed results/query")

    # Hybrid keyword+dense on a subset.
    n_docs = min(10_000, args.n)
    docs = [f"document {i} topic-{i % 50}" + (" quantization" if i % 997 == 0 else "")
            for i in range(n_docs)]
    hy = HybridIndex.build(corpus[:n_docs], docs, metric="cosine")
    vals, ids = hy.search(q[0], "quantization topic-3", k=5)
    print(f"[hybrid] RRF fused top-5: {ids.tolist()}")

    # Final metrics snapshot: the run's whole story — per-stage latency
    # histograms, plan-cache counters, per-namespace requests — straight
    # from the registry this example just exercised.
    print("[metrics] final snapshot:")
    for line in obs.render_text(
            obs.registry().snapshot(),
            only=("engine.", "plan_cache.", "tenancy.")).splitlines():
        print(f"[metrics]   {line}")


if __name__ == "__main__":
    main()
